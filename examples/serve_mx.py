"""Serve a small model with continuous batching over a paged MX KV cache.

Ragged prompt lengths + MX fp8 cache: requests enter and leave decode
mid-stream, cache pages are allocated as tokens arrive and recycled at EOS.

  PYTHONPATH=src python examples/serve_mx.py
"""
from repro.launch import serve as serve_launcher

serve_launcher.main([
    "--arch", "recurrentgemma-2b", "--reduced", "--batch", "6",
    "--max-slots", "3", "--prompt-len", "12", "--new-tokens", "24",
    "--quant", "mxfp8", "--quantize-kv", "--ragged",
    "--engine", "continuous", "--page-size", "8",
])
