"""Serve a small model with MX-compressed weights and batched requests.

  PYTHONPATH=src python examples/serve_mx.py
"""
from repro.launch import serve as serve_launcher

serve_launcher.main([
    "--arch", "recurrentgemma-2b", "--reduced", "--batch", "4",
    "--prompt-len", "12", "--new-tokens", "24",
    "--quant", "mxfp8", "--quantize-kv",
])
