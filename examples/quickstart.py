"""Quickstart: the paper's MX-DP primitive end to end in 30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import MXFP8, mx_dot, quantize
from repro.kernels import mx_matmul, quantize_pallas
from repro.kernels import ref as R

rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))

# 1. Block-quantize to MXFP8 (software-defined block size, paper §IV-A)
xq = quantize(x, "fp8_e4m3", block_size=32)          # pure-jnp path
wq = quantize_pallas(w.T, "fp8_e4m3", 32)            # fused Pallas kernel
wq = quantize(w, "fp8_e4m3", 32, axis=0)             # blocked along K
print(f"storage: {x.nbytes + w.nbytes} wide bytes -> "
      f"{xq.nbytes + wq.nbytes} MX bytes")

# 2. The three execution tiers of the paper
y_emulated = mx_dot(xq, wq, mode="emulated")   # RVV-baseline analogue
y_fused = mx_dot(xq, wq, mode="fused")         # Spatz-baseline analogue
y_native = mx_matmul(xq, wq)                   # VMXDOTP analogue (Pallas)

# 3. All tiers compute the same MX dot product (Eq. 1)
oracle = R.mx_matmul_ref(xq.elements, xq.scales, wq.elements, wq.scales,
                         fmt="fp8_e4m3", block_size=32)
for name, y in [("emulated", y_emulated), ("fused", y_fused),
                ("native", y_native)]:
    err = float(jnp.max(jnp.abs(y - oracle)))
    print(f"{name:10s} max |err| vs MX oracle: {err:.2e}")

# 4. Accuracy vs the unquantized matmul
rel = float(jnp.linalg.norm(y_native - x @ w) / jnp.linalg.norm(x @ w))
print(f"MXFP8 end-to-end relative error vs f32 matmul: {rel:.3%}")
