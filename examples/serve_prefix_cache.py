"""Serve requests sharing a system-prompt head through the prefix cache.

Eight requests share a 64-token head (think: common system prompt) and
differ only in a short user tail. The radix tree recognises the shared
page-aligned head after the first prefill: later requests retain the same
physical MX pages (ref-counted, copy-on-write) and prefill only their
tail, so the log shows a high prefix hit rate and far fewer peak pages
than eight private copies would need.

  PYTHONPATH=src python examples/serve_prefix_cache.py
"""
from repro.launch import serve as serve_launcher

serve_launcher.main([
    "--arch", "gemma2-2b", "--reduced", "--batch", "8",
    "--max-slots", "4", "--shared-prefix", "64", "--prompt-len", "12",
    "--new-tokens", "16", "--quant", "mxfp8", "--quantize-kv", "--ragged",
    "--engine", "continuous", "--page-size", "16",
])
