"""End-to-end driver: train a ~100M-param MX-quantized LM for a few hundred
steps on synthetic data, with checkpoints and auto-resume.

  PYTHONPATH=src python examples/train_mx_lm.py [--steps 300] [--small]

The model is a gemma2-family stack scaled to ~100M params. With --small it
shrinks to seconds-per-step on CPU (CI mode); the full ~100M configuration
is the honest e2e run on a real host.
"""
import argparse
import tempfile

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="mxlm_ckpt_")
    argv = ["--arch", "gemma2-2b", "--reduced", "--steps", str(args.steps),
            "--ckpt-dir", ckpt, "--quant", "mxfp8",
            "--seq-len", "64" if args.small else "256",
            "--global-batch", "8" if args.small else "16",
            "--microbatches", "1" if args.small else "2"]
    final = train_launcher.main(argv)
    print(f"finished at step {final}; checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
