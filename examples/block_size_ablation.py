"""Ablation: software-defined block sizes (the paper's flexibility claim).

Trains the same tiny LM under MXFP8/MXFP4 with k in {8, 32, 128} and reports
final loss vs the wide baseline — small blocks recover accuracy for FP4.

  PYTHONPATH=src python examples/block_size_ablation.py
"""
import jax
import jax.numpy as jnp

from repro.core import QuantConfig, WIDE
from repro.data import DataConfig, SyntheticLMDataset
from repro.nn import BlockDef, ModelConfig
from repro.train import OptimConfig, init_state, make_train_step

STEPS = 60


def run(quant, label):
    cfg = ModelConfig(
        name="abl", family="dense", d_model=128, vocab_size=256,
        pattern=(BlockDef("attn"),), num_groups=2, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, quant=quant)
    state, _ = init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(lr=3e-3, warmup_steps=5,
                                                    total_steps=STEPS)))
    ds = SyntheticLMDataset(DataConfig(vocab_size=256, seq_len=64,
                                       global_batch=8))
    losses = []
    for s in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    final = sum(losses[-5:]) / 5
    print(f"{label:22s} final loss {final:.4f}")
    return final


if __name__ == "__main__":
    base = run(WIDE, "wide bf16")
    for fmt in ("fp8_e4m3", "fp4_e2m1"):
        for k in (8, 32, 128):
            q = QuantConfig(fmt=fmt, act_fmt="fp8_e5m2", block_size=k)
            run(q, f"{fmt} k={k}")
    print(f"(wide reference: {base:.4f})")
