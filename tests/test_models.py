"""Model-zoo behaviour tests: train step, decode==forward, MX integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP4, MXFP8, WIDE, QuantConfig
from repro.nn import BlockDef, ModelConfig, model


def tiny(mixer="attn", ffn="dense", **kw):
    base = dict(
        name="tiny", family="dense", d_model=64, vocab_size=256,
        pattern=(BlockDef(mixer=mixer, ffn=ffn),), num_groups=2,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        num_experts=4, top_k=2, d_ff_expert=64,
        rnn_width=64, d_inner=128, headdim=16, d_state=32, ssd_chunk=8,
        kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        quant=QuantConfig(enabled=False),
    )
    base.update(kw)
    return ModelConfig(**base)


KEY = jax.random.PRNGKey(0)
MIXERS = ["attn", "mla", "rglru", "ssd"]


@pytest.mark.parametrize("mixer", MIXERS)
def test_forward_and_grads_finite(mixer):
    cfg = tiny(mixer, ffn="none" if mixer == "ssd" else "dense")
    params, axes = model.init(KEY, cfg)
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        axes, is_leaf=lambda t: isinstance(t, tuple)
    )
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    logits, aux = model.forward(params, cfg, tokens)
    assert logits.shape == (2, 16, 256)
    assert bool(jnp.isfinite(logits).all())
    (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, cfg, {"tokens": tokens, "labels": tokens})
    assert bool(jnp.isfinite(loss))
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize(
    "mixer,kw",
    [
        ("attn", {}),
        ("attn", dict(pattern=(BlockDef("attn", window=8),))),  # ring buffer
        ("rglru", {}),
        ("ssd", {}),
    ],
)
def test_decode_matches_forward_exactly(mixer, kw):
    """Teacher-forced prefill+decode must reproduce full-forward logits."""
    cfg = tiny(mixer, **kw)
    params, _ = model.init(KEY, cfg)
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, 256)
    full_logits, _ = model.forward(params, cfg, tokens)
    half = S // 2
    pf, cache = model.prefill(params, cfg, tokens[:, :half], max_seq=S)
    np.testing.assert_allclose(
        np.asarray(pf[:, 0]), np.asarray(full_logits[:, half - 1]),
        rtol=1e-5, atol=1e-5)
    for t in range(half, S - 1):
        step, cache = model.decode_step(
            params, cfg, cache, tokens=tokens[:, t:t + 1],
            pos=jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(step[:, 0]), np.asarray(full_logits[:, t]),
            rtol=1e-5, atol=1e-5)


def test_decode_matches_forward_mla_tolerance():
    """MLA decode uses the absorbed form + bf16 latent cache: small tol."""
    cfg = tiny("mla")
    params, _ = model.init(KEY, cfg)
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, 256)
    full_logits, _ = model.forward(params, cfg, tokens)
    _, cache = model.prefill(params, cfg, tokens[:, :8], max_seq=S)
    step, cache = model.decode_step(params, cfg, cache,
                                    tokens=tokens[:, 8:9],
                                    pos=jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full_logits[:, 8]),
                               rtol=0.05, atol=0.05)


def test_windowed_decode_beyond_window():
    """Ring-buffer cache keeps matching forward after position > window."""
    cfg = tiny("attn", pattern=(BlockDef("attn", window=4),))
    params, _ = model.init(KEY, cfg)
    S = 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, 256)
    full_logits, _ = model.forward(params, cfg, tokens)
    _, cache = model.prefill(params, cfg, tokens[:, :6], max_seq=S)
    for t in range(6, S - 1):
        step, cache = model.decode_step(params, cfg, cache,
                                        tokens=tokens[:, t:t + 1],
                                        pos=jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(step[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quant", [MXFP8, MXFP4], ids=["mxfp8", "mxfp4"])
def test_mx_quantized_training(quant):
    """MX-quantized (QAT) train step: finite loss + grads, loss near wide."""
    quant = quant.replace(block_size=16)
    cfg = tiny("attn", quant=quant)
    params, _ = model.init(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}
    (loss_q, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, cfg, batch)
    cfg_w = tiny("attn", quant=WIDE)
    (loss_w, _) = model.loss_fn(params, cfg_w, batch)[0], None
    assert bool(jnp.isfinite(loss_q))
    assert abs(float(loss_q) - float(loss_w[0] if isinstance(loss_w, tuple) else loss_w)) < 1.0
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(g).all())


def test_mx_quantized_kv_cache_decode():
    """MX-quantized KV cache: decode stays close to wide-cache decode."""
    q = MXFP8.replace(block_size=16, quantize_kv_cache=True, quantize_acts=False)
    cfg = tiny("attn", quant=q)
    cfg_wide = tiny("attn", quant=q.replace(quantize_kv_cache=False))
    params, _ = model.init(KEY, cfg)
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, 256)
    _, cache_q = model.prefill(params, cfg, tokens[:, :8], max_seq=S)
    _, cache_w = model.prefill(params, cfg_wide, tokens[:, :8], max_seq=S)
    assert cache_q["groups"][0]["k_elems"].dtype == jnp.float8_e4m3fn
    sq, _ = model.decode_step(params, cfg, cache_q, tokens=tokens[:, 8:9],
                              pos=jnp.asarray(8, jnp.int32))
    sw, _ = model.decode_step(params, cfg_wide, cache_w, tokens=tokens[:, 8:9],
                              pos=jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(sq), np.asarray(sw), rtol=0.2, atol=0.5)


def test_moe_routing_properties():
    cfg = tiny("attn", ffn="moe")
    params, _ = model.init(KEY, cfg)
    from repro.nn import moe as moe_mod
    from repro.nn.blocks import _moe_cfg

    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 64), jnp.bfloat16)
    mcfg = _moe_cfg(cfg)
    gp = jax.tree_util.tree_map(lambda p: p[0], params["groups"])
    out, aux = moe_mod.apply(gp["block0"]["ffn"], x, mcfg, cfg.quant)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # E*<f,p> >= 1 by Cauchy-Schwarz
    w, one_hot, _ = moe_mod._router(gp["block0"]["ffn"], x, mcfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(one_hot.sum(-1).max()) == 1  # top-k entries are distinct


def test_musicgen_codebooks():
    cfg = tiny("attn", num_codebooks=4, vocab_size=64)
    params, _ = model.init(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8, 4), 0, 64)
    logits, _ = model.forward(params, cfg, tokens)
    assert logits.shape == (2, 8, 4, 64)
    loss, _ = model.loss_fn(params, cfg, {"tokens": tokens, "labels": tokens})
    assert bool(jnp.isfinite(loss))


def test_embeds_input_stub():
    """VLM/audio frontend stub: forward from precomputed embeddings."""
    cfg = tiny("attn")
    params, _ = model.init(KEY, cfg)
    embeds = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 64))
    logits, _ = model.forward(params, cfg, embeds=embeds)
    assert logits.shape == (2, 8, 256)


def test_prologue_epilogue_layers():
    cfg = tiny("attn", prologue=(BlockDef("attn", ffn="dense"),),
               epilogue=(BlockDef("rglru", ffn="dense"),))
    params, _ = model.init(KEY, cfg)
    assert "prologue0" in params and "epilogue0" in params
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 256)
    logits, _ = model.forward(params, cfg, tokens)
    assert bool(jnp.isfinite(logits).all())
    # serving path covers prologue/epilogue caches too
    _, cache = model.prefill(params, cfg, tokens[:, :4], max_seq=8)
    step, _ = model.decode_step(params, cfg, cache, tokens=tokens[:, 4:5],
                                pos=jnp.asarray(4, jnp.int32))
    full, _ = model.forward(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, 4]),
                               rtol=1e-5, atol=1e-5)


def test_query_chunked_attention_equivalence():
    cfg_full = tiny("attn", query_chunk=1024)
    cfg_chunk = tiny("attn", query_chunk=4)
    params, _ = model.init(KEY, cfg_full)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    lf, _ = model.forward(params, cfg_full, tokens)
    lc, _ = model.forward(params, cfg_chunk, tokens)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc), rtol=2e-4,
                               atol=2e-4)
