"""Hypothesis compatibility shim: property tests run everywhere.

When ``hypothesis`` is installed, this module re-exports the real
``given``/``settings``/strategies and the tests are true property tests.
When it is absent (the seed image does not bake it in), a minimal
deterministic fallback replaces them: each ``@given`` test becomes a
pytest-parametrized sweep over fixed-seed random examples drawn from
lightweight strategy stand-ins. The sweep is deterministic per test name,
so failures reproduce, and it is capped so the fast suite stays fast.

Only the strategy surface these tests use is implemented: ``st.floats``,
``st.integers``, ``st.sampled_from``, ``.map``, and
``hypothesis.extra.numpy.arrays``. Extend as tests grow.
"""
from __future__ import annotations

try:  # real hypothesis, when available
    from hypothesis import given, settings  # noqa: F401
    import hypothesis.strategies as st  # noqa: F401
    from hypothesis.extra import numpy as hnp  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import types
    import zlib

    import numpy as np
    import pytest

    HAVE_HYPOTHESIS = False
    _MAX_EXAMPLES_CAP = 10  # fallback sweep budget per test (fast suite)

    class _Strategy:
        def example(self, rng):
            raise NotImplementedError

        def map(self, fn):
            return _Mapped(self, fn)

    class _Mapped(_Strategy):
        def __init__(self, inner, fn):
            self.inner, self.fn = inner, fn

        def example(self, rng):
            return self.fn(self.inner.example(rng))

    class _Floats(_Strategy):
        def __init__(self, min_value, max_value, **_):
            self.lo, self.hi = float(min_value), float(max_value)

        def example(self, rng):
            # hypothesis spreads floats across magnitudes; mimic with a
            # log-uniform draw when the positive range spans many decades
            if self.lo > 0 and self.hi / self.lo > 1e6:
                return float(np.exp(rng.uniform(np.log(self.lo),
                                                np.log(self.hi))))
            return float(rng.uniform(self.lo, self.hi))

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=2**31 - 1):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class _Arrays(_Strategy):
        def __init__(self, dtype, shape, elements=None):
            self.dtype, self.shape, self.elements = dtype, shape, elements

        def example(self, rng):
            shape = self.shape.example(rng) if isinstance(
                self.shape, _Strategy) else tuple(self.shape)
            if isinstance(self.elements, _Floats):
                vals = rng.uniform(self.elements.lo, self.elements.hi,
                                   size=shape)
            elif isinstance(self.elements, _Integers):
                vals = rng.integers(self.elements.lo, self.elements.hi + 1,
                                    size=shape)
            else:
                vals = rng.standard_normal(shape)
            return np.asarray(vals, dtype=self.dtype)

    st = types.SimpleNamespace(
        floats=lambda min_value=-1e9, max_value=1e9, **kw: _Floats(
            min_value, max_value, **kw),
        integers=lambda min_value=0, max_value=2**31 - 1: _Integers(
            min_value, max_value),
        sampled_from=_SampledFrom,
    )
    hnp = types.SimpleNamespace(arrays=_Arrays)

    def settings(max_examples=20, **_):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strats, **kw_strats):
        def deco(fn):
            n = min(getattr(fn, "_shim_max_examples", 20), _MAX_EXAMPLES_CAP)
            seed0 = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())

            def run(_shim_example):
                rng = np.random.default_rng(
                    (seed0 + 7919 * _shim_example) % 2**32)
                args = [s.example(rng) for s in strats]
                kwargs = {k: s.example(rng) for k, s in kw_strats.items()}
                return fn(*args, **kwargs)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return pytest.mark.parametrize("_shim_example", range(n))(run)
        return deco
