"""Layer-fused megakernel: the whole engine step as ONE pallas_call.

The megakernel (`kernels.mx_megakernel_step`) runs every layer's
RMSNorm, fused QKV+RoPE, ragged MX page walk (with the in-kernel
quantized K/V write), output projection and gated MLP in a single
Pallas dispatch, with the per-layer ragged step kept as the validated
oracle. Its acceptance bar, pinned here:

  * step-level bit-identity — logits AND written pool bytes must equal
    `model.ragged_step_paged` exactly, across fp8 e4m3/e5m2 + fp4,
    block sizes 16/32/64, unaligned mid-page row starts, speculative
    verify windows, sliding windows, and tiered mixed-format pools;
  * engine-level token identity — `step_mode="megakernel"` emits the
    same per-request streams as `step_mode="ragged"` under churn,
    preemption, speculative decoding, tiering and prefix sharing;
  * the structural claim — the traced step's jaxpr executes exactly
    ONE pallas_call where the per-layer oracle executes L;
  * the fallback ladder — configs the fused stack cannot serve are
    rejected with a named reason and drop to the per-layer step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, blocks, model
from repro.serve import ContinuousBatchingEngine, ServeConfig
from repro.serve.engine import _pallas_calls_in

PS = 8


def _cfg(fmt="fp8_e4m3", block_size=16, head_dim=16, num_groups=2,
         window=None, quantize_acts=False, d_model=64):
    return ModelConfig(
        name="t", family="dense", d_model=d_model, vocab_size=128,
        pattern=(BlockDef("attn", window=window),), num_groups=num_groups,
        num_heads=4, num_kv_heads=2, head_dim=head_dim, d_ff=128,
        quant=MXFP8.replace(fmt=fmt, block_size=block_size,
                            quantize_acts=quantize_acts,
                            quantize_kv_cache=True),
        decode_kernel="fused")


# ---------------------------------------------------------------------------
# step-level bit-identity vs the per-layer ragged oracle
# ---------------------------------------------------------------------------


def _fill_pool(pool, rng):
    """Decoy-filled pool: valid random bytes everywhere, so unwritten
    rows must survive the in-kernel merge untouched and garbage pages
    must never contribute. Scale bytes stay in a finite-decode range —
    E8M0 code 255 is an inf scale, which poisons both sides' logits
    with NaNs whose payload bits are schedule-dependent."""
    out = {}
    for key, leaf in pool.items():
        arr = np.asarray(leaf)
        if key.endswith("_scales"):
            out[key] = jnp.asarray(
                rng.integers(118, 134, arr.shape).astype(np.uint8))
        elif arr.dtype == np.uint8:
            out[key] = jnp.asarray(
                rng.integers(0, 256, arr.shape).astype(np.uint8))
        else:
            out[key] = jnp.asarray(
                rng.normal(size=arr.shape).astype(np.float32)).astype(
                    arr.dtype)
    return out


def _run_both_steps(cfg, tiered=False, seed=0, w=8):
    """One mixed ragged batch through oracle and megakernel.

    Row modes cover the full composition: plain decode from a mid-page
    start (13), a 3-token verify window straddling a page boundary (9),
    a fresh prefill chunk (0), and a continuation chunk from an
    unaligned mid-page start (12)."""
    rng = np.random.default_rng(seed)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    num_slots, num_pages = 4, 12
    cache = model.init_paged_cache(cfg, num_slots, num_pages, PS,
                                   tiered=tiered)
    cache_a = {"groups": tuple(_fill_pool(p, rng)
                               for p in cache["groups"])}
    flat, td = jax.tree_util.tree_flatten(cache_a)
    cache_b = jax.tree_util.tree_unflatten(td, list(flat))

    starts = np.asarray([13, 9, 0, 12], np.int32)
    n_news = np.asarray([1, 3, w, w], np.int32)
    lens = starts + n_news
    r = len(starts)
    pages_per = [-(-int(t) // PS) for t in lens]
    perm = rng.permutation(num_pages - 1)  # never the trash page
    table = np.full((r, max(pages_per) + 1), -1, np.int32)
    off = 0
    for i, npg in enumerate(pages_per):
        table[i, :npg] = perm[off:off + npg]
        off += npg
    tokens = rng.integers(0, cfg.vocab_size, (r, w)).astype(np.int32)
    logit_idx = np.zeros(r, np.int32)
    page_fmts = None
    if tiered:
        page_fmts = rng.integers(0, 3, (num_pages,)).astype(np.int32)
        for row in table:  # hot-write invariant: written pages are fp8
            for pidx in row:
                if pidx >= 0:
                    page_fmts[pidx] = 0
        page_fmts = jnp.asarray(page_fmts)

    args = (jnp.asarray(tokens), jnp.asarray(table), jnp.asarray(starts),
            jnp.asarray(lens), jnp.asarray(logit_idx))
    la, ca = jax.jit(lambda p, c, *a: model.ragged_step_paged(
        p, cfg, c, *a, num_logits=2, page_fmts=page_fmts))(
            params, cache_a, *args)
    mk = model.pack_megakernel_params(params, cfg)
    lb, cb = jax.jit(lambda p, c, *a: model.megakernel_step_paged(
        p, cfg, c, *a, num_logits=2, page_fmts=page_fmts))(
            mk, cache_b, *args)
    return np.asarray(la), ca, np.asarray(lb), cb


def _assert_bit_identical(la, ca, lb, cb):
    np.testing.assert_array_equal(la.view(np.uint8), lb.view(np.uint8))
    for x, y in zip(jax.tree_util.tree_leaves(ca),
                    jax.tree_util.tree_leaves(cb)):
        np.testing.assert_array_equal(np.asarray(x).view(np.uint8),
                                      np.asarray(y).view(np.uint8))


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_megakernel_bit_matches_ragged_oracle(fmt, block_size):
    """Format x block-size matrix: logits AND pool bytes, exactly."""
    cfg = _cfg(fmt=fmt, block_size=block_size, head_dim=block_size,
               d_model=block_size * 4)
    _assert_bit_identical(*_run_both_steps(cfg, seed=11 + block_size))


def test_megakernel_sliding_window():
    cfg = _cfg(window=12)
    _assert_bit_identical(*_run_both_steps(cfg, seed=5))


@pytest.mark.parametrize("num_groups", [1, 3])
def test_megakernel_tiered_mixed_pool(num_groups):
    """Tiered pools: per-page fp8/fp6/fp4 dequant select + trash-page
    isolation must survive the layer fusion, at L=1 and an odd L."""
    cfg = _cfg(num_groups=num_groups)
    _assert_bit_identical(
        *_run_both_steps(cfg, tiered=True, seed=3 + num_groups))


# ---------------------------------------------------------------------------
# structural: ONE pallas_call per step (oracle pays L)
# ---------------------------------------------------------------------------


def test_megakernel_jaxpr_one_pallas_call():
    """The tentpole's whole claim, measured on traced jaxprs: the fused
    step launches 1 device kernel; the per-layer oracle launches L
    (its one lexical pallas_call times the scan trip count)."""
    L = 4
    cfg = _cfg(num_groups=L)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    cache = model.init_paged_cache(cfg, 2, 8, PS)
    args = (jnp.zeros((2, 4), jnp.int32), jnp.zeros((2, 3), jnp.int32),
            jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32),
            jnp.zeros((2,), jnp.int32))
    ragged = jax.make_jaxpr(
        lambda p, c: model.ragged_step_paged(p, cfg, c, *args))(
            params, cache)
    assert _pallas_calls_in(ragged.jaxpr) == L
    mk = model.pack_megakernel_params(params, cfg)
    mega = jax.make_jaxpr(
        lambda p, c: model.megakernel_step_paged(p, cfg, c, *args))(
            mk, cache)
    assert _pallas_calls_in(mega.jaxpr) == 1


# ---------------------------------------------------------------------------
# engine-level token identity vs the ragged engine
# ---------------------------------------------------------------------------


def _churn_reqs(rng):
    return [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(4, 12), (4, 12), (7, 5), (3, 8)]]


def _run_pair(cfg, reqs, **kw):
    outs, engines = {}, {}
    for mode in ("ragged", "megakernel"):
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            step_mode=mode, **kw))
        ids = [eng.submit(p, m) for p, m in reqs]
        out = eng.run()
        outs[mode] = [out[i] for i in ids]
        engines[mode] = eng
    assert engines["megakernel"].megakernel, (
        engines["megakernel"]._megakernel_fallback_reason)
    for a, b in zip(outs["ragged"], outs["megakernel"]):
        np.testing.assert_array_equal(a, b)
    return engines


SCENARIOS = {
    "churn-prefix": dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                         prefix_cache=True),
    "chunked": dict(max_seq=48, max_slots=2, page_size=8, prefill_chunk=8),
    "spec": dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                 prefix_cache=True, spec_decode=True, num_draft_tokens=2),
    "tiered": dict(max_seq=48, max_slots=2, page_size=8, prefill_chunk=8,
                   num_pages=14, tiered=True),
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_megakernel_engine_token_identical(scenario):
    """Churn, preemption, speculative verify+rollback, tiering, prefix
    sharing: per-request streams equal the ragged engine exactly, and
    the jaxpr audit confirms 1 kernel/step vs the oracle's L."""
    cfg = _cfg()
    reqs = _churn_reqs(np.random.default_rng(3))
    engines = _run_pair(cfg, reqs, **SCENARIOS[scenario])
    sm = engines["megakernel"].cache_stats()
    sr = engines["ragged"].cache_stats()
    assert sm["pallas_calls_per_step"] == 1, sm
    assert sr["pallas_calls_per_step"] == cfg.num_layers, sr
    assert sm["megakernel"] and not sr["megakernel"]
    if sm["mixed_steps"]:
        assert sm["dispatches_per_mixed_step"] == 1.0, sm


def test_megakernel_multichunk_prefill_budgeting():
    """Ragged-aware prefill budgeting: with the batch undersubscribed,
    prefill_max_chunks=4 retires a 30-token prompt in fewer dispatches
    than one-chunk-per-step, token streams unchanged (chunk splits are
    numerics-invariant on the ragged path)."""
    cfg = _cfg()
    rng = np.random.default_rng(21)
    reqs = [(rng.integers(0, 128, (30,)).astype(np.int32), 4),
            (rng.integers(0, 128, (4,)).astype(np.int32), 6)]
    outs, engines = {}, {}
    for tag, mc in (("one", 1), ("four", 4)):
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            step_mode="megakernel", max_seq=48, max_slots=3, page_size=4,
            prefill_chunk=4, prefill_max_chunks=mc))
        ids = [eng.submit(p, m) for p, m in reqs]
        out = eng.run()
        outs[tag] = [out[i] for i in ids]
        engines[tag] = eng
    for a, b in zip(outs["one"], outs["four"]):
        np.testing.assert_array_equal(a, b)
    s1 = engines["one"].cache_stats()
    s4 = engines["four"].cache_stats()
    assert s4["prefill_dispatches"] < s1["prefill_dispatches"], (s1, s4)
    assert s4["prefill_rows_per_step"] > s1["prefill_rows_per_step"]


def test_scheduler_prefill_chunk_budget():
    """The budgeting formula's starvation bound: a full batch always
    drops back to exactly one chunk per sequence per step."""
    from repro.serve.scheduler import Scheduler
    sched = Scheduler(max_slots=2, num_pages=16, page_size=4, max_seq=16,
                      prefill_chunk=4, prefill_max_chunks=3)
    assert sched.prefill_allowed_chunks() == 3  # empty batch
    for _ in range(2):
        sched.submit(np.arange(12, dtype=np.int32), 2)
    assert sched.admit_next() is not None
    assert sched.prefill_allowed_chunks() == 3  # one slot still free
    assert sched.admit_next() is not None
    assert sched.prefill_allowed_chunks() == 1  # fully subscribed
    seq = sched.prefilling()[0]
    # undersubscribed width caps the bite at width and at the prompt
    assert sched.planned_prefill_real(seq, 4) == 4
    with pytest.raises(ValueError):
        Scheduler(max_slots=2, num_pages=16, page_size=4, max_seq=16,
                  prefill_chunk=4, prefill_max_chunks=0)


# ---------------------------------------------------------------------------
# fallback ladder
# ---------------------------------------------------------------------------


def test_reject_reason_ladder():
    good = _cfg()
    assert blocks.megakernel_reject_reason(good) is None
    cases = [
        (good.replace(pattern=(BlockDef("ssd"),)), "non-attention"),
        (good.replace(pattern=(BlockDef("attn"),
                               BlockDef("attn", window=8))),
         "non-uniform"),
        (good.replace(pattern=(BlockDef("attn"), BlockDef("attn"))),
         "stack layout"),
        (good.replace(prologue=(BlockDef("attn"),)), "stack layout"),
        (good.replace(pattern=(BlockDef("attn", ffn="none"),)), "ffn"),
        (good.replace(quant=good.quant.replace(quantize_acts=True)),
         "activation quantization"),
        (good.replace(quant=good.quant.replace(quantize_kv_cache=False)),
         "wide bf16 KV pool"),
    ]
    for cfg, needle in cases:
        reason = blocks.megakernel_reject_reason(cfg)
        assert reason and needle in reason, (needle, reason)


def test_engine_fallback_to_ragged():
    """A config the fused stack rejects still serves — on the per-layer
    ragged step, with the reason recorded — and emits the same tokens."""
    cfg = _cfg(quantize_acts=True)  # rejected by the static ladder
    reqs = _churn_reqs(np.random.default_rng(7))[:2]
    outs = {}
    for mode in ("ragged", "megakernel"):
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            step_mode=mode, max_seq=32, max_slots=2, page_size=4,
            prefill_chunk=4))
        ids = [eng.submit(p, m) for p, m in reqs]
        out = eng.run()
        outs[mode] = [out[i] for i in ids]
        if mode == "megakernel":
            assert not eng.megakernel
            assert "activation quantization" in \
                eng._megakernel_fallback_reason
            assert eng.ragged  # fell back one rung, not all the way
    for a, b in zip(outs["ragged"], outs["megakernel"]):
        np.testing.assert_array_equal(a, b)


def test_engine_fallback_to_split():
    """Ragged prerequisites unmet (einsum decode kernel): megakernel
    falls all the way back to split dispatches and still serves."""
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        step_mode="megakernel", max_seq=32, max_slots=2, page_size=4,
        decode_kernel="einsum"))
    assert not eng.megakernel and not eng.ragged
    assert "ragged prerequisites" in eng._megakernel_fallback_reason
    rid = eng.submit(np.arange(5, dtype=np.int32), 3)
    out = eng.run()
    assert len(out[rid]) == 8


def test_megakernel_param_specs_head_columns():
    """Sharded-megakernel groundwork: packed q/k/v leaves shard their
    head-column (last) dim, the stacked layer axis stays replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import megakernel_param_specs
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    packed = model.pack_megakernel_params(params, cfg)
    specs = megakernel_param_specs(packed)
    for name in ("wq", "wk", "wv"):
        assert specs["layers"][name]["w"] == P(None, None, "model")
    assert specs["layers"]["wo"]["w"] == P()
    assert specs["layers"]["up"]["w"] == P()
    assert specs["embedding"] == jax.tree_util.tree_map(
        lambda _: P(), specs["embedding"])
