"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; plus one decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced, list_archs
from repro.nn import model

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg, batch=B, seq=S):
    """Batch matching the arch family (tokens / codebooks / embeds stub)."""
    if cfg.family in ("vlm",):
        embeds = jax.random.normal(jax.random.PRNGKey(2), (batch, seq, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(3), (batch, seq), 0,
                                    cfg.vocab_size)
        return {"embeds": embeds, "labels": labels}
    if cfg.num_codebooks > 1:
        tokens = jax.random.randint(jax.random.PRNGKey(2),
                                    (batch, seq, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
        return {"tokens": tokens, "labels": tokens}
    tokens = jax.random.randint(jax.random.PRNGKey(2), (batch, seq), 0,
                                cfg.vocab_size)
    return {"tokens": tokens, "labels": tokens}


# compile-heavy train-step smokes whose code paths the fast tier already
# covers elsewhere (deepseek: MLA+MoE backward ~1 min on CPU; gemma2-9b
# duplicates gemma2-2b's stack; musicgen's codebook decode smoke stays).
# Their decode smokes below remain in the fast tier.
_HEAVY_TRAIN_SMOKE = {"deepseek-v2-lite-16b", "gemma2-9b", "musicgen-medium"}


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_TRAIN_SMOKE
     else a for a in list_archs()])
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.name == get_config(arch).name
    params, _ = model.init(KEY, cfg)
    batch = _inputs(cfg)
    logits, _ = model.forward(params, cfg,
                              tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"))
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    (loss, metrics), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, cfg, batch)
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    params, _ = model.init(KEY, cfg)
    batch = _inputs(cfg)
    _, cache = model.prefill(params, cfg,
                             tokens=None if "tokens" not in batch
                             else batch["tokens"][:, : S // 2],
                             embeds=None if "embeds" not in batch
                             else batch["embeds"][:, : S // 2],
                             max_seq=S)
    pos = jnp.asarray(S // 2, jnp.int32)
    if "embeds" in batch:
        step, cache2 = model.decode_step(params, cfg, cache,
                                         embeds=batch["embeds"][:, S // 2: S // 2 + 1],
                                         pos=pos)
    else:
        step, cache2 = model.decode_step(params, cfg, cache,
                                         tokens=batch["tokens"][:, S // 2: S // 2 + 1],
                                         pos=pos)
    assert step.shape[0] == B and step.shape[1] == 1
    assert bool(jnp.isfinite(step).all()), arch
    # cache structure must be stable across steps (jit-compatible)
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


def test_full_configs_match_assignment_sheet():
    """Pin the exact assigned hyperparameters (guards against drift)."""
    expect = {
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  num_kv_heads=1, d_ff=7680, vocab_size=256000),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff_expert=16384,
                              vocab_size=32768, num_experts=8, top_k=2),
        "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048, num_heads=16,
                                     d_ff_expert=1408, vocab_size=102400,
                                     num_experts=64, top_k=6, num_shared=2,
                                     kv_lora=512),
        "gemma2-2b": dict(num_layers=26, d_model=2304, num_heads=8,
                          num_kv_heads=4, d_ff=9216, vocab_size=256000),
        "gemma2-9b": dict(num_layers=42, d_model=3584, num_heads=16,
                          num_kv_heads=8, d_ff=14336, vocab_size=256000),
        "phi4-mini-3.8b": dict(num_layers=32, d_model=3072, num_heads=24,
                               num_kv_heads=8, d_ff=8192, vocab_size=200064),
        "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=49152),
        "mamba2-780m": dict(num_layers=48, d_model=1536, d_state=128,
                            vocab_size=50280),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096,
                                      num_heads=32, num_kv_heads=8,
                                      d_ff=14336, vocab_size=32000),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for f, v in fields.items():
            got = getattr(cfg, f) if f != "num_layers" else cfg.num_layers
            assert got == v, f"{arch}.{f}: {got} != {v}"


def test_long_500k_eligibility():
    from repro.configs import SHAPES, shape_applicable

    eligible = {a for a in list_archs()
                if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert eligible == {"recurrentgemma-2b", "mixtral-8x22b", "mamba2-780m"}
