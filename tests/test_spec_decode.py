"""Speculative decoding: drafters, greedy acceptance, engine equivalence.

The load-bearing claim (the losslessness guarantee): the speculative
engine's greedy output is token-identical to the non-speculative
fused-kernel ContinuousBatchingEngine — and to the FixedSlotEngine golden
— for ANY drafter, at multiple draft lengths, under slot churn, swap
preemption, and with the prefix cache enabled. A drafter can only change
how many tokens a verify step emits, never which tokens.
"""
import jax
import numpy as np
import pytest

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import (ContinuousBatchingEngine, FixedSlotEngine,
                         NgramDrafter, ScriptedDrafter, ServeConfig,
                         greedy_accept)
from repro.serve.spec_decode import resolve_drafter


# ---------------------------------------------------------------------------
# drafters + acceptance rule (pure host logic)
# ---------------------------------------------------------------------------


def test_ngram_drafter_continues_the_latest_match():
    d = NgramDrafter(max_ngram=2)
    hist = np.asarray([7, 1, 2, 9, 1, 2], np.int32)
    # tail bigram (1, 2) last occurred at index 1; its continuation in the
    # history is 9, 1, 2 — exactly the cycle continuing
    np.testing.assert_array_equal(d.propose(hist, 3), [9, 1, 2])
    # a short continuation pads with its own last token
    d1 = NgramDrafter(max_ngram=1)
    np.testing.assert_array_equal(
        d1.propose(np.asarray([4, 9, 4], np.int32), 3), [9, 4, 4])
    # prefers the longest n-gram: with the trigram present, use it
    d3 = NgramDrafter(max_ngram=3)
    hist2 = np.asarray([5, 1, 2, 3, 8, 1, 2, 3], np.int32)
    np.testing.assert_array_equal(d3.propose(hist2, 2), [8, 1])


def test_ngram_drafter_most_recent_occurrence_wins():
    d = NgramDrafter(max_ngram=1)
    hist = np.asarray([4, 10, 4, 20, 4], np.int32)
    # unigram 4 occurs at 0 (-> 10) and 2 (-> 20): most recent wins
    np.testing.assert_array_equal(d.propose(hist, 1), [20])


def test_ngram_drafter_no_match_repeats_last_token():
    d = NgramDrafter()
    np.testing.assert_array_equal(
        d.propose(np.asarray([1, 2, 3], np.int32), 2), [3, 3])
    # single-token history: nothing to match against
    np.testing.assert_array_equal(
        d.propose(np.asarray([9], np.int32), 2), [9, 9])


def test_scripted_drafter_is_deterministic():
    d = ScriptedDrafter(vocab=64, seed=3)
    h = np.asarray([1, 2, 3], np.int32)
    np.testing.assert_array_equal(d.propose(h, 4), d.propose(h, 4))
    assert d.propose(h, 4).dtype == np.int32
    assert (d.propose(h, 4) < 64).all() and (d.propose(h, 4) >= 0).all()


def test_greedy_accept_prefix_rule():
    # all drafts match -> all accepted + bonus
    a, em = greedy_accept([5, 6, 7], [5, 6, 7, 8])
    assert a == 3 and list(em) == [5, 6, 7, 8]
    # first mismatch cuts the prefix; the bonus is the model's own token
    a, em = greedy_accept([5, 9, 7], [5, 6, 7, 8])
    assert a == 1 and list(em) == [5, 6]
    # nothing matches -> still one token per step (plain decode's rate)
    a, em = greedy_accept([9, 9], [5, 6, 7])
    assert a == 0 and list(em) == [5]


def test_resolve_drafter():
    assert isinstance(resolve_drafter("ngram", 128), NgramDrafter)
    d = ScriptedDrafter(8)
    assert resolve_drafter(d, 128) is d
    with pytest.raises(ValueError):
        resolve_drafter("medusa", 128)


# ---------------------------------------------------------------------------
# engine goldens: lossless for any drafter, any draft length
# ---------------------------------------------------------------------------


def _cfg(quantize_kv=True):
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=quantize_kv))


def _churn_reqs(rng):
    return [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(4, 12), (4, 12), (7, 5), (3, 8)]]


@pytest.mark.parametrize("num_draft", [2, 4])
@pytest.mark.parametrize("drafter_name", ["ngram", "scripted"])
def test_spec_decode_token_identical_under_churn_and_preemption(
        num_draft, drafter_name):
    """The acceptance-criteria regression: speculative output equals the
    non-speculative fused-kernel engine AND the fixed-slot golden, per
    request, under slot churn + swap preemption, with the prefix cache
    enabled, at two draft lengths and for a good and an adversarial
    drafter."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    reqs = _churn_reqs(np.random.default_rng(3))
    base = dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                prefix_cache=True)

    plain = ContinuousBatchingEngine(params, cfg, ServeConfig(**base))
    ids_p = [plain.submit(p, m) for p, m in reqs]
    out_p = plain.run()
    assert plain.scheduler.preemptions >= 1, "pool sizing must force a swap"

    drafter = ("ngram" if drafter_name == "ngram"
               else ScriptedDrafter(vocab=128, seed=11))
    spec = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base, spec_decode=True, num_draft_tokens=num_draft,
        drafter=drafter))
    ids_s = [spec.submit(p, m) for p, m in reqs]
    out_s = spec.run()
    assert spec.scheduler.preemptions >= 1, "pool sizing must force a swap"

    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24))
    for (i_s, i_p, (p, m)) in zip(ids_s, ids_p, reqs):
        np.testing.assert_array_equal(out_s[i_s], out_p[i_p])
        np.testing.assert_array_equal(out_s[i_s],
                                      fixed.generate(p[None], m)[0])
    stats = spec.cache_stats()
    assert stats["spec_steps"] > 0
    assert stats["accepted_per_step"] >= 1.0  # every step emits >= 1


@pytest.mark.parametrize("decode_kernel", ["fused", "einsum"])
def test_spec_decode_kernel_paths_agree_with_their_plain_engine(
        decode_kernel):
    """Both attention paths support verify; each must match its own
    non-speculative engine (fused vs fused, einsum vs einsum — across
    paths logits differ at bf16-rounding level, see README)."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    reqs = _churn_reqs(np.random.default_rng(5))
    base = dict(max_seq=24, max_slots=2, page_size=4,
                decode_kernel=decode_kernel)
    plain = ContinuousBatchingEngine(params, cfg, ServeConfig(**base))
    ids_p = [plain.submit(p, m) for p, m in reqs]
    out_p = plain.run()
    spec = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base, spec_decode=True, num_draft_tokens=3))
    ids_s = [spec.submit(p, m) for p, m in reqs]
    out_s = spec.run()
    for i_s, i_p in zip(ids_s, ids_p):
        np.testing.assert_array_equal(out_s[i_s], out_p[i_p])


def test_spec_decode_eos_mid_chunk_stops_exactly():
    """An EOS accepted mid-verify-chunk must end the request at the EOS
    token — accepted drafts beyond it are discarded, exactly as plain
    decode would never have produced them."""
    cfg = _cfg(False)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(1).integers(
        0, 128, (2, 6)).astype(np.int32)
    ref = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24)).generate(
        prompts[:1], 8)[0]
    eos = int(ref[6 + 2])  # the 3rd greedy token becomes the eos id
    stop = 6 + 1 + int(np.argmax(ref[6:] == eos))
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=1, page_size=8, eos_id=eos,
        spec_decode=True, num_draft_tokens=4))
    ids = [eng.submit(prompts[0], 8), eng.submit(prompts[1], 8)]
    out = eng.run()
    first = out[ids[0]]
    assert first[-1] == eos and len(first) == stop
    np.testing.assert_array_equal(first, ref[: len(first)])
    assert len(out[ids[1]]) == 6 + 8


def test_spec_decode_rejects_bad_configs():
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="num_draft_tokens"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=24, spec_decode=True, num_draft_tokens=0))
    # temperature > 0 with spec decode is supported now (rejection-
    # sampling verification) — construction must NOT raise
    ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, spec_decode=True, temperature=0.7))
    with pytest.raises(ValueError, match="drafter"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=24, spec_decode=True, drafter="medusa"))
    rglru_cfg = ModelConfig(
        name="t", family="hybrid", d_model=64, vocab_size=128,
        pattern=(BlockDef("rglru"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, rnn_width=64,
        quant=MXFP8.replace(block_size=16, quantize_acts=False))
    rparams, _ = model.init(jax.random.PRNGKey(0), rglru_cfg)
    with pytest.raises(NotImplementedError, match="attention-only"):
        ContinuousBatchingEngine(rparams, rglru_cfg, ServeConfig(
            max_seq=24, spec_decode=True))


def test_submit_rejects_draft_window_overflow():
    """A request whose worst-case verify window would write past the page
    table is rejected at submission — loudly, not clamped (the clamp
    would silently drop speculated K/V writes mid-verify)."""
    from repro.serve import Scheduler

    s = Scheduler(max_slots=1, num_pages=4, page_size=4, max_seq=16,
                  num_draft_tokens=4)
    # 8 + 4 fits max_seq=16, but + the 4-token draft window it does not
    with pytest.raises(ValueError, match="draft window"):
        s.submit(np.arange(8, dtype=np.int32), 5)
    assert not s.queue
    # the same request is fine without speculation
    s2 = Scheduler(max_slots=1, num_pages=4, page_size=4, max_seq=16)
    s2.submit(np.arange(8, dtype=np.int32), 5)
    # and a smaller request is fine with it
    s.submit(np.arange(4, dtype=np.int32), 5)
    with pytest.raises(ValueError):
        Scheduler(max_slots=1, num_pages=4, page_size=4, max_seq=16,
                  num_draft_tokens=-1)


def test_spec_decode_with_prefix_sharing():
    """Shared-head prompts + speculation: prefix hits fire and outputs
    stay identical to the non-speculative engine."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    head = rng.integers(0, 128, (8,)).astype(np.int32)
    prompts = [np.concatenate([head,
                               rng.integers(0, 128, (3,)).astype(np.int32)])
               for _ in range(3)]
    base = dict(max_seq=28, max_slots=3, page_size=4, prefix_cache=True)
    plain = ContinuousBatchingEngine(params, cfg, ServeConfig(**base))
    ids_p = [plain.submit(p, 8) for p in prompts]
    out_p = plain.run()
    spec = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base, spec_decode=True, num_draft_tokens=3))
    ids_s = [spec.submit(p, 8) for p in prompts]
    out_s = spec.run()
    for i_s, i_p in zip(ids_s, ids_p):
        np.testing.assert_array_equal(out_s[i_s], out_p[i_p])
    assert spec.cache_stats()["prefix_hit_tokens"] > 0


def _page_bytes(eng, pid):
    """Every pool leaf's bytes for physical page ``pid``."""
    from repro.serve import kv_cache as KV

    out = []
    for _, blk, grouped in KV._iter_blocks(eng.cache):
        if not KV._is_pool(blk):
            continue
        for key in sorted(blk):
            leaf = blk[key]
            arr = np.asarray(leaf[:, pid] if grouped else leaf[pid])
            out.append(arr if arr.dtype == np.uint8
                       else arr.astype(np.float32))
    return out


def test_spec_verify_cow_protects_shared_window_page():
    """Pin the page a verify chunk is about to write into (as a
    partial-page prefix hit would): the engine must give the sequence a
    private copy before the speculative write, the pinned page's bytes
    must survive untouched — even though most of the chunk's writes get
    rolled back — and the token stream must not change."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(0).integers(0, 128, (6,)).astype(np.int32)
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24)).generate(
        prompt[None], 8)[0]
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=1, page_size=8, spec_decode=True,
        num_draft_tokens=3, drafter=ScriptedDrafter(vocab=128, seed=5)))
    eng.submit(prompt, 8)
    eng.step()  # admit + first verify chunk
    seq = eng.scheduler.active()[0]
    pinned = seq.pages[seq.pos // 8]
    eng.scheduler.pool.retain([pinned])  # simulate another holder
    before = _page_bytes(eng, pinned)
    eng.step()  # verify chunk would write into the pinned page
    assert eng.scheduler.cow_copies >= 1
    assert pinned not in seq.pages, "repointed to a private copy"
    for a, b in zip(before, _page_bytes(eng, pinned)):
        np.testing.assert_array_equal(a, b)
    while eng.step():
        pass
    eng.scheduler.pool.free([pinned])
    out = np.concatenate([prompt, eng.scheduler.finished[0].generated])
    np.testing.assert_array_equal(out, want)


def test_verify_fused_path_never_materializes_gathered_cache():
    """Structural guarantee for the verify hot path: exactly one
    pallas_call per attention layer and no wide (B, T, ...) gathered
    cache intermediate — the amortization claim depends on the chunk
    sharing one in-kernel page walk, not on a gather feeding an einsum."""
    import jax
    import jax.numpy as jnp

    from repro.core import QuantConfig
    from repro.nn import attention as A

    acfg = A.AttnConfig(d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, decode_kernel="fused")
    quant = QuantConfig(fmt="fp8_e4m3", block_size=16,
                        quantize_kv_cache=True)
    params, _ = A.init(jax.random.PRNGKey(0), acfg)
    pool = A.init_paged_pool(8, 4, acfg, quant)
    x = jnp.zeros((2, 4, 64), jnp.bfloat16)  # Tq == 4
    rows = jnp.zeros((2, 6), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: A.apply_verify_paged(*a, acfg, quant))(
        params, x, pool, rows, pos)
    t = 6 * 4  # padded table rows
    pallas_calls = 0
    for eqn in jaxpr.jaxpr.eqns:
        pallas_calls += eqn.primitive.name == "pallas_call"
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            dt = str(getattr(var.aval, "dtype", ""))
            assert not (len(shape) == 4 and shape[0] == 2
                        and t in shape[1:3]
                        and dt.startswith(("bfloat", "float32"))), (
                f"gathered cache materialized: {eqn.primitive} -> {shape}")
    assert pallas_calls == 1, jaxpr
