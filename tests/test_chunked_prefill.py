"""Chunked paged prefill: fused quantize-into-pages kernel + engine path.

The load-bearing claims, mirroring the issue's acceptance criteria:

  * the fused prefill kernel's page writes are bit-identical to the host
    ``core.quantize`` cache-write path (so chunked prefill, monolithic
    prefill, decode and verify all agree on every cache byte);
  * its attention matches a per-row f32 oracle across formats x blocks x
    chunk geometries (page-straddling chunks, padded final chunks,
    sliding windows), with an exact executed-page audit;
  * the chunked engine is token-identical to the monolithic reference
    engine across chunk sizes x fp8/fp4 x page-straddling prompts x
    prefix hits x speculative decoding;
  * the chunked path's jitted-trace population is O(1) — one trace
    regardless of how many distinct prompt lengths the server sees —
    and its jaxpr never materializes a wide K/V cache;
  * the monolithic fallback's trace caches are LRU-bounded.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP4, MXFP8, quantize
from repro.kernels import mx_attention_prefill_fused
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import (ContinuousBatchingEngine, FixedSlotEngine,
                         Scheduler, ServeConfig)


# ---------------------------------------------------------------------------
# kernel level: quantize-write exactness + attention accuracy + page audit
# ---------------------------------------------------------------------------


def _chunked_prefill_case(fmt, block_size, d, ps, pmax, prompt_len, chunk,
                          kvh=2, g=2, seed=0, window=None):
    """Prefill a prompt chunk-by-chunk through the fused kernel.

    Returns (outs per chunk, visits per chunk, pools, table, wide K/V/Q,
    the host-quantized prompt K/V oracle).
    """
    rng = np.random.default_rng(seed)
    pad = -(-prompt_len // chunk) * chunk
    kw = rng.normal(size=(1, pad, kvh, d)).astype(np.float32)
    vw = rng.normal(size=(1, pad, kvh, d)).astype(np.float32)
    qw = rng.normal(size=(1, kvh, pad, g, d)).astype(np.float32)
    npg = pmax + 3  # spare pages must stay untouched
    fmt_packed = fmt == "fp4_e2m1"
    ed = d // 2 if fmt_packed else d
    edt = jnp.uint8 if fmt_packed else (
        jnp.float8_e5m2 if fmt == "fp8_e5m2" else jnp.float8_e4m3fn)
    pools = [jnp.zeros((npg, ps, kvh, ed), edt),
             jnp.zeros((npg, ps, kvh, d // block_size), jnp.uint8),
             jnp.zeros((npg, ps, kvh, ed), edt),
             jnp.zeros((npg, ps, kvh, d // block_size), jnp.uint8)]
    perm = rng.permutation(npg)
    need = -(-prompt_len // ps)
    table_np = np.full((1, pmax), -1, np.int32)
    table_np[0, :need] = perm[:need]
    table = jnp.asarray(table_np)
    outs, visits = [], []
    for start in range(0, pad, chunk):
        real = min(chunk, prompt_len - start)
        out, pools, vis = mx_attention_prefill_fused(
            jnp.asarray(qw[:, :, start:start + chunk]),
            jnp.asarray(kw[:, start:start + chunk]),
            jnp.asarray(vw[:, start:start + chunk]),
            *pools, table, jnp.asarray([start], jnp.int32),
            jnp.asarray([start + real], jnp.int32), fmt_name=fmt,
            block_size=block_size, window=window, debug_visits=True)
        pools = list(pools)
        outs.append(np.asarray(out))
        visits.append(np.asarray(vis))
    kq = quantize(jnp.asarray(kw[0, :prompt_len]), fmt, block_size)
    vq = quantize(jnp.asarray(vw[0, :prompt_len]), fmt, block_size)
    return outs, visits, pools, table_np, (kw, vw, qw), (kq, vq)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_prefill_kernel_page_bytes_bit_identical_to_host_quantize(
        fmt, block_size):
    """Every full prompt page the kernel writes must hold exactly the
    bytes ``core.quantize`` produces — the single-quantize-path invariant
    that makes chunked and monolithic prefill interchangeable."""
    d, ps, prompt_len, chunk = 64, 8, 40, 16
    _, _, pools, table, _, (kq, vq) = _chunked_prefill_case(
        fmt, block_size, d=d, ps=ps, pmax=8, prompt_len=prompt_len,
        chunk=chunk)
    ke, ks, ve, vs = [np.asarray(p) for p in pools]
    for pg in range(prompt_len // ps):  # fully-real pages
        rows = slice(pg * ps, (pg + 1) * ps)
        for pool_leaf, src in [(ke, kq.elements), (ks, kq.scales),
                               (ve, vq.elements), (vs, vq.scales)]:
            np.testing.assert_array_equal(
                pool_leaf[table[0, pg]].astype(np.float32),
                np.asarray(src).astype(np.float32)[rows])


def test_prefill_kernel_untouched_pages_stay_untouched():
    """Pages outside the prompt's table row (and wholly-padded chunk
    pages) must keep their prior bytes — the aliased output writes only
    the chunk's own live pages."""
    d, ps, prompt_len, chunk = 32, 8, 20, 16  # pad covers rows 20..31
    _, _, pools, table, _, _ = _chunked_prefill_case(
        "fp8_e4m3", 32, d=d, ps=ps, pmax=6, prompt_len=prompt_len,
        chunk=chunk)
    used = set(table[0, : -(-prompt_len // ps)])
    npg = pools[0].shape[0]
    unused = [p for p in range(npg) if p not in used]
    for leaf in pools:
        assert np.all(np.asarray(leaf).astype(np.float32)[unused] == 0)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32])
@pytest.mark.parametrize(
    "prompt_len,chunk",
    [(40, 16),   # padded final chunk, chunk straddles pages
     (32, 16),   # exact chunk multiple
     (17, 16),   # final chunk nearly all padding, partial last page
     (9, 16)],   # single padded chunk, no resident pages at all
    ids=["padded-straddle", "exact", "tail-1", "single-chunk"])
def test_prefill_kernel_attention_matches_per_row_oracle(
        fmt, block_size, prompt_len, chunk):
    """Each real chunk query's output must equal a per-row f32 softmax
    over the quantize-snapped K/V of every position up to its own."""
    d, ps, kvh, g = 64, 8, 2, 2
    outs, visits, _, _, (_, _, qw), (kq, vq) = _chunked_prefill_case(
        fmt, block_size, d=d, ps=ps, pmax=8, prompt_len=prompt_len,
        chunk=chunk)
    kd = np.asarray(kq.dequantize(jnp.float32))  # (T, KVH, D)
    vd = np.asarray(vq.dequantize(jnp.float32))
    for ci, out in enumerate(outs):
        start = ci * chunk
        for ti in range(min(chunk, prompt_len - start)):
            p = start + ti
            for h in range(kvh):
                s = np.einsum("gd,td->gt", qw[0, h, p],
                              kd[: p + 1, h]) * d ** -0.5
                pr = np.exp(s - s.max(-1, keepdims=True))
                pr /= pr.sum(-1, keepdims=True)
                want = np.einsum("gt,td->gd", pr, vd[: p + 1, h])
                np.testing.assert_allclose(out[0, h, ti], want, atol=1e-5,
                                           rtol=0, err_msg=f"chunk {ci} "
                                           f"query {ti} head {h}")
        expect = -(-(start + min(chunk, prompt_len - start)) // ps)
        np.testing.assert_array_equal(visits[ci][:, :, 0], expect)


def test_prefill_kernel_sliding_window_matches_masked_oracle_and_skips():
    """Window masking per chunk row, plus the head-page skip: pages
    wholly below the oldest chunk query's window are neither visited nor
    allowed to influence the output."""
    d, ps, prompt_len, chunk, window = 64, 8, 48, 16, 10
    outs, visits, _, _, (_, _, qw), (kq, vq) = _chunked_prefill_case(
        "fp8_e4m3", 32, d=d, ps=ps, pmax=8, prompt_len=prompt_len,
        chunk=chunk, window=window)
    kd = np.asarray(kq.dequantize(jnp.float32))
    vd = np.asarray(vq.dequantize(jnp.float32))
    for ci, out in enumerate(outs):
        start = ci * chunk
        first = max(0, (start - window + 1) // ps)
        np.testing.assert_array_equal(
            visits[ci][:, :, 0], -(-(start + chunk) // ps) - first)
        for ti in range(chunk):
            p = start + ti
            lo = max(0, p - window + 1)
            for h in range(2):
                s = np.einsum("gd,td->gt", qw[0, h, p],
                              kd[lo: p + 1, h]) * d ** -0.5
                pr = np.exp(s - s.max(-1, keepdims=True))
                pr /= pr.sum(-1, keepdims=True)
                want = np.einsum("gt,td->gd", pr, vd[lo: p + 1, h])
                np.testing.assert_allclose(out[0, h, ti], want, atol=1e-5,
                                           rtol=0)


def test_prefill_kernel_rejects_unaligned_chunk():
    with pytest.raises(ValueError, match="whole number of pages"):
        _chunked_prefill_case("fp8_e4m3", 32, d=32, ps=8, pmax=4,
                              prompt_len=12, chunk=12)


# ---------------------------------------------------------------------------
# engine level: chunked vs monolithic token identity
# ---------------------------------------------------------------------------


def _cfg(quant, quantize_kv=True, block_size=16, window=None):
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn", window=window),), num_groups=1,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        quant=quant.replace(block_size=block_size, quantize_acts=False,
                            quantize_kv_cache=quantize_kv))


def _run_pair(cfg, reqs, base_kw, chunked_kw=None, monolithic_kw=None):
    """Serve the same requests through a chunked and a monolithic engine;
    return (chunked outputs, monolithic outputs, engines)."""
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    ch = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base_kw, prefill_mode="chunked", **(chunked_kw or {})))
    mono = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base_kw, prefill_mode="monolithic", **(monolithic_kw or {})))
    ids_c = [ch.submit(p, m) for p, m in reqs]
    out_c = ch.run()
    ids_m = [mono.submit(p, m) for p, m in reqs]
    out_m = mono.run()
    return ([out_c[i] for i in ids_c], [out_m[i] for i in ids_m], ch, mono)


@pytest.mark.parametrize("quant", [MXFP8, MXFP4], ids=["fp8", "fp4"])
@pytest.mark.parametrize("chunk", [8, 16])
@pytest.mark.parametrize("decode_kernel", ["fused", "einsum"])
def test_chunked_matches_monolithic_matrix(quant, chunk, decode_kernel):
    """The core identity matrix: ragged, page-straddling prompt lengths
    (incl. one longer than the chunk and one not a page multiple) must
    generate token-identically through chunked and monolithic prefill,
    on both attention kernel paths."""
    cfg = _cfg(quant)
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(3, 6), (8, 5), (13, 4), (21, 6)]]
    base = dict(max_seq=40, max_slots=2, page_size=8,
                decode_kernel=decode_kernel)
    out_c, out_m, ch, mono = _run_pair(
        cfg, reqs, base, chunked_kw=dict(prefill_chunk=chunk))
    # every request must have streamed through chunks (the random prompts
    # share no page-aligned head, so prefix hits cannot shrink the count)
    assert ch.prefill_chunks == sum(-(-len(p) // chunk) for p, _ in reqs)
    for c, m in zip(out_c, out_m):
        np.testing.assert_array_equal(c, m)


@pytest.mark.parametrize("decode_kernel", ["fused", "einsum"])
def test_padded_final_chunk_past_table_extent(decode_kernel):
    """Regression: a final chunk whose padding reaches past the page
    table's extent while the sequence owns its full table row. The
    padding positions' page-table columns must *drop*, not clamp into
    the last column — a clamped write scattered garbage K/V over the
    last page's live rows (real token K/V), diverging the einsum chunked
    path from the monolithic oracle."""
    cfg = _cfg(MXFP8)
    rng = np.random.default_rng(29)
    # prompt 33 with ps 8 owns all 5 table columns of max_seq 40; the
    # final 32-chunk covers rows 32..63, padding far past the table
    reqs = [(rng.integers(0, 128, (33,)).astype(np.int32), 5)]
    base = dict(max_seq=40, max_slots=1, page_size=8,
                decode_kernel=decode_kernel)
    out_c, out_m, _, _ = _run_pair(
        cfg, reqs, base, chunked_kw=dict(prefill_chunk=32))
    np.testing.assert_array_equal(out_c[0], out_m[0])


def test_chunked_matches_fixed_slot_reference():
    """Absolute golden: the chunked default engine vs the fixed-slot
    reference engine (the repo's root numerics contract)."""
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(0).integers(
        0, 128, (3, 9)).astype(np.int32)
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24)).generate(
        prompts, 6)
    got = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=3, page_size=4,
        prefill_chunk=8)).generate(prompts, 6)
    np.testing.assert_array_equal(got, want)


def test_chunked_prefix_cache_hits_token_identical():
    """Shared-head workload: the second wave of requests takes
    page-aligned prefix hits and chunked prefill starts at the cached
    offset (the tail-prefill-as-chunks-at-an-offset collapse). Outputs
    and hit accounting must match the monolithic engine's."""
    cfg = _cfg(MXFP8)
    rng = np.random.default_rng(11)
    head = rng.integers(0, 128, (16,)).astype(np.int32)
    reqs = [(np.concatenate([head, rng.integers(0, 128, (t,)).astype(
        np.int32)]), 5) for t in (3, 7, 2, 9)]
    base = dict(max_seq=48, max_slots=2, page_size=8)
    out_c, out_m, ch, mono = _run_pair(
        cfg, reqs, base, chunked_kw=dict(prefill_chunk=8))
    for c, m in zip(out_c, out_m):
        np.testing.assert_array_equal(c, m)
    sc, sm = ch.cache_stats(), mono.cache_stats()
    assert sc["prefix_hit_tokens"] == sm["prefix_hit_tokens"] > 0
    assert sc["prefill_tokens_computed"] == sm["prefill_tokens_computed"]
    assert sc["prefill_traces"] == 0 and sm["prefill_traces"] > 0


def test_chunked_with_spec_decode_token_identical():
    """Chunked admission + speculative verify in one engine must still
    reproduce the plain monolithic engine's streams exactly."""
    cfg = _cfg(MXFP8)
    rng = np.random.default_rng(13)
    motif = rng.integers(0, 128, (5,)).astype(np.int32)
    reqs = [(np.tile(motif, 4)[: s], 8) for s in (11, 17)]
    base = dict(max_seq=48, max_slots=2, page_size=8)
    out_c, out_m, ch, _ = _run_pair(
        cfg, reqs, base,
        chunked_kw=dict(prefill_chunk=16, spec_decode=True,
                        num_draft_tokens=3))
    assert ch.spec_steps > 0
    for c, m in zip(out_c, out_m):
        np.testing.assert_array_equal(c, m)


def test_chunked_survives_mid_prefill_preemption():
    """A pool tight enough that decoders must preempt sequences (possibly
    mid-prefill — the swap tuple carries the chunk resume point): the
    chunked engine under churn must match the monolithic engine on the
    default fused kernel, and the fixed-slot reference bit-for-bit on the
    einsum control (the fused-vs-fixed comparison sits in the documented
    cross-kernel rounding band — see README §Serving — so the einsum
    pairing is the exact one)."""
    cfg = _cfg(MXFP8)
    rng = np.random.default_rng(17)
    reqs = [(rng.integers(0, 128, (4,)).astype(np.int32), 14),
            (rng.integers(0, 128, (4,)).astype(np.int32), 14),
            (rng.integers(0, 128, (7,)).astype(np.int32), 5),
            (rng.integers(0, 128, (3,)).astype(np.int32), 8)]
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    base = dict(max_seq=20, max_slots=2, page_size=4, num_pages=7)
    out_c, out_m, ch, _ = _run_pair(cfg, reqs, base,
                                    chunked_kw=dict(prefill_chunk=4))
    assert ch.scheduler.preemptions >= 1, "pool sizing must force a swap"
    for c, m in zip(out_c, out_m):
        np.testing.assert_array_equal(c, m)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base, prefill_chunk=4, decode_kernel="einsum"))
    ids = [eng.submit(p, m) for p, m in reqs]
    out = eng.run()
    assert eng.scheduler.preemptions >= 1
    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24))
    for rid, (p, m) in zip(ids, reqs):
        np.testing.assert_array_equal(out[rid], fixed.generate(p[None], m)[0])


def test_chunked_requires_page_aligned_chunk():
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="multiple of"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=24, page_size=8, prefill_chunk=12))
    with pytest.raises(ValueError, match="prefill_mode"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=24, prefill_mode="streamed"))


def test_chunked_falls_back_to_monolithic_for_recurrent_mixers():
    cfg = ModelConfig(
        name="t", family="hybrid", d_model=64, vocab_size=128,
        pattern=(BlockDef("rglru"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, rnn_width=64,
        quant=MXFP8.replace(block_size=16, quantize_acts=False))
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=16, max_slots=1, page_size=4))
    assert not eng.chunked
    prompt = np.arange(5, dtype=np.int32)
    out = eng.generate(prompt[None], 4)
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=16)).generate(
        prompt[None], 4)
    np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# O(1) traces + LRU bound + structural no-wide-cache guarantee
# ---------------------------------------------------------------------------


def test_chunked_trace_population_is_constant():
    """Many distinct prompt lengths (and prefix-hit geometries) through a
    chunked engine: the jitted-entry count must not grow — one compiled
    prefill trace serves them all."""
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=48, max_slots=2, page_size=8, prefill_chunk=16))
    rng = np.random.default_rng(19)
    head = rng.integers(0, 128, (8,)).astype(np.int32)
    for s in (1, 2, 3, 5, 9, 14, 17, 23, 29):
        prompt = np.concatenate(
            [head, rng.integers(0, 128, (s,)).astype(np.int32)])
        eng.submit(prompt, 2)
    eng.run()
    # the ragged default routes chunks through the single ragged trace and
    # never compiles the split chunk trace; the split oracle compiles one
    assert eng._prefill_chunk._cache_size() == (0 if eng.ragged else 1)
    assert len(eng._prefill_fns) == 0 and len(eng._prefill_tail_fns) == 0
    assert eng.cache_stats()["prefill_traces"] == 0


def test_monolithic_trace_caches_are_lru_bounded():
    """The fallback path's per-length trace caches must respect the LRU
    cap while still serving every request correctly."""
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=48, max_slots=1, page_size=8, prefill_mode="monolithic",
        prefill_trace_cache=3, prefix_cache=False))
    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=48))
    rng = np.random.default_rng(23)
    for s in (3, 5, 7, 9, 11, 13):
        prompt = rng.integers(0, 128, (s,)).astype(np.int32)
        rid = eng.submit(prompt, 3)
        out = eng.run()[rid]
        np.testing.assert_array_equal(out, fixed.generate(prompt[None], 3)[0])
        assert len(eng._prefill_fns) <= 3
    assert eng.cache_stats()["prefill_traces"] <= 3


def test_chunked_path_never_materializes_wide_kv():
    """Structural acceptance criterion: the chunked prefill step's jaxpr
    must contain no wide (bf16/f32) K/V array covering the whole padded
    table — per-chunk work may only touch the chunk itself plus compact
    pages. The einsum reference path is the control: it *does* gather
    the wide table, proving the test can detect the violation."""
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    ps, pmax, chunk = 8, 12, 16
    # t_table = 96 collides with no model dimension (d_model 64, d_ff/vocab
    # 128, chunk 16), so any axis of that extent IS the padded table
    t_table = ps * pmax
    cache = model.init_paged_cache(cfg, num_slots=1,
                                   num_pages=pmax, page_size=ps)

    def count_wide(decode_kernel):
        cfg_k = cfg.replace(decode_kernel=decode_kernel)
        jaxpr = jax.make_jaxpr(
            lambda p, c, toks, rows, pos, nv, idx: model.prefill_chunk_paged(
                p, cfg_k, c, toks, rows, pos, nv, idx))(
            params, cache, jnp.zeros((1, chunk), jnp.int32),
            jnp.zeros((1, pmax), jnp.int32), jnp.zeros((1,), jnp.int32),
            jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32))
        wide = 0

        def scan(jx):
            nonlocal wide
            for eqn in jx.eqns:
                for var in eqn.outvars:
                    aval = getattr(var, "aval", None)
                    shape = getattr(aval, "shape", ())
                    if (len(shape) >= 3 and t_table in shape
                            and aval.dtype in (jnp.bfloat16, jnp.float32)):
                        wide += 1
                for sub in eqn.params.values():
                    if hasattr(sub, "jaxpr"):
                        scan(sub.jaxpr if hasattr(sub.jaxpr, "eqns")
                             else sub)
        scan(jaxpr.jaxpr)
        return wide

    assert count_wide("einsum") > 0, \
        "control failed: the einsum path should gather a wide table"
    assert count_wide("fused") == 0


# ---------------------------------------------------------------------------
# deferral bound + batched same-shape chunk dispatch
# ---------------------------------------------------------------------------


def test_deferral_bound_falls_back_to_independent_prefill():
    """Regression (deferred-admission starvation): a follower whose
    prompt shares an unregistered page-aligned head with a prefilling
    leader defers — but a leader that never finishes (budget-starved or
    preempted mid-prefill) must not starve it forever. After
    ``max_deferrals`` attempts the follower admits independently."""
    s = Scheduler(max_slots=2, num_pages=16, page_size=4, max_seq=32,
                  prefix_cache=True, prefill_chunk=4, max_deferrals=3)
    head = np.arange(12, dtype=np.int32)
    s.submit(head, 4)
    leader = s.admit_next()
    assert leader is not None and leader.prefill_pos == 0
    # follower shares the (not yet registered) 12-token head
    s.submit(np.concatenate([head, np.asarray([99, 98, 97, 96],
                                              np.int32)]), 4)
    for _ in range(s.max_deferrals):  # leader never gets a chunk: stalled
        assert s.admit_next() is None
    assert s.deferred_admissions == 1  # the request, counted once
    assert s.deferral_fallbacks == 1  # bound hit
    follower = s.admit_next()
    assert follower is not None
    assert follower.cached_tokens == 0  # independent: no tree hit taken
    # its private pages really are distinct from the leader's
    assert not set(follower.pages) & set(leader.pages)
    assert s.deferral_fallbacks == 1


def test_deferral_bound_survives_preempted_mid_prefill_leader():
    """The starvation loop the bound exists for: a leader preempted
    mid-prefill re-enters the queue ahead of the follower (FCFS), gets
    readmitted still-prefilling, and the follower re-defers against it
    every cycle. The per-request defer count persists across cycles, so
    the follower eventually breaks out and admits independently."""
    s = Scheduler(max_slots=2, num_pages=16, page_size=4, max_seq=32,
                  prefix_cache=True, prefill_chunk=4, max_deferrals=2)
    head = np.arange(8, dtype=np.int32)
    s.submit(np.concatenate([head, np.asarray([5, 6, 7, 8], np.int32)]), 4)
    leader = s.admit_next()
    assert leader.prefill_pos == 0
    s.submit(np.concatenate([head, np.asarray([9, 9], np.int32)]), 4)
    assert s.admit_next() is None  # defer 1 against the live leader
    # leader swapped out mid-prefill; its swap tuple carries prefill_pos
    s.preempt(leader, snapshot=None)
    leader2 = s.admit_next()  # FCFS: the leader re-enters first...
    assert leader2.req.id == leader.req.id
    assert leader2.prefill_pos == 0  # ...still mid-prefill
    assert s.admit_next() is None  # defer 2: bound hit
    assert s.deferral_fallbacks == 1
    follower = s.admit_next()  # breaks the cycle: independent prefill
    assert follower is not None and follower.cached_tokens == 0


def test_same_shape_chunk_dispatch_batches_across_sequences():
    """Regression (single-sequence chunk dispatch): with a prefill token
    budget spanning several chunks per step, same-shape chunks from
    *distinct* prefilling sequences must ride one batched kernel
    dispatch — fewer dispatches than chunks, still one compiled trace —
    and stay token-identical to the monolithic engine."""
    cfg = _cfg(MXFP8)
    rng = np.random.default_rng(31)
    reqs = [(rng.integers(0, 128, (16,)).astype(np.int32), 4)
            for _ in range(4)]
    base = dict(max_seq=32, max_slots=4, page_size=8)
    out_c, out_m, ch, _ = _run_pair(
        cfg, reqs, base,
        chunked_kw=dict(prefill_chunk=8, prefill_token_budget=32))
    for c, m in zip(out_c, out_m):
        np.testing.assert_array_equal(c, m)
    assert ch.prefill_chunks == 8  # 4 prompts x 2 chunks each
    assert ch.prefill_dispatches < ch.prefill_chunks
    assert ch.prefill_dispatches == 2  # all 4 seqs batched per step
    # batching must not fracture the O(1)-trace guarantee: one trace per
    # distinct batch width at most
    assert ch._prefill_chunk._cache_size() <= 2


def test_cancel_deferred_follower_holds_no_pages():
    """Regression (deferred-cancel accounting): a follower deferring
    behind a mid-prefill leader holds NO pages while queued — its
    tentative prefix hit is released at deferral time. Cancelling it in
    that state must be a pure dequeue: no page frees (nothing to free,
    a double free would corrupt refcounts shared with the leader) and
    the pool must drain to exactly the prefix tree's holdings."""
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=48, max_slots=2, page_size=4, prefill_chunk=4,
        prefix_cache=True, num_pages=24))
    head = np.arange(1, 25, dtype=np.int32)  # 6 chunks: a slow leader
    leader = eng.submit(head, 4)
    eng.step()  # leader admitted, one chunk in: mid-prefill
    followers = [eng.submit(
        np.concatenate([head, np.asarray([90 + i], np.int32)]), 4)
        for i in range(3)]
    eng.step()  # followers defer against the unregistered shared head
    sched = eng.scheduler
    assert sched.deferred_admissions >= 1
    assert eng.cancel(followers[0])  # cancelled while deferred+queued
    assert eng.cancel(followers[1])
    out = eng.run()
    assert followers[0] not in out and followers[1] not in out
    # survivors complete, the late follower via a real prefix hit
    assert out[leader].shape[0] == 24 + 4
    assert out[followers[2]].shape[0] == 25 + 4
    assert sched.cancellations == 2
    assert sched.pool.pages_in_use == len(sched.prefix.pages_held)


def test_cancel_churn_with_deferrals_property():
    """Random cancels over a workload built to defer constantly (every
    request shares one long unregistered head): whatever mix of states
    the victims are in — queued-deferred, mid-prefill, decoding — pages
    drain to the prefix tree's count and every survivor finishes."""
    cfg = _cfg(MXFP8)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(17)
    for mode in ("ragged", "split"):
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=48, max_slots=2, page_size=4, prefill_chunk=4,
            prefix_cache=True, num_pages=20, step_mode=mode))
        head = np.arange(1, 21, dtype=np.int32)
        ids = [eng.submit(
            np.concatenate([head[:12 + 4 * (i % 3)],
                            rng.integers(0, 128, (i % 4,)).astype(np.int32)]),
            int(rng.integers(3, 7))) for i in range(8)]
        cancelled, steps = set(), 0
        while eng.scheduler.has_work and steps < 1000:
            eng.step()
            steps += 1
            if rng.random() < 0.35:
                victim = int(rng.choice(ids))
                if victim not in cancelled and eng.cancel(victim):
                    cancelled.add(victim)
        out = eng.run()
        sched = eng.scheduler
        assert steps < 1000, "churn did not drain"
        assert sched.cancellations == len(cancelled)
        assert set(out) == set(ids) - cancelled
        assert sched.deferred_admissions >= 1, \
            "workload failed to exercise the deferral path"
        assert all(s is None for s in sched.slots)
        assert sched.pool.pages_in_use == len(sched.prefix.pages_held)
