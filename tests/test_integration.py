"""End-to-end integration: launcher training with checkpoints + resume,
loss decrease on learnable data, serving engine generation."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP8
from repro.data import DataConfig, SyntheticLMDataset
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import ServeConfig, ServeEngine
from repro.train import OptimConfig, checkpoint, init_state, make_train_step


def _cfg():
    return ModelConfig(
        name="it", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=2, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16))


def test_mx_training_decreases_loss():
    cfg = _cfg()
    state, _ = init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=1e-2, warmup_steps=2, total_steps=30)))
    ds = SyntheticLMDataset(DataConfig(vocab_size=128, seq_len=32,
                                       global_batch=8))
    losses = []
    for i in range(15):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses


@pytest.mark.slow
def test_launcher_trains_and_resumes():
    from repro.launch import train as tl

    with tempfile.TemporaryDirectory() as d:
        args = ["--arch", "gemma2-2b", "--reduced", "--steps", "6",
                "--seq-len", "16", "--global-batch", "4",
                "--ckpt-dir", d, "--ckpt-every", "2"]
        final = tl.main(args)
        assert final == 6
        assert checkpoint.latest_step(d) == 6
        # resume: nothing left to do, returns immediately at target step
        final2 = tl.main(args)
        assert final2 == 6


def test_serve_engine_greedy_deterministic():
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, ServeConfig(max_seq=48))
    prompts = np.random.default_rng(0).integers(0, 128, (2, 8)).astype(np.int32)
    out1 = eng.generate(prompts, 8)
    out2 = eng.generate(prompts, 8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 16)
    # prompts preserved
    np.testing.assert_array_equal(out1[:, :8], prompts)


def test_serve_engine_mx_weight_compression_close_to_wide():
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    wide = ServeEngine(params, cfg.replace(quant=cfg.quant.replace(
        enabled=False)), ServeConfig(max_seq=32))
    mx = ServeEngine(params, cfg.replace(quant=cfg.quant.replace(
        quantize_acts=False)), ServeConfig(max_seq=32))
    prompts = np.random.default_rng(1).integers(0, 128, (2, 8)).astype(np.int32)
    ow = wide.generate(prompts, 4)
    om = mx.generate(prompts, 4)
    # greedy decode may diverge under quantization; the first generated
    # token comes from a single forward and should usually agree
    assert ow.shape == om.shape
