"""Prefix-cache subsystem: ref-counted pages, radix tree, COW, goldens.

The load-bearing claims:
  * ``PagePool`` ref-counting never double-frees, never leaks, and
    ``peak_in_use`` is monotone (property-tested under the hypothesis
    shim);
  * the radix tree's references stay consistent with live page tables
    through arbitrary acquire/insert/release/evict interleavings;
  * with prefix sharing enabled, greedy outputs for prompts sharing a
    page-aligned head are token-identical to both the sharing-disabled
    engine and the fixed-slot reference, while steady-state pages_in_use
    is strictly lower — including under preemption and LRU eviction;
  * a sequence never writes a page another holder references
    (copy-on-write).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import (ContinuousBatchingEngine, FixedSlotEngine, PagePool,
                         PrefixCache, Scheduler, ServeConfig)
from repro.serve import kv_cache as KV


# ---------------------------------------------------------------------------
# PagePool ref-counting invariants (property-tested)
# ---------------------------------------------------------------------------


def test_page_pool_refcount_basics():
    pool = PagePool(4)
    (a,) = pool.alloc(1)
    assert pool.ref(a) == 1
    pool.retain([a])
    assert pool.ref(a) == 2 and pool.pages_in_use == 1
    pool.free([a])
    assert pool.ref(a) == 1 and pool.pages_in_use == 1  # still held
    pool.free([a])
    assert pool.ref(a) == 0 and pool.pages_in_use == 0  # last ref frees
    with pytest.raises(ValueError):
        pool.free([a])  # double free
    with pytest.raises(ValueError):
        pool.retain([a])  # retain of a free page
    with pytest.raises(ValueError):
        pool.retain([99])


@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_page_pool_property_no_leak_no_double_free(seed):
    """Random alloc/retain/free interleavings against a model refcount
    dict: the pool and the model always agree, frees of dead pages always
    raise, and peak_in_use is monotone."""
    rng = np.random.default_rng(seed)
    pool = PagePool(8)
    refs = {}  # pid -> model refcount
    peak = 0
    for _ in range(200):
        op = rng.integers(3)
        if op == 0:  # alloc
            n = int(rng.integers(0, 4))
            ids = pool.alloc(n)
            if sum(1 for r in refs.values() if r > 0) + n <= 8:
                assert ids is not None and len(ids) == n
                for pid in ids:
                    assert refs.get(pid, 0) == 0
                    refs[pid] = 1
            else:
                assert ids is None
        elif op == 1:  # retain a live page
            live = [p for p, r in refs.items() if r > 0]
            if live:
                pid = int(rng.choice(live))
                pool.retain([pid])
                refs[pid] += 1
        else:  # free one reference (sometimes of a dead page: must raise)
            live = [p for p, r in refs.items() if r > 0]
            if live and rng.random() < 0.9:
                pid = int(rng.choice(live))
                pool.free([pid])
                refs[pid] -= 1
            else:
                dead = [p for p in range(8) if refs.get(p, 0) == 0]
                if dead:
                    with pytest.raises(ValueError):
                        pool.free([int(rng.choice(dead))])
        in_use = sum(1 for r in refs.values() if r > 0)
        assert pool.pages_in_use == in_use
        assert pool.free_pages == 8 - in_use
        for pid in range(8):
            assert pool.ref(pid) == refs.get(pid, 0)
        assert pool.peak_in_use >= peak  # monotone
        peak = pool.peak_in_use
    # drain: every page must come back
    for pid, r in refs.items():
        for _ in range(r):
            pool.free([pid])
    assert pool.free_pages == 8 and pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# radix tree: lookup / insert / evict
# ---------------------------------------------------------------------------


def _tree(num_pages=16, ps=4):
    pool = PagePool(num_pages)
    return PrefixCache(pool, ps), pool


def test_prefix_tree_insert_lookup_roundtrip():
    tree, pool = _tree()
    prompt = np.arange(10, dtype=np.int32)  # 2 full pages + tail of 2
    pages = pool.alloc(3)
    assert tree.insert(prompt, pages) == 2  # only full pages enter
    assert pool.ref(pages[0]) == 2 and pool.ref(pages[1]) == 2
    assert pool.ref(pages[2]) == 1  # partial tail page stays private
    # same head, longer prompt: hits both pages, retains them
    hit, cached = tree.acquire(np.arange(16, dtype=np.int32))
    assert hit == pages[:2] and cached == 8
    assert pool.ref(pages[0]) == 3
    # divergent second page: only the first matches
    other = np.concatenate([np.arange(4), [99, 99, 99, 99], [1, 2]])
    hit2, cached2 = tree.acquire(other.astype(np.int32))
    assert hit2 == pages[:1] and cached2 == 4
    # the hit cap: a fully cached prompt still leaves >= 1 token to prefill
    hit3, cached3 = tree.acquire(np.arange(8, dtype=np.int32))
    assert cached3 == 4  # (8-1)//4 = 1 page, not 2
    # stats are reported at admission time (acquire itself is stat-free:
    # failed admissions retry every step and must not inflate hit rates)
    assert tree.hits == 0 and tree.lookups == 0
    for cached in (cached, cached2, cached3):
        tree.record_lookup(cached)
    assert tree.hits == 3 and tree.lookups == 3 and tree.hit_tokens == 16


def test_prefix_tree_eviction_lru_and_pinning():
    tree, pool = _tree(num_pages=8, ps=4)
    p_a = pool.alloc(2)
    tree.insert(np.arange(8, dtype=np.int32), p_a)  # chain a: 2 nodes
    p_b = pool.alloc(1)
    tree.insert(np.asarray([50, 51, 52, 53], np.int32), p_b)  # leaf b
    for pid in p_a + p_b:
        pool.free([pid])  # sequences done: only the tree holds the pages
    # chain a's leaf is older than b; eviction takes LRU leaves first
    assert tree.evict(1) == 1
    assert pool.ref(p_a[1]) == 0  # a's leaf went first (LRU)
    # pinned pages are not evictable: acquire b, then ask for everything
    hit, _ = tree.acquire(np.asarray([50, 51, 52, 53, 0], np.int32))
    assert hit == p_b
    assert tree.evict(10) == 1  # only a's root falls; b is pinned
    assert pool.ref(p_b[0]) == 2 and tree.num_nodes == 1
    pool.free(p_b)  # release the acquisition
    assert tree.evict(1) == 1 and tree.num_nodes == 0
    assert pool.pages_in_use == 0


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prefix_tree_property_refcounts_match_live_tables(seed):
    """Random admit (acquire+alloc+insert) / finish (free) / evict churn:
    every page's refcount equals (tree holds it) + (# live tables holding
    it), and draining everything empties the pool."""
    rng = np.random.default_rng(seed)
    ps, num_pages = 4, 32
    pool = PagePool(num_pages)
    tree = PrefixCache(pool, ps)
    vocab = 3  # tiny vocab -> prompts collide -> real sharing
    live = []  # page tables of "running" sequences
    for _ in range(60):
        op = rng.integers(3)
        if op == 0:  # admit
            n_tok = int(rng.integers(1, 13))
            prompt = rng.integers(0, vocab, size=(n_tok,)).astype(np.int32)
            hit, cached = tree.acquire(prompt)
            need = -(-n_tok // ps) - len(hit)
            if not pool.can_alloc(need):
                tree.evict(need - pool.free_pages)
            ids = pool.alloc(need)
            if ids is None:
                if hit:
                    pool.free(hit)
                continue
            table = hit + ids
            tree.insert(prompt, table)
            live.append(table)
        elif op == 1 and live:  # finish
            table = live.pop(int(rng.integers(len(live))))
            pool.free(table)
        else:  # pressure
            tree.evict(int(rng.integers(1, 4)))
        held = tree.pages_held
        for pid in range(num_pages):
            want = held.count(pid) + sum(t.count(pid) for t in live)
            assert pool.ref(pid) == want, (pid, want, pool.ref(pid))
    for table in live:
        pool.free(table)
    tree.evict(num_pages)
    assert pool.pages_in_use == 0 and tree.num_nodes == 0


# ---------------------------------------------------------------------------
# copy-on-write
# ---------------------------------------------------------------------------


def _cfg(quantize_kv=True, **kw):
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=quantize_kv), **kw)


def test_copy_page_copies_every_pool_layer():
    cache = model.init_paged_cache(_cfg(), num_slots=1, num_pages=4,
                                   page_size=4)
    fill = lambda leaf: jnp.arange(leaf.size, dtype=jnp.float32).reshape(
        leaf.shape).astype(leaf.dtype)
    cache = jax.tree_util.tree_map(fill, cache)
    out = KV.copy_page(cache, jnp.asarray(1, jnp.int32),
                       jnp.asarray(3, jnp.int32))
    for _, blk, grouped in KV._iter_blocks(out):
        assert KV._is_pool(blk)
        for leaf in blk.values():
            src = leaf[:, 1] if grouped else leaf[1]
            dst = leaf[:, 3] if grouped else leaf[3]
            np.testing.assert_array_equal(np.asarray(src), np.asarray(dst))


def test_engine_cow_never_writes_a_shared_page():
    """Pin the page a sequence is about to write (as a partial-page hit
    would); the engine must copy it to a fresh page first, and the token
    stream must not change."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(0).integers(0, 128, (6,)).astype(np.int32)
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24)).generate(
        prompt[None], 8)[0]
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=1, page_size=8))
    eng.submit(prompt, 8)
    eng.step()  # admit + first decode
    seq = eng.scheduler.active()[0]
    wp = seq.pos // 8
    pinned = seq.pages[wp]
    eng.scheduler.pool.retain([pinned])  # simulate another holder
    eng.step()
    assert eng.scheduler.cow_copies == 1
    assert seq.pages[wp] != pinned  # repointed to a private copy
    assert eng.scheduler.pool.ref(pinned) == 1  # our pin is the only ref
    while eng.step():
        pass
    eng.scheduler.pool.free([pinned])
    out = np.concatenate([prompt, eng.scheduler.finished[0].generated])
    np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# scheduler: skip-ahead admission + validation
# ---------------------------------------------------------------------------


def test_skip_ahead_admits_a_fitting_request_behind_a_stuck_head():
    s = Scheduler(max_slots=2, num_pages=4, page_size=4, max_seq=16,
                  admit_window=4)
    big = s.submit(np.arange(12, dtype=np.int32), 4)  # needs 3 pages
    a = s.admit_next()
    assert a.req.id == big
    # new head (another big one) can't fit: only 1 page left
    s.submit(np.arange(12, dtype=np.int32), 4)
    s.submit(np.arange(4, dtype=np.int32), 4)  # needs 1 page: fits
    b = s.admit_next()
    assert b is not None and len(b.req.prompt) == 4  # skipped the stuck head
    assert s.skipped_admissions == 1
    assert s.queue[0].prompt.shape == (12,)  # head-of-line order otherwise


def test_skip_ahead_window_is_bounded():
    s = Scheduler(max_slots=2, num_pages=4, page_size=4, max_seq=16,
                  admit_window=2)
    s.submit(np.arange(12, dtype=np.int32), 4)
    assert s.admit_next().req.id == 0
    s.submit(np.arange(12, dtype=np.int32), 4)  # stuck head
    s.submit(np.arange(12, dtype=np.int32), 4)  # also stuck (in window)
    s.submit(np.arange(4, dtype=np.int32), 4)  # would fit, outside window
    assert s.admit_next() is None


def test_submit_rejects_bad_input_loudly():
    s = Scheduler(max_slots=1, num_pages=4, page_size=4, max_seq=16)
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit(np.zeros(0, np.int32), 4)
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        s.submit(np.arange(4, dtype=np.int32), 0)
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        s.submit(np.arange(4, dtype=np.int32), -3)
    with pytest.raises(ValueError, match="must be an int"):
        s.submit(np.arange(4, dtype=np.int32), 2.5)
    with pytest.raises(ValueError, match="integer token ids"):
        s.submit(np.zeros(4, np.float32), 4)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        s.submit(np.arange(14, dtype=np.int32), 4)
    assert not s.queue  # nothing slipped through


# ---------------------------------------------------------------------------
# engine goldens: sharing on == sharing off == fixed-slot
# ---------------------------------------------------------------------------


def _shared_head_prompts(n, head_len, tail_len, rng):
    head = rng.integers(0, 128, (head_len,)).astype(np.int32)
    return np.stack([np.concatenate(
        [head, rng.integers(0, 128, (tail_len,)).astype(np.int32)])
        for _ in range(n)])


@pytest.mark.parametrize("quantize_kv", [False, True])
def test_prefix_sharing_token_identical_and_fewer_pages(quantize_kv):
    cfg = _cfg(quantize_kv)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompts = _shared_head_prompts(3, 16, 4, np.random.default_rng(1))
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=32)).generate(
        prompts, 6)
    outs, peaks = {}, {}
    for on in (False, True):
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=32, max_slots=3, page_size=8, prefix_cache=on))
        outs[on] = eng.generate(prompts, 6)
        peaks[on] = eng.cache_stats()["peak_pages"]
        assert (eng.cache_stats().get("prefix_hit_tokens", 0) > 0) == on
    np.testing.assert_array_equal(outs[False], want)
    np.testing.assert_array_equal(outs[True], want)
    assert peaks[True] < peaks[False], peaks


def test_prefix_sharing_with_preemption_and_eviction():
    """Tight pool: sharing + swap preemption + LRU eviction all fire, and
    every request still matches its own fixed-slot generation exactly.
    Shared pages must never be extracted into a snapshot."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = _shared_head_prompts(6, 32, 8, rng)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=52, max_slots=3, page_size=8, num_pages=10,
        prefix_cache=True))
    ids = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    stats = eng.cache_stats()
    assert stats["preemptions"] >= 1, "pool sizing must force a swap"
    assert stats["prefix_evictions"] >= 1, "pool sizing must force eviction"
    assert stats["prefix_hit_tokens"] > 0
    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=52))
    for rid, p in zip(ids, prompts):
        np.testing.assert_array_equal(out[rid],
                                      fixed.generate(p[None], 10)[0])


def test_lone_sequence_reclaims_swapped_shared_refs():
    """Regression: pages pinned by tree refs + a swapped-out request's
    retained shared refs must not starve a lone active sequence. The
    engine extracts the shared pages into the swap snapshot, drops the
    references, and the run completes — token-identically."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, (5,)).astype(np.int32)
               for _ in range(2)]
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=14, max_slots=2, page_size=4, num_pages=4,
        prefix_cache=True))
    ids = [eng.submit(p, 9) for p in prompts]
    out = eng.run()  # raised "page pool exhausted" before the fix
    assert eng.scheduler.preemptions >= 1
    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=14))
    for rid, p in zip(ids, prompts):
        np.testing.assert_array_equal(out[rid],
                                      fixed.generate(p[None], 9)[0])


def test_prefix_cache_auto_disabled_for_recurrent_mixers():
    cfg = ModelConfig(
        name="t", family="hybrid", d_model=64, vocab_size=128,
        pattern=(BlockDef("rglru"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, rnn_width=64,
        quant=MXFP8.replace(block_size=16, quantize_acts=False))
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=16, max_slots=1, page_size=4, prefix_cache=True))
    assert not eng.prefix_enabled
    assert eng.scheduler.prefix is None


# ---------------------------------------------------------------------------
# dedupe-on-insert: the hit-cap duplicate last page
# ---------------------------------------------------------------------------


def test_insert_dedupes_hit_cap_duplicate_last_page():
    """Two identical, exactly-page-aligned prompts: the second admission
    can only hit N-1 pages (the cap leaves one token to prefill), so it
    arrives at insert with a private duplicate of the last page. Insert
    must repoint its table entry to the tree's page and free the copy."""
    tree, pool = _tree(num_pages=8, ps=4)
    prompt = np.arange(8, dtype=np.int32)  # exactly 2 pages
    first = pool.alloc(2)
    assert tree.insert(prompt, first) == 2
    pool.free(first)  # first sequence finishes; the tree keeps its pages
    # second admission: acquire hits page 0 only (the cap), tail page is
    # freshly prefilled
    hit, cached = tree.acquire(prompt)
    assert hit == first[:1] and cached == 4
    dup = pool.alloc(1)
    table = hit + dup
    assert tree.insert(prompt, table) == 0  # nothing new in the tree
    assert table == first, "table must be repointed to the shared pages"
    assert pool.ref(dup[0]) == 0, "the duplicate page must be freed"
    assert pool.ref(first[1]) == 2  # tree + the second sequence
    assert tree.dedupes == 1 and tree.stats()["prefix_dedupes"] == 1
    pool.free(table)  # second sequence finishes
    assert pool.ref(first[1]) == 1  # only the tree holds it again


def test_engine_same_prompt_admissions_share_all_pages():
    """End-to-end dedupe regression: two same-prompt admissions end up
    with identical prompt page tables (one physical copy), the pool holds
    exactly the tree's pages after the run, and outputs stay
    token-identical to the fixed-slot reference."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(2).integers(
        0, 128, (16,)).astype(np.int32)  # exactly 2 pages of 8
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=32, max_slots=2, page_size=8, prefix_cache=True))
    i1, i2 = eng.submit(prompt, 4), eng.submit(prompt, 4)
    # chunked admission defers the second request one step so it can hit
    # the first one's freshly registered pages instead of racing past
    # the tree (monolithic admitted both in a single step)
    for _ in range(4):
        eng.step()
        if len(eng.scheduler.active()) == 2:
            break
    seqs = eng.scheduler.active()
    assert len(seqs) == 2
    assert seqs[0].pages[:2] == seqs[1].pages[:2], \
        "dedupe-on-insert must share the hit-cap duplicate last page"
    stats = eng.scheduler.prefix.stats()
    assert stats["prefix_dedupes"] == 1
    out = eng.run()
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=32)).generate(
        prompt[None], 4)[0]
    np.testing.assert_array_equal(out[i1], want)
    np.testing.assert_array_equal(out[i2], want)


# ---------------------------------------------------------------------------
# partial-page prefix hits (monolithic admission)
# ---------------------------------------------------------------------------


def test_partial_page_hit_monolithic_token_identical():
    """Regression (lost partial-page hits): prompts sharing a head that
    ends mid-page must take the partial-tail hit under monolithic
    admission — and stay token-identical to the cache-disabled engine,
    because the engine COWs the partial page before installing the
    remaining rows in place."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(41)
    head = rng.integers(0, 128, (10,)).astype(np.int32)  # 1 page + 2 tail
    reqs = [(head, 4)] + [
        (np.concatenate([head, rng.integers(0, 128, (t,)).astype(
            np.int32)]), 4) for t in (6, 3)]

    def serve(prefix):
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=32, max_slots=1, page_size=8,
            prefill_mode="monolithic", prefix_cache=prefix))
        ids = [eng.submit(p, m) for p, m in reqs]
        out = eng.run()
        return [out[i] for i in ids], eng

    want, _ = serve(False)
    got, eng = serve(True)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)
    stats = eng.cache_stats()
    assert stats["prefix_partial_inserts"] >= 1
    # the followers' hits include the 2 mid-page tokens, not just page 0
    assert stats["prefix_hit_tokens"] >= 2 * 10
    assert eng.scheduler.cow_copies >= 1  # partial pages were COWed


def test_release_partial_unpins_exactly_one_entry():
    tree, pool = _tree()
    prompt = np.arange(10, dtype=np.int32)  # 2 full + 2-token tail @ ps 4
    pages = pool.alloc(3)
    tree.insert(prompt, pages, partial=True)
    assert tree.num_partial_entries == 1 and pool.ref(pages[2]) == 2
    assert not tree.release_partial(pages[0])  # full-page node: untouched
    assert tree.release_partial(pages[2])
    assert tree.num_partial_entries == 0 and pool.ref(pages[2]) == 1
    assert not tree.release_partial(pages[2])  # already gone
    pool.free(pages)
    tree.evict(10)
    assert pool.pages_in_use == 0


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prefix_tree_property_partial_refcounts(seed):
    """Partial-tail churn obeys the same refcount conservation: through
    acquire (partial hits modelled with the engine's COW-or-unpin
    contract), insert(partial=True), finish, evict, and random
    release_partial probes, every page's refcount equals (tree full +
    partial holds) + (live tables holding it); draining empties all."""
    rng = np.random.default_rng(seed)
    ps, num_pages = 4, 32
    pool = PagePool(num_pages)
    tree = PrefixCache(pool, ps)
    vocab = 3  # tiny vocab -> heads collide -> real partial hits
    live = []
    for _ in range(60):
        op = rng.integers(4)
        if op == 0:  # admit, monolithic-style (partial hits allowed)
            n_tok = int(rng.integers(1, 13))
            prompt = rng.integers(0, vocab, size=(n_tok,)).astype(np.int32)
            hit, cached = tree.acquire(prompt)
            if cached % ps:  # partial page: COW it, or unpin as fallback
                old = hit[-1]
                if pool.can_alloc(1):
                    (new,) = pool.alloc(1)
                    pool.free([old])
                    hit[-1] = new
                else:
                    assert tree.release_partial(old)
            need = -(-n_tok // ps) - len(hit)
            if not pool.can_alloc(need):
                tree.evict(need - pool.free_pages)
            ids = pool.alloc(need)
            if ids is None:
                pool.free(hit)
                continue
            table = hit + ids
            tree.insert(prompt, table, partial=True)
            live.append(table)
        elif op == 1 and live:  # finish
            pool.free(live.pop(int(rng.integers(len(live)))))
        elif op == 2:  # pressure
            tree.evict(int(rng.integers(1, 4)))
        else:  # unpin probe: free pages never match, held may
            pid = int(rng.integers(num_pages))
            if pool.ref(pid) == 0:
                assert not tree.release_partial(pid)
            else:
                tree.release_partial(pid)
        held = tree.pages_held
        for pid in range(num_pages):
            want = held.count(pid) + sum(t.count(pid) for t in live)
            assert pool.ref(pid) == want, (pid, want, pool.ref(pid))
    for table in live:
        pool.free(table)
    tree.evict(num_pages)
    assert pool.pages_in_use == 0
    assert tree.num_nodes == 0 and tree.num_partial_entries == 0
