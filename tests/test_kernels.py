"""Pallas kernel validation: shape/dtype/block-size sweeps vs ref.py oracles.

All kernels run in interpret mode on CPU (the TPU target is exercised by the
lowering dry-run). assert_allclose tolerances reflect f32 accumulation-order
differences only — the MX math itself is exact in both paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quantize
from repro.kernels import mx_matmul, mx_matmul_trainable, quantize_pallas
from repro.kernels import ref as R

FMTS = ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"]
RNG = np.random.default_rng(1234)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# mx_matmul vector-vector (MX x MX)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 32, 8),  # single-tile minimum
        (16, 64, 128),
        (128, 256, 64),
        (256, 1024, 128),  # multi-tile in every grid dim
        (64, 512, 96),  # non-128 N
    ],
)
def test_mx_matmul_vv_shapes(fmt, m, k, n):
    x, w = _rand((m, k), 2.0), _rand((k, n), 0.5)
    xq, wq = quantize(x, fmt, 32), quantize(w, fmt, 32, axis=0)
    got = np.asarray(mx_matmul(xq, wq))
    want = np.asarray(
        R.mx_matmul_ref(xq.elements, xq.scales, wq.elements, wq.scales,
                        fmt=fmt, block_size=32)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_size", [8, 16, 32, 64, 128])
def test_mx_matmul_software_defined_block_sizes(block_size):
    """Paper design goal: block size is software-defined, not fixed to 32."""
    x, w = _rand((32, 256)), _rand((256, 32))
    xq = quantize(x, "fp8_e4m3", block_size)
    wq = quantize(w, "fp8_e4m3", block_size, axis=0)
    got = np.asarray(mx_matmul(xq, wq))
    want = np.asarray(
        R.mx_matmul_ref(xq.elements, xq.scales, wq.elements, wq.scales,
                        fmt="fp8_e4m3", block_size=block_size)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("fmt", FMTS)
def test_mx_matmul_bf16_accumulation(fmt):
    """Paper Table I: BF16 accumulator variants (vmxdotp.ww/qq)."""
    x, w = _rand((32, 128)), _rand((128, 32))
    xq, wq = quantize(x, fmt, 32), quantize(w, fmt, 32, axis=0)
    got = mx_matmul(xq, wq, acc_dtype=jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    want = R.mx_matmul_ref(
        xq.elements, xq.scales, wq.elements, wq.scales, fmt=fmt, block_size=32
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=0.05, atol=0.5
    )


def test_mx_matmul_batched_lead_dims():
    x = _rand((2, 4, 8, 64))
    w = _rand((64, 32))
    xq = quantize(x, "fp8_e4m3", 32)
    wq = quantize(w, "fp8_e4m3", 32, axis=0)
    got = mx_matmul(xq, wq)
    assert got.shape == (2, 4, 8, 32)
    flat = mx_matmul(
        quantize(x.reshape(-1, 64), "fp8_e4m3", 32), wq
    ).reshape(2, 4, 8, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(flat), rtol=1e-6)


# ---------------------------------------------------------------------------
# mx_matmul weight-only (vector-scalar variant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("m,k,n", [(8, 64, 8), (64, 512, 96), (128, 256, 128)])
def test_mx_matmul_wo_shapes(fmt, m, k, n):
    x, w = _rand((m, k)), _rand((k, n))
    wq = quantize(w, fmt, 32, axis=0)
    got = np.asarray(mx_matmul(x, wq))
    want = np.asarray(
        R.mx_matmul_wo_ref(x, wq.elements, wq.scales, fmt=fmt, block_size=32)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_mx_matmul_trainable_grads():
    x, w = _rand((16, 64)), _rand((64, 16))
    wq = quantize(w, "fp8_e4m3", 32, axis=0)

    def loss(x):
        return jnp.sum(mx_matmul_trainable(x, wq, "fp8_e4m3", 32, jnp.float32) ** 2)

    g = jax.grad(loss)(x)
    y = mx_matmul(x, wq)
    expect = 2.0 * np.asarray(y) @ np.asarray(wq.dequantize()).T
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quantize_pallas vs oracle (bit-exact)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("shape", [(8, 32), (64, 256), (4, 8, 128), (256, 2048)])
def test_quantize_pallas_bit_exact(fmt, shape):
    x = _rand(shape, 3.0)
    got = quantize_pallas(x, fmt, 32)
    want_e, want_s = R.mx_quantize_ref(x.reshape(-1, shape[-1]), fmt=fmt, block_size=32)
    np.testing.assert_array_equal(
        np.asarray(got.scales).reshape(want_s.shape), np.asarray(want_s)
    )
    ge = np.asarray(got.elements).reshape(np.asarray(want_e).shape)
    if fmt == "fp4_e2m1":
        np.testing.assert_array_equal(ge, np.asarray(want_e))
    else:
        np.testing.assert_array_equal(
            ge.astype(np.float32), np.asarray(want_e).astype(np.float32)
        )


@pytest.mark.parametrize("fmt", FMTS)
def test_quantize_pallas_roundtrip_through_matmul(fmt):
    """End-to-end: pallas quantize -> pallas matmul == core quantize -> ref."""
    x, w = _rand((32, 128)), _rand((128, 32))
    xq = quantize_pallas(x, fmt, 32)
    wq = quantize(w, fmt, 32, axis=0)
    got = np.asarray(mx_matmul(xq, wq))
    xq2 = quantize(x, fmt, 32)
    want = np.asarray(
        R.mx_matmul_ref(xq2.elements, xq2.scales, wq.elements, wq.scales,
                        fmt=fmt, block_size=32)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------


@given(
    fmt=st.sampled_from(FMTS),
    block_size=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    scale_exp=st.integers(-8, 8),
)
@settings(max_examples=15, deadline=None)
def test_kernel_scale_homogeneity(fmt, block_size, seed, scale_exp):
    """MX-DP is exactly homogeneous under power-of-two input scaling
    (paper Eq. (1): scales multiply out front)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    s = float(2.0**scale_exp)
    y1 = np.asarray(
        mx_matmul(quantize(x * s, fmt, block_size), quantize(w, fmt, block_size, axis=0))
    )
    y0 = np.asarray(
        mx_matmul(quantize(x, fmt, block_size), quantize(w, fmt, block_size, axis=0))
    )
    np.testing.assert_allclose(y1, y0 * s, rtol=1e-5, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_kernel_linearity_in_blocks(seed):
    """Zeroing one MX block must subtract exactly that block's contribution."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    wq = quantize(jnp.asarray(w), "fp8_e4m3", 32, axis=0)
    full = np.asarray(mx_matmul(quantize(jnp.asarray(x), "fp8_e4m3", 32), wq))
    x0 = x.copy()
    x0[:, 32:] = 0.0
    head = np.asarray(mx_matmul(quantize(jnp.asarray(x0), "fp8_e4m3", 32), wq))
    x1 = x.copy()
    x1[:, :32] = 0.0
    tail = np.asarray(mx_matmul(quantize(jnp.asarray(x1), "fp8_e4m3", 32), wq))
    np.testing.assert_allclose(full, head + tail, rtol=1e-5, atol=1e-5)
