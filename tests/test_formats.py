"""Unit + property tests for MX element/scale formats (OCP MX spec v1.0)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, hnp, settings, st

from repro.core import formats as F

FMTS = ["fp8_e4m3", "fp8_e5m2", "fp6_e3m2", "fp6_e2m3", "fp4_e2m1"]
FP6_FMTS = ["fp6_e3m2", "fp6_e2m3"]


# ---------------------------------------------------------------------------
# E8M0 scale format
# ---------------------------------------------------------------------------


def test_e8m0_roundtrip_powers_of_two():
    exps = np.arange(-126, 127, dtype=np.int32)
    amax = np.exp2(exps.astype(np.float64)).astype(np.float32)
    for fmt in (F.FP8_E4M3, F.FP8_E5M2, F.FP4_E2M1):
        e = np.asarray(F.e8m0_from_amax(jnp.asarray(amax), fmt))
        expected = np.clip(exps - fmt.emax + F.E8M0_BIAS, 0, 254)
        np.testing.assert_array_equal(e, expected.astype(np.uint8))


def test_e8m0_zero_amax():
    e = F.e8m0_from_amax(jnp.zeros((4,)), F.FP8_E4M3)
    np.testing.assert_array_equal(np.asarray(e), 0)


def test_e8m0_scale_decode_exact():
    """Scale decode must be bit-exact powers of two (shift-based, Listing 1)."""
    e = np.arange(0, 255, dtype=np.uint8)
    s = np.asarray(F.e8m0_to_scale(jnp.asarray(e)))
    expected = np.exp2(e.astype(np.float64) - 127.0).astype(np.float32)
    np.testing.assert_array_equal(s, expected)


@given(st.floats(min_value=1e-30, max_value=1e30, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_e8m0_amax_maps_into_format_range(amax):
    """After scaling, |amax/scale| must round into <= 2^(emax+1)."""
    for fmt in (F.FP8_E4M3, F.FP8_E5M2, F.FP4_E2M1):
        e = F.e8m0_from_amax(jnp.asarray([amax], dtype=jnp.float32), fmt)
        scale = float(F.e8m0_to_scale(e)[0])
        ratio = amax / scale
        assert ratio < 2.0 ** (fmt.emax + 1) * (1 + 1e-6)


# ---------------------------------------------------------------------------
# Element casts vs ml_dtypes oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
def test_cast_matches_ml_dtypes(fmt):
    rng = np.random.default_rng(42)
    info = F.get_format(fmt)
    x = np.concatenate(
        [
            rng.normal(size=2048).astype(np.float32) * info.max / 3,
            rng.uniform(-info.max * 1.5, info.max * 1.5, size=2048).astype(
                np.float32
            ),
            np.array([0.0, -0.0, info.max, -info.max], dtype=np.float32),
        ]
    )
    ours = np.asarray(F.cast_to_format_value(jnp.asarray(x), fmt))
    oracle = F.numpy_cast_oracle(x, fmt)
    np.testing.assert_array_equal(ours, oracle)


def test_fp4_tie_to_even():
    # midpoints and their RNE results (even mantissa neighbour)
    ties = {0.25: 0.0, 0.75: 1.0, 1.25: 1.0, 1.75: 2.0, 2.5: 2.0, 3.5: 4.0, 5.0: 4.0}
    x = jnp.asarray(list(ties.keys()), dtype=jnp.float32)
    got = np.asarray(F.cast_fp4_value(x))
    np.testing.assert_array_equal(got, np.asarray(list(ties.values()), np.float32))
    got_neg = np.asarray(F.cast_fp4_value(-x))
    np.testing.assert_array_equal(got_neg, -np.asarray(list(ties.values()), np.float32))


def test_fp4_saturation():
    x = jnp.asarray([7.0, 100.0, -9.5], dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(F.cast_fp4_value(x)), [6.0, 6.0, -6.0])


# ---------------------------------------------------------------------------
# FP4 nibble pack/unpack
# ---------------------------------------------------------------------------


@given(
    hnp.arrays(
        np.float32,
        st.integers(min_value=1, max_value=16).map(lambda n: (n, 8)),
        elements=st.floats(-8, 8, width=32),
    )
)
@settings(max_examples=50, deadline=None)
def test_fp4_pack_roundtrip(x):
    xj = jnp.asarray(x)
    nib = F.fp4_encode(xj)
    packed = F.fp4_pack(nib)
    assert packed.shape == (*x.shape[:-1], x.shape[-1] // 2)
    unpacked = F.fp4_unpack(packed)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(nib))
    decoded = np.asarray(F.fp4_decode(unpacked))
    np.testing.assert_array_equal(decoded, np.asarray(F.cast_fp4_value(xj)))


def test_fp4_encode_is_4bit():
    x = jnp.asarray(np.linspace(-10, 10, 101), dtype=jnp.float32)
    nib = np.asarray(F.fp4_encode(x))
    assert nib.max() <= 15


@pytest.mark.parametrize("fmt", FMTS)
def test_encode_decode_elements_roundtrip(fmt):
    rng = np.random.default_rng(7)
    x = rng.normal(size=(8, 32)).astype(np.float32)
    stored = F.encode_elements(jnp.asarray(x), fmt)
    back = np.asarray(F.decode_elements(stored, fmt))
    expected = np.asarray(F.cast_to_format_value(jnp.asarray(x), fmt))
    np.testing.assert_array_equal(back, expected)
    bits = F.storage_bits_per_element(fmt)
    assert stored.size * stored.dtype.itemsize * 8 == x.size * bits


# ---------------------------------------------------------------------------
# FP6 E3M2 / E2M3: exhaustive bit-level checks vs the scalar spec oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FP6_FMTS)
def test_fp6_all_64_code_points_roundtrip(fmt):
    """Every one of the 64 codes decodes to its spec grid value (sign |
    exp | mantissa, bias 2^(e-1)-1, e_field 0 => subnormal) and
    re-encodes to the identical code — including both signed zeros."""
    info = F.get_format(fmt)
    codes = np.arange(64, dtype=np.uint8)
    vals = np.asarray(F.fp6_decode(jnp.asarray(codes), fmt))
    grid = F.scalar_code_grid(fmt)
    expected = np.concatenate([grid, -grid]).astype(np.float32)
    np.testing.assert_array_equal(vals, expected)
    assert vals[0] == 0.0 and vals[32] == 0.0 and np.signbit(vals[32])
    assert np.abs(vals).max() == info.max
    back = np.asarray(F.fp6_encode(jnp.asarray(vals), fmt))
    np.testing.assert_array_equal(back, codes)


@pytest.mark.parametrize("fmt", FP6_FMTS)
def test_fp6_every_adjacent_midpoint_ties_to_even(fmt):
    """RNE at every representable boundary: the exact midpoint of each
    adjacent magnitude pair must land on the even-code neighbour (both
    signs), subnormal range included."""
    grid = F.scalar_code_grid(fmt)
    mids = (grid[:-1] + grid[1:]) / 2.0
    # even-mantissa-code winner per pair (codes i, i+1: exactly one even)
    want = np.where(np.arange(len(mids)) % 2 == 0, grid[:-1], grid[1:])
    got = np.asarray(
        F.cast_to_format_value(jnp.asarray(mids, jnp.float32), fmt))
    np.testing.assert_array_equal(got, want.astype(np.float32))
    got_neg = np.asarray(
        F.cast_to_format_value(jnp.asarray(-mids, jnp.float32), fmt))
    np.testing.assert_array_equal(got_neg, -want.astype(np.float32))


@pytest.mark.parametrize("fmt", FP6_FMTS)
def test_fp6_subnormal_encoding(fmt):
    """Subnormals keep e_field 0 and exact multiples of min_subnormal;
    magnitudes under half the smallest subnormal flush to +-0, and the
    exact half ties to the even code (zero)."""
    info = F.get_format(fmt)
    sub = info.min_subnormal
    n_sub = (1 << info.mantissa_bits) - 1
    x = np.arange(1, n_sub + 1, dtype=np.float64) * sub
    codes = np.asarray(F.fp6_encode(jnp.asarray(x, jnp.float32), fmt))
    np.testing.assert_array_equal(codes, np.arange(1, n_sub + 1))
    np.testing.assert_array_equal(
        np.asarray(F.fp6_decode(jnp.asarray(codes), fmt)),
        x.astype(np.float32))
    tiny = jnp.asarray([sub / 2, sub / 4, -sub / 2, 0.75 * sub],
                       jnp.float32)
    got = np.asarray(F.cast_to_format_value(tiny, fmt))
    np.testing.assert_array_equal(got, [0.0, 0.0, 0.0, sub])


@pytest.mark.parametrize("fmt", FP6_FMTS)
def test_fp6_saturation(fmt):
    info = F.get_format(fmt)
    x = jnp.asarray([info.max, info.max * 1.5, 1e30, -1e30], jnp.float32)
    got = np.asarray(F.cast_to_format_value(x, fmt))
    np.testing.assert_array_equal(
        got, [info.max, info.max, info.max, -info.max])


@pytest.mark.parametrize("fmt", FP6_FMTS)
def test_fp6_cast_matches_scalar_oracle(fmt):
    """Dense sweep over the whole dynamic range vs the from-first-
    principles scalar oracle (independent of ml_dtypes AND of the jnp
    code): bit-equal everywhere, midpoints and subnormals included."""
    info = F.get_format(fmt)
    grid = F.scalar_code_grid(fmt)
    rng = np.random.default_rng(19)
    x = np.concatenate([
        rng.uniform(-info.max * 1.25, info.max * 1.25, 4096),
        grid, -grid, (grid[:-1] + grid[1:]) / 2,
        -(grid[:-1] + grid[1:]) / 2,
    ]).astype(np.float32)
    got = np.asarray(F.cast_to_format_value(jnp.asarray(x), fmt))
    np.testing.assert_array_equal(got, F.scalar_cast_oracle(x, fmt))


@given(
    hnp.arrays(
        np.float32,
        st.integers(min_value=1, max_value=8).map(lambda n: (n, 8)),
        elements=st.floats(-30, 30, width=32),
    )
)
@settings(max_examples=50, deadline=None)
def test_fp6_pack_roundtrip(x):
    for fmt in FP6_FMTS:
        xj = jnp.asarray(x)
        codes = F.fp6_encode(xj, fmt)
        packed = F.fp6_pack(codes)
        assert packed.shape == (*x.shape[:-1], 3 * x.shape[-1] // 4)
        unpacked = F.fp6_unpack(packed)
        np.testing.assert_array_equal(np.asarray(unpacked),
                                      np.asarray(codes))
        decoded = np.asarray(F.fp6_decode(unpacked, fmt))
        np.testing.assert_array_equal(
            decoded, np.asarray(F.cast_to_format_value(xj, fmt)))
