"""Async serving front end: overload control, SSE streaming, cancel
semantics, and prefix-cache persistence.

The HTTP/SSE cases run a real ``ServeHTTPServer`` on an ephemeral port
inside ``asyncio.run`` (stdlib only — no pytest-asyncio in the CI
image). Cancel and persistence are exercised at the engine level where
the page/refcount invariants can be asserted directly.
"""
import asyncio
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import (AsyncServeEngine, ContinuousBatchingEngine,
                         DrainingError, OverloadConfig, OverloadController,
                         SamplingParams, ServeConfig, ServeHTTPServer,
                         ShedError, TierPolicy)
from repro.serve.server import sse_generate


def _cfg():
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=True))


@pytest.fixture(scope="module")
def model_and_cfg():
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _engine(params, cfg, **kw):
    args = dict(max_seq=24, max_slots=2, page_size=4)
    args.update(kw)
    return ContinuousBatchingEngine(params, cfg, ServeConfig(**args))


def _tree_pages(eng):
    return len(eng.scheduler.prefix.pages_held)


# ---------------------------------------------------------------------------
# overload controller (pure host logic, injected clock)
# ---------------------------------------------------------------------------


def test_overload_predicts_sheds_and_recovers():
    now = [0.0]
    ctl = OverloadController(OverloadConfig(slo_ms=100),
                             clock=lambda: now[0])
    # no measurements yet: everything is admitted
    ctl.admit(50)
    # two first tokens 10ms apart, each 20ms after its submit
    ctl.observe_first_token(0.02)
    now[0] += 0.01
    ctl.observe_first_token(0.02)
    assert abs(ctl.predicted_latency(5) - (5 * 0.01 + 0.02)) < 1e-9
    ctl.admit(8)  # predicted 100ms == SLO, not over -> admit
    with pytest.raises(ShedError) as ei:
        ctl.admit(9)  # 110ms > SLO
    assert ei.value.retry_after_s > 0
    assert ctl.shedding
    # hysteresis: 100ms is back under the SLO but not under 85ms
    with pytest.raises(ShedError):
        ctl.admit(8)
    # an empty queue always admits (liveness: estimates can refresh)
    ctl.admit(0)
    assert ctl.shedding  # depth-0 admit does not flip the state
    ctl.admit(6)  # 80ms < 85ms -> shedding ends
    assert not ctl.shedding
    stats = ctl.stats()
    assert stats["shed_count"] == 2 and stats["admitted_count"] == 4


def test_overload_max_queue_is_a_hard_cap():
    ctl = OverloadController(OverloadConfig(max_queue=2))
    ctl.admit(0)
    ctl.admit(1)
    with pytest.raises(ShedError):
        ctl.admit(2)


def test_overload_config_validation():
    for bad in (dict(slo_ms=0), dict(max_queue=-1), dict(ewma_alpha=0),
                dict(hysteresis=1.5), dict(min_retry_after_s=-1.0)):
        with pytest.raises(ValueError):
            OverloadConfig(**bad).validate()
    assert ShedError("x", retry_after_s=-1.0).retry_after_s == 0.0


def test_overload_retry_after_never_zero():
    """A cold controller's max_queue cap has no drain-rate estimate and
    the SLO branch can overshoot by epsilon — both used to hand clients
    Retry-After: 0, a reconnect hot loop. Every shed now floors at
    min_retry_after_s."""
    now = [0.0]
    # cold cap: no first-token interval ever observed -> estimate is 0
    ctl = OverloadController(OverloadConfig(max_queue=1),
                             clock=lambda: now[0])
    ctl.admit(0)
    with pytest.raises(ShedError) as ei:
        ctl.admit(1)
    assert ei.value.retry_after_s == pytest.approx(0.05)
    # warm cap: a real interval beats the floor
    ctl = OverloadController(OverloadConfig(max_queue=1),
                             clock=lambda: now[0])
    ctl.observe_first_token(0.01)
    now[0] += 0.25
    ctl.observe_first_token(0.01)
    with pytest.raises(ShedError) as ei:
        ctl.admit(5)
    assert ei.value.retry_after_s == pytest.approx(0.25)
    # SLO branch at the boundary: predicted - slo ~ 0 -> clamped to floor
    ctl = OverloadController(OverloadConfig(slo_ms=100),
                             clock=lambda: now[0])
    ctl.observe_first_token(0.02)
    now[0] += 0.01
    ctl.observe_first_token(0.02)
    with pytest.raises(ShedError) as ei:
        ctl.admit(9)  # predicted 110ms, 10ms over -> under the 50ms floor
    assert ei.value.retry_after_s == pytest.approx(0.05)
    # and a custom floor propagates
    ctl = OverloadController(OverloadConfig(max_queue=1,
                                            min_retry_after_s=2.0),
                             clock=lambda: now[0])
    ctl.admit(0)
    with pytest.raises(ShedError) as ei:
        ctl.admit(1)
    assert ei.value.retry_after_s == pytest.approx(2.0)


def test_engine_submit_sheds_and_counts(model_and_cfg):
    params, cfg = model_and_cfg
    eng = _engine(params, cfg, max_queue=1)
    eng.submit(np.arange(1, 5, dtype=np.int32), 2)
    with pytest.raises(ShedError):
        eng.submit(np.arange(1, 5, dtype=np.int32), 2)
    assert eng.cache_stats()["shed_count"] == 1
    eng.run()  # the admitted request still completes


# ---------------------------------------------------------------------------
# HTTP/SSE end to end
# ---------------------------------------------------------------------------


async def _http(port, method, path, body=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    data = json.dumps(body or {}).encode()
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
                  f"Content-Length: {len(data)}\r\n\r\n").encode() + data)
    await writer.drain()
    status = (await reader.readline()).decode()
    clen = 0
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        name, _, value = line.decode().partition(":")
        if name.strip().lower() == "content-length":
            clen = int(value)
    payload = json.loads(await reader.readexactly(clen)) if clen else {}
    writer.close()
    await writer.wait_closed()
    return status, payload


def test_sse_streaming_end_to_end(model_and_cfg):
    """Streamed greedy tokens == direct engine output; same-seed sampled
    streams are identical across concurrent connections; health route
    answers; the final SSE event carries the full token list."""
    params, cfg = model_and_cfg
    prompt = list(range(1, 9))

    async def go():
        eng = _engine(params, cfg, max_slots=4, max_seq=32, page_size=8)
        aeng = AsyncServeEngine(eng)
        srv = ServeHTTPServer(aeng, port=0)
        await srv.start()

        async def client(payload):
            toks, final = [], None
            async for ev in sse_generate("127.0.0.1", srv.port, payload):
                if "token" in ev:
                    toks.append(ev["token"])
                if ev.get("done"):
                    final = ev
            return toks, final

        (g, gf), (s1, _), (s2, _) = await asyncio.gather(
            client({"prompt": prompt, "max_new_tokens": 6}),
            client({"prompt": prompt, "max_new_tokens": 6,
                    "temperature": 0.8, "seed": 5}),
            client({"prompt": prompt, "max_new_tokens": 6,
                    "temperature": 0.8, "seed": 5}))
        status, health = await _http(srv.port, "GET", "/v1/health")
        await srv.stop()
        return g, gf, s1, s2, status, health

    g, gf, s1, s2, status, health = asyncio.run(go())
    assert len(g) == 6 and gf["tokens"] == g
    assert s1 == s2 and len(s1) == 6
    assert "200" in status and "queue_depth" in health

    eng = _engine(params, cfg, max_slots=4, max_seq=32, page_size=8)
    rid = eng.submit(np.asarray(prompt, np.int32), 6)
    direct = eng.run()[rid]
    assert list(direct[len(prompt):]) == g


def test_sse_disconnect_cancels_and_frees(model_and_cfg):
    params, cfg = model_and_cfg

    async def go():
        eng = _engine(params, cfg, max_slots=2, max_seq=64, page_size=8)
        aeng = AsyncServeEngine(eng)
        srv = ServeHTTPServer(aeng, port=0)
        await srv.start()
        body = json.dumps({"prompt": list(range(1, 9)),
                           "max_new_tokens": 50}).encode()
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       srv.port)
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        for _ in range(8):  # status + headers + a few token events
            await reader.readline()
        writer.close()  # hang up mid-stream
        await writer.wait_closed()
        await aeng.drain()  # engine must reach idle, not decode 50 tokens
        await srv.stop()
        return eng

    eng = asyncio.run(go())
    assert eng.scheduler.cancellations == 1
    assert all(s is None for s in eng.scheduler.slots)
    assert eng.scheduler.pool.pages_in_use == _tree_pages(eng)


def test_http_shed_429_and_drain_503(model_and_cfg):
    params, cfg = model_and_cfg

    async def go():
        eng = _engine(params, cfg, max_queue=0)
        aeng = AsyncServeEngine(eng)
        srv = ServeHTTPServer(aeng, port=0)
        await srv.start()
        shed_msg = None
        try:
            async for _ in sse_generate("127.0.0.1", srv.port, {
                    "prompt": [1, 2, 3], "max_new_tokens": 2}):
                pass
        except RuntimeError as e:
            shed_msg = str(e)
        _, drained = await _http(srv.port, "POST", "/v1/drain")
        drain_msg = None
        try:
            async for _ in sse_generate("127.0.0.1", srv.port, {
                    "prompt": [1, 2, 3], "max_new_tokens": 2}):
                pass
        except RuntimeError as e:
            drain_msg = str(e)
        with pytest.raises(DrainingError):
            aeng.submit([1, 2, 3], 2)
        await srv.stop()
        return shed_msg, drained, drain_msg

    shed_msg, drained, drain_msg = asyncio.run(go())
    assert "429" in shed_msg and "Retry-After" in shed_msg
    assert drained == {"drained": True}
    assert "503" in drain_msg


# ---------------------------------------------------------------------------
# cancel semantics (engine level)
# ---------------------------------------------------------------------------


def test_cancel_unknown_finished_and_queued(model_and_cfg):
    params, cfg = model_and_cfg
    eng = _engine(params, cfg)
    assert not eng.cancel(99)
    p = np.arange(1, 5, dtype=np.int32)
    ids = [eng.submit(p + i, 3) for i in range(3)]
    assert eng.cancel(ids[1])  # still queued: just dequeued
    assert len(eng.scheduler.queue) == 2
    out = eng.run()
    assert set(out) == {ids[0], ids[2]}
    assert not eng.cancel(ids[0])  # finished: nothing to cancel
    assert eng.scheduler.cancellations == 1


def test_cancel_active_mid_decode_frees_pages(model_and_cfg):
    params, cfg = model_and_cfg
    eng = _engine(params, cfg, max_seq=64, page_size=8)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), 40)
    for _ in range(3):  # prefill + a couple of decode steps
        eng.step()
    assert any(s is not None for s in eng.scheduler.slots)
    assert eng.cancel(rid)
    assert all(s is None for s in eng.scheduler.slots)
    assert eng.scheduler.pool.pages_in_use == _tree_pages(eng)
    assert not eng.scheduler.has_work
    assert eng.run() == {}


def test_cancel_mid_chunked_prefill(model_and_cfg):
    params, cfg = model_and_cfg
    eng = _engine(params, cfg, max_seq=64, page_size=4, prefill_chunk=4,
                  prefill_token_budget=4)
    long_prompt = np.arange(1, 33, dtype=np.int32)  # 8 chunks
    rid = eng.submit(long_prompt, 4)
    eng.step()  # one chunk in: mid-prefill
    assert eng.cancel(rid)
    rid2 = eng.submit(np.arange(1, 9, dtype=np.int32), 4)
    out = eng.run()
    assert rid2 in out and rid not in out
    assert eng.scheduler.pool.pages_in_use == _tree_pages(eng)


def test_cancel_swapped_out_request(model_and_cfg):
    """Cancelling a swap-preempted (queued, snapshot-holding) request
    frees only its shared pages and the rest of the workload completes
    untouched."""
    params, cfg = model_and_cfg
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(4, 14), (4, 14), (7, 5), (3, 8)]]
    eng = _engine(params, cfg, max_seq=20, max_slots=2, page_size=4,
                  num_pages=7)
    ids = [eng.submit(p, m) for p, m in reqs]
    swapped = None
    for _ in range(400):
        eng.step()
        swapped = next((r for r in eng.scheduler.queue
                        if r.swap is not None), None)
        if swapped is not None:
            break
    assert swapped is not None, "pool sizing must force a swap"
    assert eng.cancel(swapped.id)
    out = eng.run()  # run() returns everything finished, incl. earlier
    assert swapped.id not in out
    assert set(out) == {i for i in ids if i != swapped.id}
    assert all(s is None for s in eng.scheduler.slots)
    assert eng.scheduler.pool.pages_in_use == _tree_pages(eng)


def test_cancel_mid_verify_spec_engine(model_and_cfg):
    params, cfg = model_and_cfg
    eng = _engine(params, cfg, max_seq=32, max_slots=2, page_size=8,
                  spec_decode=True, num_draft_tokens=3)
    p = np.arange(1, 7, dtype=np.int32)
    r1 = eng.submit(p, 12)
    r2 = eng.submit(p[::-1].copy(), 12)
    while eng.spec_steps < 1:
        eng.step()
    assert eng.cancel(r1)
    out = eng.run()
    assert r1 not in out and out[r2].shape[0] == 6 + 12
    assert eng.scheduler.pool.pages_in_use == _tree_pages(eng)


def test_cancel_churn_property(model_and_cfg):
    """Random cancels at random times across a churning workload: no
    page leaks, no double frees, survivors all finish."""
    params, cfg = model_and_cfg
    rng = np.random.default_rng(11)
    eng = _engine(params, cfg, max_seq=20, max_slots=2, page_size=4,
                  num_pages=10)
    ids = [eng.submit(rng.integers(0, 128, (int(s),)).astype(np.int32),
                      int(m))
           for s, m in zip(rng.integers(3, 9, 8), rng.integers(4, 13, 8))]
    cancelled = set()
    steps = 0
    while eng.scheduler.has_work and steps < 1000:
        eng.step()
        steps += 1
        if rng.random() < 0.3:
            victim = int(rng.choice(ids))
            if victim not in cancelled and eng.cancel(victim):
                cancelled.add(victim)
    out = eng.run()
    assert eng.scheduler.cancellations == len(cancelled)
    assert set(out) == set(ids) - cancelled
    for rid in set(ids) - cancelled:
        assert out[rid].shape[0] > 0
    assert all(s is None for s in eng.scheduler.slots)
    assert eng.scheduler.pool.pages_in_use == _tree_pages(eng)


# ---------------------------------------------------------------------------
# prefix-cache persistence
# ---------------------------------------------------------------------------


def _export_pages(eng):
    st = eng.scheduler.prefix.export_state()
    return st, ([nd["page"] for nd in st["nodes"]]
                + [ent["page"] for ent in st["partials"]])


def test_prefix_snapshot_roundtrip_bit_identical(model_and_cfg, tmp_path):
    params, cfg = model_and_cfg
    kw = dict(max_seq=32, max_slots=2, page_size=4)
    e1 = _engine(params, cfg, **kw)
    p1 = np.arange(1, 13, dtype=np.int32)  # 3 full pages
    p2 = np.concatenate([p1[:8], np.arange(50, 58, dtype=np.int32)])
    r1 = e1.submit(p1, 6)
    e1.submit(p2, 6)
    out1 = e1.run()
    path = tmp_path / "prefix.npz"
    n_pages = e1.save_prefix_cache(path)
    assert n_pages > 0

    e2 = _engine(params, cfg, **kw)
    n_entries = e2.load_prefix_cache(path)
    assert n_entries == (e1.scheduler.prefix.num_nodes
                         + e1.scheduler.prefix.num_partial_entries)

    # identical tree structure (same BFS order), bit-identical page bytes
    st1, pages1 = _export_pages(e1)
    st2, pages2 = _export_pages(e2)
    strip = lambda st: [{k: v for k, v in nd.items() if k != "page"}
                        for nd in st["nodes"] + st["partials"]]
    assert strip(st1) == strip(st2)
    s1 = e1._extract(e1.cache, jnp.asarray(0, jnp.int32),
                     jnp.asarray(pages1, jnp.int32))
    s2 = e2._extract(e2.cache, jnp.asarray(0, jnp.int32),
                     jnp.asarray(pages2, jnp.int32))
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s2)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

    # a warm hit on the imported tree decodes token-identically
    r = e2.submit(p1, 6)
    out2 = e2.run()
    np.testing.assert_array_equal(out2[r], out1[r1])
    assert e2.cache_stats()["prefix_hit_rate"] > 0


def test_prefix_snapshot_roundtrip_tiered_formats(model_and_cfg,
                                                  tmp_path):
    """Tiered pool: per-page element formats survive the round trip (a
    demoted fp6/fp4 page must be read back as fp6/fp4)."""
    params, cfg = model_and_cfg
    kw = dict(max_seq=32, max_slots=2, page_size=4, tiered=True,
              tier_policy=TierPolicy(hot_steps=1, cold_steps=2,
                                     repack_pages_per_step=8))
    e1 = _engine(params, cfg, **kw)
    p1 = np.arange(1, 13, dtype=np.int32)
    r1 = e1.submit(p1, 8)
    out1 = e1.run()
    path = tmp_path / "tiered.npz"
    assert e1.save_prefix_cache(path) > 0

    e2 = _engine(params, cfg, **kw)
    e2.load_prefix_cache(path)
    _, pages1 = _export_pages(e1)
    _, pages2 = _export_pages(e2)
    fmts1 = [int(e1.page_fmts[p]) for p in pages1]
    fmts2 = [int(e2.page_fmts[p]) for p in pages2]
    assert fmts1 == fmts2
    assert any(f != e1._base_fmt_id for f in fmts1), \
        "policy should have demoted some pages below the base format"
    r = e2.submit(p1, 8)
    out2 = e2.run()
    np.testing.assert_array_equal(out2[r], out1[r1])


@pytest.mark.parametrize("save_mode,load_mode",
                         [("ragged", "split"), ("split", "ragged")])
def test_prefix_snapshot_roundtrip_across_step_modes(model_and_cfg,
                                                     tmp_path, save_mode,
                                                     load_mode):
    """The ragged engine's pool carries one extra trash page (the sink
    for masked-lane K/V writes) that the split engine's does not. A
    snapshot is addressed by *listed page*, not pool geometry, so it
    must round-trip between the two modes — the trash page must neither
    leak into the snapshot nor shift the importer's page indexing."""
    params, cfg = model_and_cfg
    kw = dict(max_seq=32, max_slots=2, page_size=4)
    e1 = _engine(params, cfg, step_mode=save_mode, **kw)
    assert e1._trash_pages == (1 if save_mode == "ragged" else 0)
    p1 = np.arange(1, 13, dtype=np.int32)
    r1 = e1.submit(p1, 6)
    e1.submit(np.concatenate([p1[:8], np.arange(50, 58, dtype=np.int32)]),
              6)
    out1 = e1.run()
    path = tmp_path / "xmode.npz"
    assert e1.save_prefix_cache(path) > 0

    e2 = _engine(params, cfg, step_mode=load_mode, **kw)
    assert e2.load_prefix_cache(path) > 0
    st1, _ = _export_pages(e1)
    st2, pages2 = _export_pages(e2)
    strip = lambda st: [{k: v for k, v in nd.items() if k != "page"}
                        for nd in st["nodes"] + st["partials"]]
    assert strip(st1) == strip(st2)
    # no imported entry may sit on the importer's trash page
    assert all(p < e2.num_pages for p in pages2)
    r = e2.submit(p1, 6)
    out2 = e2.run()
    np.testing.assert_array_equal(out2[r], out1[r1])
    assert e2.cache_stats()["prefix_hit_rate"] > 0


def test_prefix_snapshot_rejects_mismatched_geometry(model_and_cfg,
                                                     tmp_path):
    params, cfg = model_and_cfg
    e1 = _engine(params, cfg, max_seq=32, max_slots=2, page_size=4)
    e1.submit(np.arange(1, 13, dtype=np.int32), 4)
    e1.run()
    path = tmp_path / "prefix.npz"
    e1.save_prefix_cache(path)
    e2 = _engine(params, cfg, max_seq=32, max_slots=2, page_size=8)
    with pytest.raises(ValueError, match="snapshot|page"):
        e2.load_prefix_cache(path)
