"""Paged MX decode attention: gather vs contiguous (bit-exact) + fused.

The two-pass paged kernel gathers compact K/V tiles through the page table
and then runs the identical attention kernel, so paged and contiguous
caches must agree to the bit in interpret mode — any mismatch means the
page plumbing (table indexing, clamping, masking) is wrong, not the float
math.

The single-pass fused kernel (`mx_attention_decode_fused`) accumulates an
online softmax over page tiles, so it is checked against an f32 einsum
reference to <= 1e-5 (online rescaling reorders f32 additions), plus
structural checks: no gathered (B, KVH, T, ·) array — wide or compact —
may appear in its jaxpr, and unallocated/garbage pages must never
contribute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize
from repro.kernels import (gather_kv_pages, mx_attention_decode,
                           mx_attention_decode_fused,
                           mx_attention_decode_paged,
                           mx_attention_verify_fused)

RNG = np.random.default_rng(123)


def _einsum_reference(q, kq, vq, lens):
    """f32 dequantize + masked softmax oracle on the contiguous cache."""
    q = np.asarray(q, np.float32)
    kd = np.asarray(kq.dequantize(jnp.float32))
    vd = np.asarray(vq.dequantize(jnp.float32))
    b, kvh, g, d = q.shape
    out = np.zeros((b, kvh, g, d), np.float32)
    for i in range(b):
        t = int(lens[i])
        s = np.einsum("kgd,ktd->kgt", q[i], kd[i, :, :t]) * d ** -0.5
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        out[i] = np.einsum("kgt,ktd->kgd", p, vd[i, :, :t])
    return out


def _paged_layout(kq, vq, b, kvh, t, ps, rng):
    """Scatter a contiguous (B, KVH, T, ·) cache into a shuffled page pool."""
    npg = t // ps
    pool_pages = b * npg + 3  # spare pages stay garbage (must be masked)
    perm = rng.permutation(pool_pages)[: b * npg]
    table = perm.reshape(b, npg).astype(np.int32)
    arrs = {}
    for name, src in [("ke", kq.elements), ("ks", kq.scales),
                      ("ve", vq.elements), ("vs", vq.scales)]:
        src = np.asarray(src)
        pool = np.full((pool_pages, ps, kvh, src.shape[-1]), 255,
                       dtype=src.dtype if src.dtype != np.uint8 else np.uint8)
        if pool.dtype != np.uint8:
            pool[:] = 0
        for i in range(b):
            for p in range(npg):
                pool[table[i, p]] = src[i, :, p * ps:(p + 1) * ps].transpose(
                    1, 0, 2)
        arrs[name] = jnp.asarray(pool)
    return arrs, jnp.asarray(table)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_paged_matches_contiguous_bit_exact(fmt, block_size):
    b, kvh, g, d, t, ps = 2, 2, 2, 64, 64, 16
    q = jnp.asarray(RNG.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    lens = np.array([t - 3, t - 17], np.int32)

    want = []
    for i in range(b):
        kpos = jnp.where(jnp.arange(t) < lens[i], jnp.arange(t),
                         -1).astype(jnp.int32)
        want.append(np.asarray(mx_attention_decode(
            q[i:i + 1], kq.elements[i:i + 1], kq.scales[i:i + 1],
            vq.elements[i:i + 1], vq.scales[i:i + 1], kpos,
            int(lens[i]) - 1, fmt_name=fmt, block_size=block_size)))
    want = np.concatenate(want, axis=0)

    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, RNG)
    got = np.asarray(mx_attention_decode_paged(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), fmt_name=fmt, block_size=block_size))
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))


def test_gather_kv_pages_reorders_exactly():
    b, kvh, t, d, ps = 2, 3, 32, 32, 8
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, RNG)
    ke, ks, ve, vs = gather_kv_pages(pools["ke"], pools["ks"], pools["ve"],
                                     pools["vs"], table)
    np.testing.assert_array_equal(
        np.asarray(ke).astype(np.float32),
        np.asarray(kq.elements).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(kq.scales))
    np.testing.assert_array_equal(
        np.asarray(ve).astype(np.float32),
        np.asarray(vq.elements).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vq.scales))


def test_unallocated_table_entries_never_contribute():
    """Rows past seq_len come from clamped/garbage pages; outputs must not
    depend on their contents."""
    b, kvh, g, d, t, ps = 1, 2, 2, 32, 32, 8
    q = jnp.asarray(RNG.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, RNG)
    seq_len = jnp.asarray([ps + 3], jnp.int32)  # only the first 2 pages valid
    base = np.asarray(mx_attention_decode_paged(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        seq_len))
    table2 = np.asarray(table).copy()
    table2[0, 2:] = -1  # drop the unallocated tail entirely
    got = np.asarray(mx_attention_decode_paged(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"],
        jnp.asarray(table2), seq_len))
    np.testing.assert_array_equal(got.view(np.uint32), base.view(np.uint32))


def test_contiguous_kernel_per_sequence_positions():
    """(B,) pos / (B, T) kpos rows must equal per-row scalar calls."""
    b, kvh, g, d, t = 3, 2, 2, 32, 48
    q = jnp.asarray(RNG.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    lens = np.array([10, 48, 33], np.int32)
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    got = np.asarray(mx_attention_decode(
        q, kq.elements, kq.scales, vq.elements, vq.scales, kpos,
        jnp.asarray(lens) - 1))
    for i in range(b):
        want = np.asarray(mx_attention_decode(
            q[i:i + 1], kq.elements[i:i + 1], kq.scales[i:i + 1],
            vq.elements[i:i + 1], vq.scales[i:i + 1],
            jnp.arange(t, dtype=jnp.int32), int(lens[i]) - 1))
        np.testing.assert_array_equal(got[i:i + 1].view(np.uint32),
                                      want.view(np.uint32))


# ---------------------------------------------------------------------------
# single-pass fused kernel: accuracy, edge cases, structural guarantees
# ---------------------------------------------------------------------------


def _fused_case(fmt, block_size, b, kvh, g, d, t, ps, lens, rng, **kw):
    """Build a shuffled paged layout, run fused, compare to the f32 oracle."""
    q = jnp.asarray(rng.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    got = np.asarray(mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), fmt_name=fmt, block_size=block_size, **kw))
    return got, _einsum_reference(q, kq, vq, lens)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_fused_matches_einsum_reference(fmt, block_size):
    rng = np.random.default_rng(11)
    lens = np.array([61, 17], np.int32)
    got, want = _fused_case(fmt, block_size, b=2, kvh=2, g=2, d=64, t=64,
                            ps=16, lens=lens, rng=rng)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp4_e2m1"])
@pytest.mark.parametrize(
    "lens",
    [np.array([16, 32], np.int32),   # exactly on a page boundary
     np.array([1, 1], np.int32),     # single-token sequences
     np.array([64, 64], np.int32)],  # fully-packed table, no padding
    ids=["page-boundary", "seq-len-1", "fully-packed"])
def test_fused_edge_lengths(fmt, lens):
    """Boundary occupancies the page-skip predicate must get right."""
    rng = np.random.default_rng(13)
    got, want = _fused_case(fmt, 32, b=2, kvh=2, g=2, d=64, t=64, ps=16,
                            lens=lens, rng=rng)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("d", [32, 64])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_fused_fp4_packed_nibbles(d, block_size):
    """fp4 stores two nibbles per byte: the in-kernel unpack must cope
    with every (head_dim, block) tiling the serve configs use."""
    if block_size > d:
        pytest.skip("block cannot exceed head_dim")
    rng = np.random.default_rng(17)
    lens = np.array([37, 8, 40], np.int32)
    got, want = _fused_case("fp4_e2m1", block_size, b=3, kvh=2, g=4, d=d,
                            t=40, ps=8, lens=lens, rng=rng)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


def test_fused_unallocated_pages_never_contribute():
    """Entries past ceil(seq_len / PS) are garbage/-1; flipping their
    contents or ids must not change the output at all."""
    rng = np.random.default_rng(19)
    b, kvh, g, d, t, ps = 1, 2, 2, 32, 32, 8
    q = jnp.asarray(rng.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    seq_len = jnp.asarray([ps + 3], jnp.int32)  # only the first 2 pages valid
    base = np.asarray(mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        seq_len))
    table2 = np.asarray(table).copy()
    table2[0, 2:] = -1  # drop the unallocated tail entirely
    got = np.asarray(mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"],
        jnp.asarray(table2), seq_len))
    np.testing.assert_array_equal(got.view(np.uint32), base.view(np.uint32))


def test_fused_sliding_window_matches_masked_reference():
    rng = np.random.default_rng(23)
    b, kvh, g, d, t, ps, window = 2, 2, 2, 64, 64, 16, 12
    q = jnp.asarray(rng.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    lens = np.array([61, 30], np.int32)
    got = np.asarray(mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), window=window))
    kd = np.asarray(kq.dequantize(jnp.float32))
    vd = np.asarray(vq.dequantize(jnp.float32))
    for i in range(b):
        pos = int(lens[i]) - 1
        lo = max(0, pos - window + 1)
        s = np.einsum("kgd,ktd->kgt", np.asarray(q[i], np.float32),
                      kd[i, :, lo:pos + 1]) * d ** -0.5
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        want = np.einsum("kgt,ktd->kgd", p, vd[i, :, lo:pos + 1])
        np.testing.assert_allclose(got[i], want, atol=1e-5, rtol=0)


def test_fused_visits_exactly_the_resident_pages():
    """The skip predicate's audit trail: the kernel's visit counter must
    equal ceil(seq_len / PS) per (batch, kv-head) cell — more visits
    means work scales with the padded table again, fewer means dropped
    context. (Wall-clock can't falsify this off-TPU: the interpreter
    visits every grid cell and only predicates the body away.)"""
    rng = np.random.default_rng(29)
    b, kvh, g, d, t, ps = 3, 2, 2, 32, 32, 8
    q = jnp.asarray(rng.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    lens = np.array([1, 8, 27], np.int32)  # 1, 1, and 4 resident pages
    _, visits = mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), debug_visits=True)
    want = np.broadcast_to(np.ceil(lens / ps).astype(np.int32)[:, None],
                           (b, kvh))
    np.testing.assert_array_equal(np.asarray(visits)[:, :, 0], want)


def test_fused_never_materializes_gathered_cache():
    """Structural guarantee: the fused path's jaxpr contains exactly one
    pallas_call and no intermediate shaped like a gathered cache — neither
    the wide f32/bf16 copy nor the compact one the two-pass kernel
    produces, in either the kernel layout (B, KVH, T, ·) or the nn einsum
    layout (B, T, KVH, ·). ``d != t`` so a padded-T axis is unambiguous."""
    b, kvh, g, d, t, ps = 2, 2, 2, 16, 32, 8
    pmax = t // ps
    npg = b * pmax + 2

    def run(q, ke, ks, ve, vs, table, lens):
        return mx_attention_decode_fused(q, ke, ks, ve, vs, table, lens,
                                         fmt_name="fp8_e4m3", block_size=16)

    jaxpr = jax.make_jaxpr(run)(
        jnp.zeros((b, kvh, g, d), jnp.float32),
        jnp.zeros((npg, ps, kvh, d), jnp.float8_e4m3fn),
        jnp.zeros((npg, ps, kvh, 1), jnp.uint8),
        jnp.zeros((npg, ps, kvh, d), jnp.float8_e4m3fn),
        jnp.zeros((npg, ps, kvh, 1), jnp.uint8),
        jnp.zeros((b, pmax), jnp.int32),
        jnp.zeros((b,), jnp.int32))
    pallas_calls = 0
    for eqn in jaxpr.jaxpr.eqns:
        pallas_calls += eqn.primitive.name == "pallas_call"
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            assert not (len(shape) == 4 and shape[0] == b
                        and t in (shape[1], shape[2])), (
                f"gathered cache materialized: {eqn.primitive} -> {shape}")
    assert pallas_calls == 1, jaxpr


# ---------------------------------------------------------------------------
# Tq > 1 fused verify kernel (speculative decoding's batched verify)
# ---------------------------------------------------------------------------


def _verify_reference(q, kq, vq, lens, window=None):
    """f32 oracle for the multi-query verify kernel, one query at a time.

    q: (B, KVH, Tq, G, D). Query ``ti`` of sequence ``i`` sits at absolute
    position ``lens[i] - Tq + ti`` and attends keys ``<= that position``
    (minus the sliding window, if any) — per-row causal masking is the
    whole point, so the oracle computes every row independently.
    """
    q = np.asarray(q, np.float32)
    kd = np.asarray(kq.dequantize(jnp.float32))
    vd = np.asarray(vq.dequantize(jnp.float32))
    b, kvh, tq, g, d = q.shape
    out = np.zeros((b, kvh, tq, g, d), np.float32)
    for i in range(b):
        for ti in range(tq):
            p = int(lens[i]) - tq + ti
            lo = 0 if window is None else max(0, p - window + 1)
            s = np.einsum("kgd,ktd->kgt", q[i, :, ti],
                          kd[i, :, lo:p + 1]) * d ** -0.5
            pr = np.exp(s - s.max(-1, keepdims=True))
            pr /= pr.sum(-1, keepdims=True)
            out[i, :, ti] = np.einsum("kgt,ktd->kgd", pr, vd[i, :, lo:p + 1])
    return out


def _verify_case(fmt, block_size, b, kvh, g, d, t, ps, tq, lens, rng,
                 **kw):
    q = jnp.asarray(rng.normal(size=(b, kvh, tq, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    got = mx_attention_verify_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), fmt_name=fmt, block_size=block_size, **kw)
    window = kw.get("window")
    if kw.get("debug_visits"):
        out, visits = got
        return (np.asarray(out), np.asarray(visits),
                _verify_reference(q, kq, vq, lens, window))
    return np.asarray(got), _verify_reference(q, kq, vq, lens, window)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_verify_matches_einsum_reference(fmt, block_size):
    rng = np.random.default_rng(31)
    lens = np.array([61, 23], np.int32)
    got, want = _verify_case(fmt, block_size, b=2, kvh=2, g=2, d=64, t=64,
                             ps=16, tq=4, lens=lens, rng=rng)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("tq", [1, 2, 3, 4, 5])
def test_verify_every_chunk_length(tq):
    """Chunk lengths 1..K: the per-row causal mask must be exact at every
    draft count the engine can run, including the Tq == 1 decode case."""
    rng = np.random.default_rng(37)
    lens = np.array([29, 40, tq], np.int32)  # incl. a chunk-only sequence
    got, want = _verify_case("fp8_e4m3", 32, b=3, kvh=2, g=2, d=32, t=40,
                             ps=8, tq=tq, lens=lens, rng=rng)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp4_e2m1"])
@pytest.mark.parametrize(
    "lens",
    [np.array([18, 33], np.int32),   # chunk straddles a page boundary
     np.array([16, 32], np.int32),   # chunk ends exactly on a boundary
     np.array([4, 20], np.int32),    # chunk is the whole first page tail
     np.array([64, 50], np.int32)],  # fully-packed table / interior
    ids=["straddle", "boundary-end", "first-page", "packed"])
def test_verify_page_boundary_straddling_chunks(fmt, lens):
    """A verify chunk whose tokens span two pages: rows of the same chunk
    live in different page tiles and the online softmax must stitch them
    per query row."""
    rng = np.random.default_rng(41)
    got, want = _verify_case(fmt, 32, b=2, kvh=2, g=2, d=64, t=64, ps=16,
                             tq=4, lens=lens, rng=rng)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


def test_verify_sliding_window_matches_masked_reference():
    rng = np.random.default_rng(43)
    lens = np.array([61, 30], np.int32)
    got, want = _verify_case("fp8_e4m3", 32, b=2, kvh=2, g=2, d=64, t=64,
                             ps=16, tq=3, lens=lens, rng=rng, window=12)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


def test_verify_visits_exactly_the_resident_pages():
    """The page-skip audit holds for multi-query chunks too: visits per
    (batch, kv-head) cell == ceil(seq_len / PS), independent of Tq."""
    rng = np.random.default_rng(47)
    lens = np.array([3, 17, 40], np.int32)
    got, visits, want = _verify_case(
        "fp8_e4m3", 32, b=3, kvh=2, g=2, d=32, t=40, ps=8, tq=3,
        lens=lens, rng=rng, debug_visits=True)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)
    expect = np.broadcast_to(np.ceil(lens / 8).astype(np.int32)[:, None],
                             (3, 2))
    np.testing.assert_array_equal(visits[:, :, 0], expect)


def test_fused_window_head_pages_skipped_exactly():
    """Sliding-window head skip audit: pages wholly below the query's
    window must not execute (visits == pages actually inside the
    window), and the output must equal the masked reference — too few
    visits would drop in-window context, too many means the head DMA
    and dequant work came back."""
    rng = np.random.default_rng(61)
    b, kvh, g, d, t, ps, window = 3, 2, 2, 64, 64, 8, 10
    q = jnp.asarray(rng.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    lens = np.array([64, 41, 7], np.int32)  # deep, mid, shorter-than-window
    got, visits = mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), window=window, debug_visits=True)
    first = np.maximum((lens - 1 - window + 1) // ps, 0)
    want_visits = np.ceil(lens / ps).astype(np.int32) - first
    np.testing.assert_array_equal(
        np.asarray(visits)[:, :, 0],
        np.broadcast_to(want_visits[:, None], (b, kvh)))
    kd = np.asarray(kq.dequantize(jnp.float32))
    vd = np.asarray(vq.dequantize(jnp.float32))
    for i in range(b):
        pos = int(lens[i]) - 1
        lo = max(0, pos - window + 1)
        s = np.einsum("kgd,ktd->kgt", np.asarray(q[i], np.float32),
                      kd[i, :, lo:pos + 1]) * d ** -0.5
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        want = np.einsum("kgt,ktd->kgd", p, vd[i, :, lo:pos + 1])
        np.testing.assert_allclose(np.asarray(got)[i], want, atol=1e-5,
                                   rtol=0)


def test_verify_window_head_pages_skipped_exactly():
    """The multi-query chunk's head skip is bounded by the *oldest*
    query: visits == ceil(len/PS) - max(0, (len - Tq - W + 1) // PS),
    and every row still matches the per-row masked oracle."""
    rng = np.random.default_rng(67)
    tq, ps, window = 3, 8, 10
    lens = np.array([62, 30, 11], np.int32)
    got, visits, want = _verify_case(
        "fp8_e4m3", 32, b=3, kvh=2, g=2, d=64, t=64, ps=ps, tq=tq,
        lens=lens, rng=rng, window=window, debug_visits=True)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)
    first = np.maximum((lens - tq - window + 1) // ps, 0)
    expect = np.ceil(lens / ps).astype(np.int32) - first
    np.testing.assert_array_equal(
        visits[:, :, 0], np.broadcast_to(expect[:, None], (3, 2)))


def test_verify_tq1_is_bitwise_the_decode_kernel():
    """decode_fused is the Tq == 1 case of verify_fused by delegation;
    pin that equivalence bit-for-bit so the two can never drift."""
    rng = np.random.default_rng(53)
    b, kvh, g, d, t, ps = 2, 2, 2, 64, 64, 16
    q = jnp.asarray(rng.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    lens = jnp.asarray([61, 17], jnp.int32)
    dec = np.asarray(mx_attention_decode_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table, lens))
    ver = np.asarray(mx_attention_verify_fused(
        q[:, :, None], pools["ke"], pools["ks"], pools["ve"], pools["vs"],
        table, lens))[:, :, 0]
    np.testing.assert_array_equal(dec.view(np.uint32), ver.view(np.uint32))


def test_verify_rejected_region_never_contributes():
    """Rows past seq_len hold garbage (e.g. rejected speculated K/V from
    an earlier, longer chunk): flipping the garbage pages' ids to -1 must
    not change any query row's output — the rollback-by-truncation
    guarantee at the kernel level."""
    rng = np.random.default_rng(59)
    b, kvh, g, d, t, ps, tq = 1, 2, 2, 32, 32, 8, 3
    q = jnp.asarray(rng.normal(size=(b, kvh, tq, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        rng.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, rng)
    seq_len = jnp.asarray([ps + 3], jnp.int32)  # only the first 2 pages valid
    base = np.asarray(mx_attention_verify_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        seq_len))
    table2 = np.asarray(table).copy()
    table2[0, 2:] = -1
    got = np.asarray(mx_attention_verify_fused(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"],
        jnp.asarray(table2), seq_len))
    np.testing.assert_array_equal(got.view(np.uint32), base.view(np.uint32))
