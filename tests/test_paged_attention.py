"""Paged MX decode attention: page-table gather vs contiguous, bit-exact.

The paged kernel gathers compact K/V tiles through the page table and then
runs the identical attention kernel, so paged and contiguous caches must
agree to the bit in interpret mode — any mismatch means the page plumbing
(table indexing, clamping, masking) is wrong, not the float math.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize
from repro.kernels import (gather_kv_pages, mx_attention_decode,
                           mx_attention_decode_paged)

RNG = np.random.default_rng(123)


def _paged_layout(kq, vq, b, kvh, t, ps, rng):
    """Scatter a contiguous (B, KVH, T, ·) cache into a shuffled page pool."""
    npg = t // ps
    pool_pages = b * npg + 3  # spare pages stay garbage (must be masked)
    perm = rng.permutation(pool_pages)[: b * npg]
    table = perm.reshape(b, npg).astype(np.int32)
    arrs = {}
    for name, src in [("ke", kq.elements), ("ks", kq.scales),
                      ("ve", vq.elements), ("vs", vq.scales)]:
        src = np.asarray(src)
        pool = np.full((pool_pages, ps, kvh, src.shape[-1]), 255,
                       dtype=src.dtype if src.dtype != np.uint8 else np.uint8)
        if pool.dtype != np.uint8:
            pool[:] = 0
        for i in range(b):
            for p in range(npg):
                pool[table[i, p]] = src[i, :, p * ps:(p + 1) * ps].transpose(
                    1, 0, 2)
        arrs[name] = jnp.asarray(pool)
    return arrs, jnp.asarray(table)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_paged_matches_contiguous_bit_exact(fmt, block_size):
    b, kvh, g, d, t, ps = 2, 2, 2, 64, 64, 16
    q = jnp.asarray(RNG.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), fmt, block_size)
    lens = np.array([t - 3, t - 17], np.int32)

    want = []
    for i in range(b):
        kpos = jnp.where(jnp.arange(t) < lens[i], jnp.arange(t),
                         -1).astype(jnp.int32)
        want.append(np.asarray(mx_attention_decode(
            q[i:i + 1], kq.elements[i:i + 1], kq.scales[i:i + 1],
            vq.elements[i:i + 1], vq.scales[i:i + 1], kpos,
            int(lens[i]) - 1, block_size=block_size)))
    want = np.concatenate(want, axis=0)

    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, RNG)
    got = np.asarray(mx_attention_decode_paged(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        jnp.asarray(lens), block_size=block_size))
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))


def test_gather_kv_pages_reorders_exactly():
    b, kvh, t, d, ps = 2, 3, 32, 32, 8
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, RNG)
    ke, ks, ve, vs = gather_kv_pages(pools["ke"], pools["ks"], pools["ve"],
                                     pools["vs"], table)
    np.testing.assert_array_equal(
        np.asarray(ke).astype(np.float32),
        np.asarray(kq.elements).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(kq.scales))
    np.testing.assert_array_equal(
        np.asarray(ve).astype(np.float32),
        np.asarray(vq.elements).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vq.scales))


def test_unallocated_table_entries_never_contribute():
    """Rows past seq_len come from clamped/garbage pages; outputs must not
    depend on their contents."""
    b, kvh, g, d, t, ps = 1, 2, 2, 32, 32, 8
    q = jnp.asarray(RNG.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    pools, table = _paged_layout(kq, vq, b, kvh, t, ps, RNG)
    seq_len = jnp.asarray([ps + 3], jnp.int32)  # only the first 2 pages valid
    base = np.asarray(mx_attention_decode_paged(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"], table,
        seq_len))
    table2 = np.asarray(table).copy()
    table2[0, 2:] = -1  # drop the unallocated tail entirely
    got = np.asarray(mx_attention_decode_paged(
        q, pools["ke"], pools["ks"], pools["ve"], pools["vs"],
        jnp.asarray(table2), seq_len))
    np.testing.assert_array_equal(got.view(np.uint32), base.view(np.uint32))


def test_contiguous_kernel_per_sequence_positions():
    """(B,) pos / (B, T) kpos rows must equal per-row scalar calls."""
    b, kvh, g, d, t = 3, 2, 2, 32, 48
    q = jnp.asarray(RNG.normal(size=(b, kvh, g, d)).astype(np.float32))
    kq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    vq = quantize(jnp.asarray(
        RNG.normal(size=(b, kvh, t, d)).astype(np.float32)), "fp8_e4m3", 32)
    lens = np.array([10, 48, 33], np.int32)
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    got = np.asarray(mx_attention_decode(
        q, kq.elements, kq.scales, vq.elements, vq.scales, kpos,
        jnp.asarray(lens) - 1))
    for i in range(b):
        want = np.asarray(mx_attention_decode(
            q[i:i + 1], kq.elements[i:i + 1], kq.scales[i:i + 1],
            vq.elements[i:i + 1], vq.scales[i:i + 1],
            jnp.arange(t, dtype=jnp.int32), int(lens[i]) - 1))
        np.testing.assert_array_equal(got[i:i + 1].view(np.uint32),
                                      want.view(np.uint32))
