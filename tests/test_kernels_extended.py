"""Extended kernel coverage: MX-KV-cache decode attention + dgrad kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize
from repro.kernels import ref as R
from repro.kernels.mx_attention import mx_attention_decode
from repro.kernels.mx_matmul import mx_matmul_dgrad

RNG = np.random.default_rng(77)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# mx_attention_decode (serving: wide q x MX cache, vector-scalar family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("b,kvh,g,d,t", [(1, 2, 1, 32, 64), (2, 4, 3, 64, 128),
                                         (1, 8, 2, 128, 256)])
def test_mx_attention_decode_vs_oracle(fmt, b, kvh, g, d, t):
    q = _rand((b, kvh, g, d))
    kq = quantize(_rand((b, kvh, t, d)), fmt, 32)
    vq = quantize(_rand((b, kvh, t, d)), fmt, 32)
    valid = t - 7
    kpos = jnp.where(jnp.arange(t) < valid, jnp.arange(t), -1).astype(jnp.int32)
    pos = valid - 1
    got = mx_attention_decode(q, kq.elements, kq.scales, vq.elements,
                              vq.scales, kpos, pos, block_size=32)
    want = R.mx_attention_decode_ref(q, kq.elements, kq.scales, vq.elements,
                                     vq.scales, kpos, pos, fmt=fmt,
                                     block_size=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_mx_attention_decode_masks_empty_and_future_slots():
    """Changing masked-out cache slots must not change the output."""
    b, kvh, g, d, t = 1, 2, 2, 32, 64
    q = _rand((b, kvh, g, d))
    k = np.asarray(_rand((b, kvh, t, d)))
    v = np.asarray(_rand((b, kvh, t, d)))
    kpos = jnp.where(jnp.arange(t) < 20, jnp.arange(t), -1).astype(jnp.int32)
    pos = 19

    def run(karr, varr):
        kq = quantize(jnp.asarray(karr), "fp8_e4m3", 32)
        vq = quantize(jnp.asarray(varr), "fp8_e4m3", 32)
        return np.asarray(mx_attention_decode(
            q, kq.elements, kq.scales, vq.elements, vq.scales, kpos, pos))

    base = run(k, v)
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 20:] = 99.0  # garbage in empty slots
    v2[:, :, 20:] = -99.0
    np.testing.assert_allclose(run(k2, v2), base, rtol=1e-6, atol=1e-6)


def test_mx_attention_softcap():
    b, kvh, g, d, t = 1, 1, 1, 32, 32
    q = _rand((b, kvh, g, d), 5.0)
    kq = quantize(_rand((b, kvh, t, d), 5.0), "fp8_e4m3", 32)
    vq = quantize(_rand((b, kvh, t, d)), "fp8_e4m3", 32)
    kpos = jnp.arange(t, dtype=jnp.int32)
    got = mx_attention_decode(q, kq.elements, kq.scales, vq.elements,
                              vq.scales, kpos, t - 1, softcap=50.0)
    want = R.mx_attention_decode_ref(q, kq.elements, kq.scales, vq.elements,
                                     vq.scales, kpos, t - 1, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mx_matmul_dgrad (training backward through MX weights)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("m,k,n", [(8, 64, 32), (64, 512, 96),
                                   (128, 256, 128)])
def test_mx_dgrad_vs_dequant_reference(fmt, m, k, n):
    w = _rand((k, n))
    dy = _rand((m, n))
    wq = quantize(w, fmt, 32, axis=0)
    got = np.asarray(mx_matmul_dgrad(dy, wq.elements, wq.scales,
                                     fmt_name=fmt, interpret=True))
    want = np.asarray(dy) @ np.asarray(wq.dequantize()).T
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_size", [8, 32, 64])
def test_mx_dgrad_block_sizes(block_size):
    w = _rand((256, 64))
    dy = _rand((32, 64))
    wq = quantize(w, "fp8_e4m3", block_size, axis=0)
    got = np.asarray(mx_matmul_dgrad(dy, wq.elements, wq.scales,
                                     fmt_name="fp8_e4m3",
                                     block_size=block_size, interpret=True))
    want = np.asarray(dy) @ np.asarray(wq.dequantize()).T
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_trainable_path_uses_native_dgrad_end_to_end():
    from repro.kernels import mx_matmul, mx_matmul_trainable

    x = _rand((16, 64))
    wq = quantize(_rand((64, 16)), "fp8_e4m3", 32, axis=0)

    def loss(x):
        return jnp.sum(
            mx_matmul_trainable(x, wq, "fp8_e4m3", 32, jnp.float32) ** 2)

    g = jax.grad(loss)(x)
    y = mx_matmul(x, wq)
    expect = 2.0 * np.asarray(y) @ np.asarray(wq.dequantize()).T
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-4)
