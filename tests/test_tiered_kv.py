"""Tiered mixed-format KV cache: repack kernel bit-exactness + engine
format-lifecycle correctness.

The load-bearing claims:

  * the Pallas repack kernel's narrow re-encode is bit-identical to a
    host decode -> ``core.quantize``-math re-encode of the same rows,
    leaves untouched pages byte-identical, zeroes dead tail bytes, and
    handles mixed source formats + padded page lists;
  * widening (the COW promote path) is lossless: fp4 -> fp8 repack
    decodes to exactly the fp4 values;
  * a tiered engine with the repack budget at zero is token-identical to
    the plain all-fp8 engine under churn (preemption pressure, prefix
    sharing, speculative decoding) — the unit-metered pool and format
    plumbing alone change nothing;
  * an aggressive tiering policy keeps its invariants under churn:
    per-step repack stays under budget, the unit accounting matches the
    per-page format census, and the engine is deterministic;
  * swap-out/restore preserves narrow page formats: a preempted
    sequence whose pages were already repacked resumes bit-identically
    to the same run without the preemption.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.kernels import mx_repack_pages
from repro.kernels.mx_attention import _quantize_rows
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import ContinuousBatchingEngine, ServeConfig, TierPolicy
from repro.serve.engine import _FMT_BITS
from repro.serve.kv_cache import UNITS_BY_BITS

MIXED = ("fp8_e4m3", "fp6_e3m2", "fp4_e2m1")


# ---------------------------------------------------------------------------
# repack kernel vs host oracle
# ---------------------------------------------------------------------------


def _host_decode(rows_bytes, scales, fmt_name, bs):
    """(PS, D) stored bytes + E8M0 scales -> (PS, D) f32, via the public
    formats API (independent of the kernel's in-Pallas decode)."""
    fmt = F.get_format(fmt_name)
    d = rows_bytes.shape[-1]
    stored = jnp.asarray(rows_bytes[..., : fmt.storage_len(d)])
    if fmt.bits == 8:
        stored = jax.lax.bitcast_convert_type(stored, fmt.storage_dtype)
    vals = F.decode_elements(stored, fmt_name)
    nb = d // bs
    s = F.e8m0_to_scale(jnp.asarray(scales))
    return np.asarray(
        (vals.reshape(-1, nb, bs) * s[..., None]).reshape(-1, d))


def _host_requant(rows_bytes, scales, src_fmt, dst_fmt, bs):
    """Decode + re-encode on the host: the repack oracle."""
    wide = _host_decode(rows_bytes, scales, src_fmt, bs)
    q_e, q_s = _quantize_rows(jnp.asarray(wide), dst_fmt, bs)
    if F.get_format(dst_fmt).bits == 8:
        q_e = jax.lax.bitcast_convert_type(q_e, jnp.uint8)
    return np.asarray(q_e), np.asarray(q_s)


def _fresh_pools(rng, npages=6, ps=4, kvh=2, d=32, bs=16):
    """uint8 tiered pools with every page holding fp8-encoded content."""
    nb = d // bs
    ke = np.zeros((npages, ps, kvh, d), np.uint8)
    ks = np.zeros((npages, ps, kvh, nb), np.uint8)
    ve = np.zeros_like(ke)
    vs = np.zeros_like(ks)
    for elems, sc in ((ke, ks), (ve, vs)):
        for p in range(npages):
            for h in range(kvh):
                wide = rng.normal(size=(ps, d)).astype(np.float32) * 3.0
                q_e, q_s = _quantize_rows(jnp.asarray(wide), "fp8_e4m3", bs)
                elems[p, :, h, :] = np.asarray(
                    jax.lax.bitcast_convert_type(q_e, jnp.uint8))
                sc[p, :, h, :] = np.asarray(q_s)
    return tuple(jnp.asarray(a) for a in (ke, ks, ve, vs)), bs


def _repack(pools, ids, fmts, count, dst, bs, nlist=4):
    ids = ids + [ids[-1]] * (nlist - len(ids))
    fmts = fmts + [fmts[-1]] * (nlist - len(fmts))
    return mx_repack_pages(
        *pools, jnp.asarray(ids, jnp.int32), jnp.asarray(fmts, jnp.int32),
        jnp.asarray(count, jnp.int32), dst_fmt_name=dst, mixed_fmts=MIXED,
        block_size=bs)


@pytest.mark.parametrize("dst", ["fp6_e3m2", "fp6_e2m3", "fp4_e2m1"])
def test_repack_kernel_matches_host_requant(dst):
    pools, bs = _fresh_pools(np.random.default_rng(0))
    before = [np.asarray(a) for a in pools]
    out = [np.asarray(a) for a in _repack(pools, [1, 3], [0, 0], 2, dst, bs)]
    w = F.get_format(dst).storage_len(before[0].shape[-1])
    for p in range(before[0].shape[0]):
        for h in range(before[0].shape[2]):
            for e_i, s_i in ((0, 1), (2, 3)):
                got_e, got_s = out[e_i][p, :, h, :], out[s_i][p, :, h, :]
                if p in (1, 3):
                    want_e, want_s = _host_requant(
                        before[e_i][p, :, h, :], before[s_i][p, :, h, :],
                        "fp8_e4m3", dst, bs)
                    np.testing.assert_array_equal(got_e[:, :w], want_e)
                    np.testing.assert_array_equal(got_e[:, w:], 0)
                    np.testing.assert_array_equal(got_s, want_s)
                else:  # untouched pages stay byte-identical
                    np.testing.assert_array_equal(got_e,
                                                  before[e_i][p, :, h, :])
                    np.testing.assert_array_equal(got_s,
                                                  before[s_i][p, :, h, :])


def test_repack_kernel_mixed_source_formats():
    """One call can repack pages whose *sources* differ (fp6 and fp8
    both heading to fp4) — the per-page format id rides scalar prefetch."""
    pools, bs = _fresh_pools(np.random.default_rng(1))
    pools = _repack(pools, [3], [0], 1, "fp6_e3m2", bs)
    mid = [np.asarray(a) for a in pools]
    out = [np.asarray(a) for a in _repack(
        pools, [3, 4], [F.FORMAT_IDS["fp6_e3m2"], 0], 2, "fp4_e2m1", bs)]
    w = F.get_format("fp4_e2m1").storage_len(mid[0].shape[-1])
    for p, src in ((3, "fp6_e3m2"), (4, "fp8_e4m3")):
        for h in range(mid[0].shape[2]):
            for e_i, s_i in ((0, 1), (2, 3)):
                want_e, want_s = _host_requant(
                    mid[e_i][p, :, h, :], mid[s_i][p, :, h, :], src,
                    "fp4_e2m1", bs)
                np.testing.assert_array_equal(out[e_i][p, :, h, :w], want_e)
                np.testing.assert_array_equal(out[e_i][p, :, h, w:], 0)
                np.testing.assert_array_equal(out[s_i][p, :, h, :], want_s)


def test_repack_widening_is_lossless():
    """The COW promote path: fp4 -> fp8 re-encode must decode to exactly
    the fp4 values (every fp4 grid point is on the fp8 grid)."""
    pools, bs = _fresh_pools(np.random.default_rng(2))
    pools = _repack(pools, [2], [0], 1, "fp4_e2m1", bs)
    narrow = [np.asarray(a) for a in pools]
    out = [np.asarray(a) for a in _repack(
        pools, [2], [F.FORMAT_IDS["fp4_e2m1"]], 1, "fp8_e4m3", bs)]
    for h in range(narrow[0].shape[2]):
        for e_i, s_i in ((0, 1), (2, 3)):
            want = _host_decode(narrow[e_i][2, :, h, :],
                                narrow[s_i][2, :, h, :], "fp4_e2m1", bs)
            got = _host_decode(out[e_i][2, :, h, :], out[s_i][2, :, h, :],
                               "fp8_e4m3", bs)
            np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# engine: format lifecycle under churn
# ---------------------------------------------------------------------------


def _cfg(quant=None):
    from repro.core import MXFP8

    quant = MXFP8 if quant is None else quant
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=quant.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=True))


def _churn_reqs(rng, n=6):
    """Shared-head + ragged tails: prefix sharing, page straddling."""
    head = rng.integers(0, 128, (16,)).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, 128, (3 + 5 * (i % 3),)).astype(np.int32)
        prompt = np.concatenate([head, tail]) if i % 2 else tail
        reqs.append((prompt, 6))
    return reqs


def _serve(params, cfg, reqs, **kw):
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=48, max_slots=2, page_size=8, decode_kernel="fused",
        prefill_chunk=8, **kw))
    ids = [eng.submit(p, m) for p, m in reqs]
    out = eng.run()
    return [out[i] for i in ids], eng


@pytest.mark.parametrize("spec", [False, True], ids=["decode", "spec"])
def test_tiered_repack_disabled_token_identical_under_churn(spec):
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    reqs = _churn_reqs(np.random.default_rng(5))
    kw = dict(num_pages=14)  # tight: forces eviction/preemption pressure
    if spec:
        kw.update(spec_decode=True, num_draft_tokens=3)
    want, base = _serve(params, cfg, reqs, **kw)
    got, tier = _serve(params, cfg, reqs, tiered=True,
                       tier_policy=TierPolicy(repack_pages_per_step=0),
                       **kw)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)
    assert tier.cache_stats()["repacked_pages"] == 0


def test_tiered_requires_fp8_base_and_fp4_only_engine_still_serves():
    """The fp4-only corner of the format matrix: tiering over an fp4
    base is rejected loudly (new writes must land full-width — there is
    no narrower tier to demote to), while the plain all-fp4 engine
    serves the same churn workload to completion deterministically."""
    from repro.core import MXFP4

    cfg = _cfg(MXFP4)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    reqs = _churn_reqs(np.random.default_rng(5))
    with pytest.raises(ValueError, match="8-bit base"):
        _serve(params, cfg, reqs, num_pages=14, tiered=True,
               tier_policy=TierPolicy(repack_pages_per_step=0))
    out1, eng = _serve(params, cfg, reqs, num_pages=14)
    assert all(len(g) == len(p) + m for g, (p, m) in zip(out1, reqs))
    out2, _ = _serve(params, cfg, reqs, num_pages=14)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)


def _census_units(eng):
    pool = eng.scheduler.pool
    return sum(
        UNITS_BY_BITS[_FMT_BITS[F.FORMAT_BY_ID[int(eng.page_fmts[pid])]]]
        for pid in range(eng.num_pages) if pool.ref(pid) > 0)


def test_tiered_aggressive_churn_invariants():
    """Mixed-format churn: pages demote while requests come and go. The
    accounting invariants must hold and the run must be deterministic."""
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    reqs = _churn_reqs(np.random.default_rng(9), n=8)
    policy = TierPolicy(hot_steps=1, cold_steps=3, repack_pages_per_step=3)
    out1, eng = _serve(params, cfg, reqs, num_pages=14, tiered=True,
                       tier_policy=policy)
    stats = eng.cache_stats()
    assert stats["repacked_pages"] > 0
    assert stats["max_repacked_in_step"] <= policy.repack_pages_per_step
    # unit metering == per-page format census, and narrow pages exist
    assert _census_units(eng) == eng.scheduler.pool.units_in_use
    assert all(int(f) in F.FORMAT_BY_ID for f in eng.page_fmts)
    for p, m in reqs:  # greedy, no EOS: every request runs to max_new
        pass
    assert all(len(g) == len(p) + m for g, (p, m) in zip(out1, reqs))
    out2, _ = _serve(params, cfg, reqs, num_pages=14, tiered=True,
                     tier_policy=policy)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)


def test_trash_page_outside_tiering_and_census():
    """The ragged step appends ONE trash page past the schedulable pool
    (pid == num_pages) as the sink for masked-lane K/V writes. It is
    never allocated, never ages, never demotes, and never appears in
    the per-format census — an off-by-one in any geometry consumer
    (repack scan, stats census, pool bounds) would surface here."""
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    reqs = _churn_reqs(np.random.default_rng(9), n=8)
    policy = TierPolicy(hot_steps=1, cold_steps=2, repack_pages_per_step=4)
    _, eng = _serve(params, cfg, reqs, num_pages=14, tiered=True,
                    tier_policy=policy)
    assert eng.ragged and eng._trash_pages == 1
    stats = eng.cache_stats()
    assert stats["repacked_pages"] > 0
    # the trash page sits at pid == num_pages (tiering doubles the
    # schedulable pool first, so num_pages here is the doubled count)
    trash = eng.num_pages
    assert len(eng.page_fmts) == eng.num_pages + 1
    assert int(eng.page_fmts[trash]) == eng._base_fmt_id, \
        "trash page was demoted/repacked"
    # it is not schedulable: the pool's bounds stop short of it
    pool = eng.scheduler.pool
    with pytest.raises(ValueError, match="unknown page"):
        pool.ref(trash)
    # census over schedulable pages only == unit metering
    assert _census_units(eng) == pool.units_in_use
    assert sum(stats[f"pages_{f}"] for f in eng._mixed_fmts) == \
        sum(1 for pid in range(eng.num_pages) if pool.ref(pid) > 0)
    # pool byte accounting covers the trash page exactly once
    from repro.serve.kv_cache import pool_page_nbytes
    assert stats["page_bytes"] == pool_page_nbytes(
        eng.cache, eng.num_pages + 1)


def test_swap_restore_preserves_narrow_page_formats():
    """A sequence whose prompt pages already demoted is preempted and
    restored; generation must continue exactly as if the preemption
    never happened (raw bytes AND format ids both survive the swap)."""
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(21).integers(0, 128, (24,)) \
        .astype(np.int32)

    def drive(force_swap):
        # no prefix tree: the sequence OWNS every page, so the swap
        # blob (not the tree) must carry the narrow format ids across
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=64, max_slots=2, page_size=8, decode_kernel="fused",
            prefill_chunk=8, prefix_cache=False, tiered=True,
            tier_policy=TierPolicy(hot_steps=1, cold_steps=2,
                                   repack_pages_per_step=8)))
        rid = eng.submit(prompt, 24)
        frozen = saved = None
        while True:
            more = eng.step()
            seq = next((s for s in eng.scheduler.slots
                        if s is not None and s.req.id == rid), None)
            if (frozen is None and seq is not None
                    and seq.prefill_pos is None
                    and any(int(eng.page_fmts[p]) != eng._base_fmt_id
                            for p in seq.pages)):
                # freeze the tiers at a deterministic point (both runs
                # reach it at the same step) so the only difference
                # between the runs is the forced preemption itself
                frozen = eng.tier = dataclasses.replace(
                    eng.tier, repack_pages_per_step=0)
                if force_swap:
                    eng._swap_out(seq)
                    saved = list(eng._swap_fmts[rid])
            if not more:
                break
        assert frozen is not None, "no page demoted before completion"
        out = next(r for r in eng.scheduler.finished if r.id == rid)
        return np.asarray(out.generated), saved

    want, _ = drive(force_swap=False)
    got, saved = drive(force_swap=True)
    assert saved is not None and any(
        fid != F.FORMAT_IDS["fp8_e4m3"] for fid in saved), \
        "forced swap captured no narrow page (test setup drifted)"
    np.testing.assert_array_equal(got, want)
