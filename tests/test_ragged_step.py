"""One-dispatch ragged engine step: kernel + engine identity matrix.

The ragged kernel (`mx_attention_ragged_fused`) must be *bit-identical*
to the split-dispatch oracle it replaces, at both layers:

  * kernel level — a ragged row whose write window was pre-written
    host-side (exact `core.quantize` math) and then verified with
    `mx_attention_verify_fused` must match the ragged kernel's output
    AND its in-kernel written pool bytes, across fp8 e4m3/e5m2 + fp4
    and block sizes 16/32/64;
  * engine level — `step_mode="ragged"` must emit the same per-request
    token streams as `step_mode="split"` (the validated oracle) under
    churn, preemption, speculative decoding, chunked prefill, tiering,
    and prefix sharing — while running exactly ONE device dispatch per
    steady-state mixed step.

Plus the structural guarantee: one `pallas_call` per engine step layer
and no pool-shaped scatter (`.at[].set` K/V write) on the ragged path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP8, quantize
from repro.kernels import (mx_attention_ragged_fused,
                           mx_attention_verify_fused)
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import ContinuousBatchingEngine, ServeConfig


# ---------------------------------------------------------------------------
# kernel-level identity: ragged row == host-write + verify oracle
# ---------------------------------------------------------------------------


def _scatter_rows(pool, table_row, quant, lo, hi, ps):
    """Write contiguous token rows [lo, hi) of one sequence into `pool`.

    quant.elements/.scales are (KVH, T, ·); pool pages are (PS, KVH, ·).
    """
    el = np.asarray(quant.elements)
    sc = np.asarray(quant.scales)
    ke, ks = pool
    for t in range(lo, hi):
        pg = table_row[t // ps]
        ke[pg, t % ps] = el[:, t]
        ks[pg, t % ps] = sc[:, t]


def _ragged_case(fmt, block_size, d=64, g=2, kvh=2, ps=8, seed=101):
    """Three coexisting row modes against per-row verify oracles.

    Row 0: plain decode (n_new=1, mid-page start). Row 1: verify window
    (n_new=3, straddling a page boundary). Row 2: fresh prefill chunk
    (n_new=W from row 0). Row 3: continuation chunk with an unaligned,
    mid-page start — the case the aligned prefill kernel cannot run.
    """
    rng = np.random.default_rng(seed)
    w = 8
    starts = [13, 9, 0, 12]
    n_news = [1, 3, w, w]
    r = len(starts)
    totals = [s + n for s, n in zip(starts, n_news)]
    pages_per = [-(-t // ps) for t in totals]
    npages = sum(pages_per) + 3  # spare + trash page (last)
    pmax = max(pages_per) + 1    # room for a -1 tail entry
    perm = rng.permutation(npages - 1)  # never hand out the trash page
    table = np.full((r, pmax), -1, np.int32)
    off = 0
    for i, npg in enumerate(pages_per):
        table[i, :npg] = perm[off:off + npg]
        off += npg

    # decoy codes everywhere: garbage pages must never contribute and
    # unwritten rows of written pages must survive the merge untouched
    def _pool_from(cache):
        q_ = quantize(jnp.asarray(cache), fmt, block_size)
        el = np.asarray(q_.elements).reshape(kvh, npages, ps, -1)
        sc = np.asarray(q_.scales).reshape(kvh, npages, ps, -1)
        return (np.ascontiguousarray(el.transpose(1, 2, 0, 3)),
                np.ascontiguousarray(sc.transpose(1, 2, 0, 3)))

    decoy = rng.normal(size=(kvh, npages * ps, d)).astype(np.float32)
    ke0, ks0 = _pool_from(decoy)
    ve0, vs0 = _pool_from(decoy[:, ::-1])

    # per-row contiguous wide caches; quantize row-wise (block along D) —
    # identical math whether done in one batch or token-by-token
    caches = [(rng.normal(size=(kvh, t, d)).astype(np.float32),
               rng.normal(size=(kvh, t, d)).astype(np.float32))
              for t in totals]
    kq = [quantize(jnp.asarray(kc), fmt, block_size) for kc, _ in caches]
    vq = [quantize(jnp.asarray(vc), fmt, block_size) for _, vc in caches]

    # want pool: every token row host-written; input pool: only the
    # resident prefix [0, start) — the ragged kernel must produce the
    # missing window bytes itself
    want = [a.copy() for a in (ke0, ks0, ve0, vs0)]
    have = [a.copy() for a in (ke0, ks0, ve0, vs0)]
    for i in range(r):
        _scatter_rows((want[0], want[1]), table[i], kq[i], 0, totals[i], ps)
        _scatter_rows((want[2], want[3]), table[i], vq[i], 0, totals[i], ps)
        _scatter_rows((have[0], have[1]), table[i], kq[i], 0, starts[i], ps)
        _scatter_rows((have[2], have[3]), table[i], vq[i], 0, starts[i], ps)

    q = rng.normal(size=(r, kvh, w, g, d)).astype(np.float32)
    k_new = rng.normal(size=(r, w, kvh, d)).astype(np.float32)  # padding
    v_new = rng.normal(size=(r, w, kvh, d)).astype(np.float32)
    for i in range(r):
        for t in range(n_news[i]):
            k_new[i, t] = caches[i][0][:, starts[i] + t]
            v_new[i, t] = caches[i][1][:, starts[i] + t]

    out, pools, visits = mx_attention_ragged_fused(
        jnp.asarray(q), jnp.asarray(k_new), jnp.asarray(v_new),
        *(jnp.asarray(a) for a in have), jnp.asarray(table),
        jnp.asarray(starts, jnp.int32), jnp.asarray(totals, jnp.int32),
        fmt_name=fmt, block_size=block_size, debug_visits=True)
    return (np.asarray(out), [np.asarray(p) for p in pools],
            np.asarray(visits), want, have, q, table, starts, n_news,
            totals, ps)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"])
@pytest.mark.parametrize("block_size", [16, 32, 64])
def test_ragged_kernel_bit_matches_split_oracle(fmt, block_size):
    (out, pools, visits, want, have, q, table, starts, n_news, totals,
     ps) = _ragged_case(fmt, block_size)

    # 1) in-kernel written pool bytes == host core.quantize writes, and
    #    rows the step does not own keep their exact old codes
    for i in range(len(starts)):
        for t in range(totals[i]):
            pg, prow = table[i, t // ps], t % ps
            for got, exp in zip(pools, want):
                np.testing.assert_array_equal(
                    got[pg, prow].view(np.uint8),
                    exp[pg, prow].view(np.uint8))
    owned = {int(table[i, p]) for i in range(len(starts))
             for p in range(starts[i] // ps, -(-totals[i] // ps))}
    for pg in range(pools[0].shape[0]):
        if pg in owned:
            continue
        for got, old in zip(pools, have):
            np.testing.assert_array_equal(got[pg].view(np.uint8),
                                          old[pg].view(np.uint8))

    # 2) attention output bit-matches the split verify kernel reading the
    #    host-written pool (same page walk, same flash accumulation)
    for i in range(len(starts)):
        n = n_news[i]
        ref = np.asarray(mx_attention_verify_fused(
            jnp.asarray(q[i:i + 1, :, :n]),
            *(jnp.asarray(a) for a in want), jnp.asarray(table[i:i + 1]),
            jnp.asarray([totals[i]], jnp.int32),
            fmt_name=fmt, block_size=block_size))
        np.testing.assert_array_equal(
            out[i:i + 1, :, :n].view(np.uint32), ref.view(np.uint32))

    # 3) exact page-visit audit: every page in [0, ceil(total/PS)) and
    #    nothing else
    expect = np.array([-(-t // ps) for t in totals], np.int32)
    np.testing.assert_array_equal(
        visits[:, :, 0], np.broadcast_to(expect[:, None], visits.shape[:2]))


def test_ragged_kernel_head_tiling_at_large_gdim():
    """head_dim 128 x G 8 pushes W*G*D past one flash row tile: the tiled
    `_flash_update` path must stay bit-identical to the verify oracle
    (which shares the same tiling, so this also regression-checks both
    against the f32 einsum reference at kernel tolerance)."""
    (out, pools, visits, want, have, q, table, starts, n_news, totals,
     ps) = _ragged_case("fp8_e4m3", 32, d=128, g=8, kvh=2, seed=131)
    for i in range(len(starts)):
        n = n_news[i]
        ref = np.asarray(mx_attention_verify_fused(
            jnp.asarray(q[i:i + 1, :, :n]),
            *(jnp.asarray(a) for a in want), jnp.asarray(table[i:i + 1]),
            jnp.asarray([totals[i]], jnp.int32),
            fmt_name="fp8_e4m3", block_size=32))
        np.testing.assert_array_equal(
            out[i:i + 1, :, :n].view(np.uint32), ref.view(np.uint32))


def test_ragged_kernel_inactive_rows_only_touch_trash_page():
    """An inactive slot row (start=0, len=1, all -1 table) must write its
    garbage exclusively to the reserved trash page (pool page NP-1)."""
    rng = np.random.default_rng(7)
    kvh, d, ps, w, g = 2, 32, 8, 4, 2
    npages = 5
    decoy = rng.normal(size=(kvh, npages * ps, d)).astype(np.float32)
    qd = quantize(jnp.asarray(decoy), "fp8_e4m3", 32)
    el = np.asarray(qd.elements).reshape(kvh, npages, ps, -1)
    sc = np.asarray(qd.scales).reshape(kvh, npages, ps, -1)
    ke = np.ascontiguousarray(el.transpose(1, 2, 0, 3))
    ks = np.ascontiguousarray(sc.transpose(1, 2, 0, 3))
    pools = [ke, ks, ke.copy(), ks.copy()]
    table = np.full((1, 3), -1, np.int32)
    out, new_pools = mx_attention_ragged_fused(
        jnp.asarray(rng.normal(size=(1, kvh, w, g, d)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(1, w, kvh, d)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(1, w, kvh, d)).astype(np.float32)),
        *(jnp.asarray(a) for a in pools), jnp.asarray(table),
        jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
        fmt_name="fp8_e4m3", block_size=32)
    for got, old in zip(new_pools, pools):
        got = np.asarray(got)
        np.testing.assert_array_equal(got[:-1].view(np.uint8),
                                      old[:-1].view(np.uint8))
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# engine-level identity matrix: ragged vs the split-dispatch oracle
# ---------------------------------------------------------------------------


def _cfg(fmt="fp8_e4m3", block_size=16):
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(fmt=fmt, block_size=block_size,
                            quantize_acts=False, quantize_kv_cache=True))


def _churn_reqs(rng):
    return [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(4, 12), (4, 12), (7, 5), (3, 8)]]


def _run_both(cfg, reqs, **kw):
    outs, engines = {}, {}
    for mode in ("split", "ragged"):
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            step_mode=mode, **kw))
        ids = [eng.submit(p, m) for p, m in reqs]
        out = eng.run()
        outs[mode] = [out[i] for i in ids]
        engines[mode] = eng
    assert engines["ragged"].ragged, "unexpected fallback to split"
    for a, b in zip(outs["split"], outs["ragged"]):
        np.testing.assert_array_equal(a, b)
    return engines


SCENARIOS = {
    "churn-prefix": dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                         prefix_cache=True),
    "chunked": dict(max_seq=48, max_slots=2, page_size=8, prefill_chunk=8),
    "spec": dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                 prefix_cache=True, spec_decode=True, num_draft_tokens=2),
    "spec-chunk": dict(max_seq=48, max_slots=2, page_size=8,
                       prefill_chunk=16, spec_decode=True,
                       num_draft_tokens=3),
    "tiered": dict(max_seq=48, max_slots=2, page_size=8, prefill_chunk=8,
                   num_pages=14, tiered=True),
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_ragged_engine_token_identical(scenario):
    """Mixed batches (decode-only / +verify / +prefill-chunk / all three)
    under churn, preemption, tiering, and prefix sharing: per-request
    streams must equal the split-dispatch oracle exactly."""
    cfg = _cfg()
    reqs = _churn_reqs(np.random.default_rng(3))
    engines = _run_both(cfg, reqs, **SCENARIOS[scenario])
    eng = engines["ragged"]
    if "num_pages" in SCENARIOS[scenario] and not SCENARIOS[scenario].get(
            "tiered"):
        assert eng.scheduler.preemptions >= 1, "pool must force a swap"
    stats = eng.cache_stats()
    if stats["mixed_steps"]:
        assert stats["dispatches_per_mixed_step"] == 1.0, stats


@pytest.mark.parametrize("fmt,block_size",
                         [("fp8_e5m2", 16), ("fp4_e2m1", 16),
                          ("fp8_e4m3", 8)])
def test_ragged_engine_formats(fmt, block_size):
    """KV-format sweep rides the engine too: e5m2 and packed-nibble fp4
    pools must stay token-identical through the in-kernel write path."""
    cfg = _cfg(fmt, block_size)
    reqs = _churn_reqs(np.random.default_rng(9))[:2]
    _run_both(cfg, reqs, max_seq=32, max_slots=2, page_size=4,
              prefill_chunk=4)


def test_ragged_one_dispatch_per_mixed_step():
    """The acceptance gate in test form: a workload built to overlap
    decode with a long multi-chunk prefill must run every mixed step as
    exactly ONE device dispatch — while the split oracle needs >= 2."""
    cfg = _cfg()
    rng = np.random.default_rng(17)
    reqs = [(rng.integers(0, 128, (4,)).astype(np.int32), 8),
            (rng.integers(0, 128, (20,)).astype(np.int32), 4)]
    engines = _run_both(cfg, reqs, max_seq=32, max_slots=2, page_size=4,
                        prefill_chunk=4)
    rs = engines["ragged"].cache_stats()
    ss = engines["split"].cache_stats()
    assert rs["mixed_steps"] >= 2, rs
    assert rs["dispatches_per_mixed_step"] == 1.0, rs
    assert rs["dispatches_ragged"] == rs["dispatches_total"], rs
    assert ss["mixed_steps"] >= 1 and ss["dispatches_per_mixed_step"] >= 2.0
    for key in ("decode", "verify", "prefill", "ragged", "write", "repack"):
        assert f"dispatches_{key}" in rs


# ---------------------------------------------------------------------------
# structural: one pallas_call per step, no pool scatter on the ragged path
# ---------------------------------------------------------------------------


def _subjaxprs(params):
    for v in params.values():
        if isinstance(v, jax.extend.core.ClosedJaxpr):
            yield v.jaxpr
        elif hasattr(v, "eqns"):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.extend.core.ClosedJaxpr):
                    yield x.jaxpr
                elif hasattr(x, "eqns"):
                    yield x


def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from _all_eqns(sub)


def test_ragged_step_jaxpr_one_pallas_call_no_pool_scatter():
    """Trace the engine's actual jitted ragged step on its real argument
    shapes: exactly one `pallas_call` per attention layer (one layer
    here => one total) and no scatter writing a pool-shaped operand —
    the 1-row `.at[].set` K/V write is gone from the ragged path."""
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=2, page_size=4, prefill_chunk=4))
    assert eng.ragged
    captured = {}
    orig = eng._ragged_fn

    def spy(*a, **k):
        captured.setdefault("args", a)
        return orig(*a, **k)

    eng._ragged_fn = spy
    eng.submit(np.arange(5, dtype=np.int32), 3)
    eng.run()
    jaxpr = jax.make_jaxpr(orig)(*captured["args"])

    pool_shapes = {tuple(leaf.shape)
                   for leaf in jax.tree_util.tree_leaves(eng.cache)
                   if getattr(leaf, "ndim", 0) == 4}
    pallas_calls = 0
    for eqn in _all_eqns(jaxpr.jaxpr):
        pallas_calls += eqn.primitive.name == "pallas_call"
        if eqn.primitive.name.startswith("scatter"):
            for var in eqn.outvars:
                shape = tuple(getattr(var.aval, "shape", ()))
                assert shape not in pool_shapes, (
                    f"pool-shaped scatter on the ragged path: {shape}")
    assert pallas_calls == 1, f"{pallas_calls} pallas_calls in step jaxpr"
