"""Sharded multi-device serving: KV-head-parallel ragged step over a mesh.

The contract under test is *token identity*: the engine on a (1, M)
(data, model) mesh — page-pool K/V leaves and wq/wk/wv head columns
sharded along the KV-head axis, wo and everything else replicated, one
all-gather of the attention output per step — must emit per-request
token streams bit-identical to the single-device engine, under churn,
preemption, speculative decoding, and tiered background repack.

Multi-device cases run in a subprocess (device count is locked at first
jax init and the main pytest process must keep 1 device — same pattern
as test_distributed.py). Fallback/validation paths run in-process: they
never build a mesh.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import ContinuousBatchingEngine, ServeConfig


def _cfg(num_heads=4, num_kv_heads=2):
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=num_heads,
        num_kv_heads=num_kv_heads, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=True))


# ---------------------------------------------------------------------------
# fallback + validation (no mesh is ever built: runs on 1 device)
# ---------------------------------------------------------------------------


def test_mesh_1x1_falls_back_to_unsharded():
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=2, page_size=4, mesh_shape=(1, 1)))
    assert eng.mesh is None and eng.tp == 1
    assert eng.cache_stats()["kv_head_shards"] == 1


def test_mesh_requires_ragged_step_or_falls_back():
    """A config the ragged step rejects (einsum decode kernel) must run
    unsharded rather than die — the same fallback ladder the ragged step
    itself uses."""
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=2, page_size=4, decode_kernel="einsum",
        mesh_shape=(1, 2)))
    assert not eng.ragged and eng.mesh is None
    out = eng.generate(np.arange(1, 5, dtype=np.int32)[None], 4)
    assert out.shape == (1, 8)


def test_mesh_validation_errors():
    cfg = _cfg(num_kv_heads=2)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    base = dict(max_seq=24, max_slots=2, page_size=4)
    # KV heads must divide over the model axis
    with pytest.raises(ValueError, match="divisible"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            mesh_shape=(1, 3), **base))
    # data-parallel serving is a router-level follow-on, not a mesh dim
    with pytest.raises(ValueError, match="data"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            mesh_shape=(2, 1), **base))
    with pytest.raises(ValueError, match="mesh_shape"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            mesh_shape=(1, 0), **base))
    # divisible but more devices than this 1-device process has
    with pytest.raises(ValueError, match="devices"):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            mesh_shape=(1, 2), **base))


def test_pool_specs_shard_kv_head_axis_only():
    from jax.sharding import PartitionSpec as P

    from repro.serve import kv_cache
    cfg = _cfg()
    cache = model.init_paged_cache(cfg, 2, 8, 4)
    specs = kv_cache.pool_specs(cache, "model")
    flat_c = jax.tree_util.tree_leaves(cache)
    flat_s, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_c) == len(flat_s)
    for leaf, spec in zip(flat_c, flat_s):
        # KVH is always ndim-2 of a pool leaf; NP and the storage dim
        # stay unsharded so page gathers remain shard-local
        assert spec[leaf.ndim - 2] == "model"
        assert all(e is None for i, e in enumerate(spec)
                   if i != leaf.ndim - 2)


def test_serve_param_specs_shard_qkv_replicate_wo():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import serve_param_specs
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    specs = serve_param_specs(params)

    def walk(p, s, inside=None):
        if isinstance(p, dict):
            for key, val in p.items():
                walk(val, s[key],
                     key if key in ("wq", "wk", "wv", "wo") else inside)
        elif isinstance(p, (list, tuple)):
            for pv, sv in zip(p, s):
                walk(pv, sv, inside)
        else:
            if inside in ("wq", "wk", "wv"):
                assert s[p.ndim - 1] == "model", (inside, s)
            else:
                # wo + everything outside attention: replicated
                assert all(e is None for e in s), (inside, s)

    walk(params, specs)


# ---------------------------------------------------------------------------
# multi-device: token identity + structure (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import ContinuousBatchingEngine, ServeConfig
from repro.serve.engine import TierPolicy

assert len(jax.devices()) == 8
cfg = ModelConfig(
    name="t", family="dense", d_model=64, vocab_size=128,
    pattern=(BlockDef("attn"),), num_groups=1, num_heads=8,
    num_kv_heads=8, head_dim=16, d_ff=128,
    quant=MXFP8.replace(block_size=16, quantize_acts=False,
                        quantize_kv_cache=True))
rng = np.random.default_rng(3)
reqs = [(rng.integers(0, 128, (s,)).astype(np.int32), m)
        for s, m in [(4, 12), (4, 12), (7, 5), (3, 8), (12, 6)]]

SCENARIOS = {
    # pool sized to force preemption, shared prefixes in play
    "churn": dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                  prefix_cache=True),
    # speculative decoding: verify windows ride the sharded kernel
    "spec": dict(max_seq=24, max_slots=2, page_size=4, num_pages=7,
                 prefix_cache=True, spec_decode=True, num_draft_tokens=2),
    # tiered repack: demotions run as shard-local sharded dispatches
    "tiered": dict(max_seq=48, max_slots=2, page_size=8, prefill_chunk=8,
                   num_pages=14, tiered=True,
                   tier_policy=TierPolicy(hot_steps=2, cold_steps=4,
                                          repack_pages_per_step=2)),
}

for name, kw in SCENARIOS.items():
    outs, stats = {}, {}
    for mesh in (None, (1, 8)):
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
            mesh_shape=mesh, **kw))
        if mesh is not None:
            assert eng.mesh is not None, "unexpected fallback to unsharded"
            assert eng.tp == 8
        ids = [eng.submit(p, m) for p, m in reqs]
        out = eng.run()
        outs[mesh] = [out[i] for i in ids]
        stats[mesh] = eng.cache_stats()
    for a, b in zip(outs[None], outs[(1, 8)]):
        np.testing.assert_array_equal(a, b)
    s = stats[(1, 8)]
    assert s["kv_head_shards"] == 8
    if name == "churn":
        assert s["preemptions"] >= 1, "pool must force a swap"
    if name == "tiered":
        assert s["repacked_pages"] >= 1, "policy must demote some pages"
        assert s["repacked_pages"] == stats[None]["repacked_pages"]
    print(name, "identical;",
          "mixed", s["mixed_steps"], "dpm", s["dispatches_per_mixed_step"])

# structural: the sharded step's jaxpr still contains exactly ONE
# pallas_call (one attention layer here) — shard_map partitions the
# kernel grid along KV heads, it must not replicate or split the call
params, _ = model.init(jax.random.PRNGKey(0), cfg)
eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
    max_seq=24, max_slots=2, page_size=4, prefill_chunk=4,
    mesh_shape=(1, 8)))
assert eng.mesh is not None
captured = {}
orig = eng._ragged_fn

def spy(*a, **k):
    captured.setdefault("args", a)
    return orig(*a, **k)

eng._ragged_fn = spy
eng.submit(np.arange(5, dtype=np.int32), 3)
eng.run()
jaxpr = jax.make_jaxpr(orig)(*captured["args"])

def _subjaxprs(prms):
    for v in prms.values():
        if isinstance(v, jax.extend.core.ClosedJaxpr):
            yield v.jaxpr
        elif hasattr(v, "eqns"):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.extend.core.ClosedJaxpr):
                    yield x.jaxpr
                elif hasattr(x, "eqns"):
                    yield x

def _all_eqns(j):
    for eqn in j.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from _all_eqns(sub)

names = [e.primitive.name for e in _all_eqns(jaxpr.jaxpr)]
assert names.count("pallas_call") == 1, names.count("pallas_call")
assert any(n in ("shard_map", "smap") for n in names), sorted(set(names))
assert names.count("all_gather") == 1, names.count("all_gather")
print("SHARDED_SERVE_OK")
"""


@pytest.mark.slow
def test_sharded_engine_token_identical_and_one_kernel_per_shard():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_SERVE_OK" in proc.stdout
