"""Property tests for MX block quantization and the mx_dot execution modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import formats as F
from repro.core import mx_dot, qat_matmul, quantize, quantize_value

FMTS = ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1"]


def _error_bound(fmt, amax):
    """Worst-case per-element error of MX quantization for block amax.

    Two regimes: RNE half-ulp at the top binade, and spec-mandated
    saturation when amax/scale lands in (fmt.max, 2^(emax+1)).
    """
    info = F.get_format(fmt)
    scale = 2.0 ** (np.floor(np.log2(np.maximum(amax, 1e-38))) - info.emax)
    half_ulp = scale * 2.0 ** (info.emax - info.mantissa_bits) / 2
    sat = scale * max(2.0 ** (info.emax + 1) - info.max, 0.0)
    return np.maximum(half_ulp, sat) * 1.0001 + 1e-12


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("block_size", [8, 16, 32, 64])
def test_quantize_error_bound(fmt, block_size):
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, 256)).astype(np.float32) * 10
    t = quantize(jnp.asarray(x), fmt, block_size)
    deq = np.asarray(t.dequantize())
    blocked = x.reshape(4, -1, block_size)
    amax = np.abs(blocked).max(-1, keepdims=True)
    err = np.abs(deq.reshape(blocked.shape) - blocked)
    bound = _error_bound(fmt, amax)
    assert (err <= bound).all(), float((err - bound).max())


@pytest.mark.parametrize("fmt", FMTS)
def test_quantize_axis_handling(fmt):
    rng = np.random.default_rng(5)
    x = rng.normal(size=(32, 6, 10)).astype(np.float32)
    t0 = quantize(jnp.asarray(x), fmt, 8, axis=0)
    assert t0.shape == x.shape and t0.axis == 0
    d0 = np.asarray(t0.dequantize())
    assert d0.shape == x.shape
    # blocking along axis 0 == blocking the transposed array along -1
    t2 = quantize(jnp.asarray(np.moveaxis(x, 0, -1)), fmt, 8, axis=-1)
    d2 = np.moveaxis(np.asarray(t2.dequantize()), -1, 0)
    np.testing.assert_array_equal(d0, d2)


def test_block_size_must_divide():
    with pytest.raises(ValueError):
        quantize(jnp.zeros((4, 30)), "fp8_e4m3", 32)


@given(
    st.sampled_from(FMTS),
    st.sampled_from([8, 16, 32]),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_quantize_idempotent(fmt, block_size, seed):
    """Quantizing an already-quantized array is exact (grid fixpoint)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    q1 = quantize_value(x, fmt, block_size)
    q2 = quantize_value(q1, fmt, block_size)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_scaling_invariance_power_of_two(seed):
    """MX quantization commutes with power-of-two scaling of the input."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    a = np.asarray(quantize_value(x, "fp8_e4m3", 32)) * 4.0
    b = np.asarray(quantize_value(x * 4.0, "fp8_e4m3", 32))
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_zero_block():
    t = quantize(jnp.zeros((2, 64)), "fp8_e4m3", 32)
    np.testing.assert_array_equal(np.asarray(t.dequantize()), 0.0)
    np.testing.assert_array_equal(np.asarray(t.scales), 0)


def test_nbytes_compression():
    x = jnp.ones((128, 128))
    t8 = quantize(x, "fp8_e4m3", 32)
    t4 = quantize(x, "fp4_e2m1", 32)
    assert t8.nbytes == 128 * 128 + 128 * 4
    assert t4.nbytes == 128 * 128 // 2 + 128 * 4


# ---------------------------------------------------------------------------
# mx_dot execution-mode equivalence (paper: all tiers compute the same MX-DP)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("block_size", [8, 32])
def test_mode_equivalence(fmt, block_size):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    xq = quantize(x, fmt, block_size)
    wq = quantize(w, fmt, block_size, axis=0)
    y_em = np.asarray(mx_dot(xq, wq, mode="emulated"))
    y_fu = np.asarray(mx_dot(xq, wq, mode="fused"))
    # bf16-operand fused path is exact in value (fp8/fp4 values and
    # power-of-two scales are representable); accumulation order may differ.
    np.testing.assert_allclose(y_fu, y_em, rtol=2e-5, atol=2e-5)


def test_weight_only_variant():
    """Vector-scalar analogue: wide activations x MX weights.

    Fused mode carries the wide operand in bf16 (TPU operand dtype), so the
    reference casts x through bf16 too.
    """
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    wq = quantize(w, "fp8_e4m3", 32, axis=0)
    y = np.asarray(mx_dot(x, wq, mode="fused"))
    xb = np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32))
    ref = xb @ np.asarray(wq.dequantize())
    np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)
    # emulated mode keeps the wide operand in f32
    y_em = np.asarray(mx_dot(x, wq, mode="emulated"))
    ref_em = np.asarray(x) @ np.asarray(wq.dequantize())
    np.testing.assert_allclose(y_em, ref_em, rtol=1e-5, atol=1e-5)


def test_bf16_accumulation_mode():
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 8)).astype(np.float32))
    xq, wq = quantize(x, "fp8_e4m3", 32), quantize(w, "fp8_e4m3", 32, axis=0)
    y16 = mx_dot(xq, wq, mode="fused", acc_dtype=jnp.bfloat16)
    y32 = mx_dot(xq, wq, mode="fused", acc_dtype=jnp.float32)
    assert y16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y16, np.float32), np.asarray(y32), rtol=0.05, atol=0.5
    )


def test_qat_matmul_grads_match_ste():
    """QAT backward == straight-through: dx = dy @ wq^T, dw = xq^T @ dy."""
    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    dy = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))

    y, vjp = jax.vjp(lambda x, w: qat_matmul(x, w, "fp8_e4m3", 32), x, w)
    dx, dw = vjp(dy)
    xq = quantize_value(x, "fp8_e4m3", 32)
    wq = quantize_value(w, "fp8_e4m3", 32, axis=0)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dy @ wq.T), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(xq.T @ dy), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(xq @ wq), rtol=1e-4, atol=1e-4)


def test_quantization_sqnr_ordering():
    """FP8 must beat FP4 everywhere; small blocks must help FP4 on
    heavy-tailed data (paper ref [19] uses small blocks for FP4 training).

    Note (validated experimentally): for near-Gaussian data FP8's 17-binade
    element range makes block size nearly irrelevant, so the small-block
    advantage is asserted only for the range-starved FP4 format on data with
    outliers — this matches the regime ref [19] targets.
    """
    rng = np.random.default_rng(23)
    base = rng.normal(size=(64, 256)).astype(np.float32)
    outliers = np.where(rng.random(base.shape) < 0.02, 64.0, 1.0)
    x = jnp.asarray(base * outliers)

    def sqnr(fmt, k):
        q = np.asarray(quantize_value(x, fmt, k))
        xn = np.asarray(x)
        return 10 * np.log10((xn**2).mean() / ((q - xn) ** 2).mean())

    assert sqnr("fp8_e4m3", 32) > sqnr("fp4_e2m1", 32) + 5
    assert sqnr("fp4_e2m1", 8) > sqnr("fp4_e2m1", 128)
