"""Continuous-batching serve stack: page pool, scheduler, engine goldens.

The load-bearing claim: the paged continuous-batching engine's greedy
outputs are token-identical to the fixed-slot reference — per request,
under ragged lengths, slot churn, EOS recycling, and swap preemption.
"""
import jax
import numpy as np
import pytest

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import (ContinuousBatchingEngine, FixedSlotEngine, PagePool,
                         Scheduler, ServeConfig, pages_for)
from repro.serve import kv_cache as KV


# ---------------------------------------------------------------------------
# page pool invariants (pure host logic)
# ---------------------------------------------------------------------------


def test_page_pool_alloc_free_invariants():
    pool = PagePool(8)
    a = pool.alloc(3)
    b = pool.alloc(5)
    assert sorted(a + b) == list(range(8))
    assert pool.alloc(1) is None and pool.free_pages == 0
    pool.free(a)
    assert pool.free_pages == 3 and pool.pages_in_use == 5
    c = pool.alloc(3)
    assert sorted(c) == sorted(a)  # recycled, no phantom pages
    with pytest.raises(ValueError):
        pool.free([c[0], c[0]])  # double free
    with pytest.raises(ValueError):
        pool.free([99])  # unknown page
    assert pool.peak_in_use == 8


def test_pages_for_rounding():
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(0, 8) == 0


# ---------------------------------------------------------------------------
# scheduler: FCFS admission, EOS recycling, preemption bookkeeping
# ---------------------------------------------------------------------------


def _sched(**kw):
    args = dict(max_slots=2, num_pages=8, page_size=4, max_seq=16)
    args.update(kw)
    return Scheduler(**args)


def test_scheduler_fcfs_admission_and_eos_recycling():
    s = _sched()
    p = np.arange(6, dtype=np.int32)
    ids = [s.submit(p, 4) for _ in range(4)]
    assert ids == [0, 1, 2, 3]
    a0 = s.admit_next()
    a1 = s.admit_next()
    assert (a0.req.id, a1.req.id) == (0, 1)  # strict FCFS
    assert s.admit_next() is None  # no free slot
    # run request 0 to its EOS: slot + pages recycle, 2 admits next
    assert s.record_token(a0, 7)  # token 1 of 4
    for tok in (1, 2):
        s.advance(a0)
        assert s.record_token(a0, tok)
    s.advance(a0)
    assert not s.record_token(a0, 3)  # max_new reached -> finished
    assert s.slots[a0.slot] is None
    a2 = s.admit_next()
    assert a2.req.id == 2
    # eos_id finishes early and recycles too
    assert not s.record_token(a2, 99, eos_id=99)
    assert s.finished[-1].id == 2 and s.pool.pages_in_use == pages_for(6, 4)


def test_scheduler_rejects_oversized_requests():
    s = _sched()
    with pytest.raises(ValueError):
        s.submit(np.zeros(14, np.int32), 4)  # 14 + 4 > max_seq 16
    with pytest.raises(ValueError):
        s.submit(np.zeros(0, np.int32), 4)  # empty prompt
    with pytest.raises(ValueError):
        Scheduler(max_slots=1, num_pages=2, page_size=4, max_seq=16)


def test_scheduler_preemption_requeues_front_with_snapshot():
    s = _sched(num_pages=4, max_seq=16)
    s.submit(np.arange(4, dtype=np.int32), 8)
    s.submit(np.arange(4, dtype=np.int32), 8)
    a0, a1 = s.admit_next(), s.admit_next()
    victim = s.pick_victim(exclude=a0)
    assert victim is a1  # youngest loses
    s.preempt(victim, snapshot={"fake": True})
    assert s.queue[0] is victim.req and victim.req.swap is not None
    assert s.slots[victim.slot] is None
    # freed pages make room for a0 to grow
    a0.pos = 4
    assert s.try_grow(a0)


# ---------------------------------------------------------------------------
# engine goldens: token-identical to the fixed-slot reference
# ---------------------------------------------------------------------------


def _cfg(quantize_kv):
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=quantize_kv))


@pytest.mark.parametrize("quantize_kv", [False, True])
def test_continuous_matches_fixed_slot_greedy(quantize_kv):
    cfg = _cfg(quantize_kv)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(0).integers(
        0, 128, (3, 8)).astype(np.int32)
    want = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24)).generate(
        prompts, 6)
    got = ContinuousBatchingEngine(
        params, cfg, ServeConfig(max_seq=24, max_slots=3,
                                 page_size=8)).generate(prompts, 6)
    np.testing.assert_array_equal(got, want)


def test_ragged_churn_with_preemption_token_identical():
    """More requests than slots, ragged lengths, a pool tight enough to
    force swap preemption — every request must still match its own
    fixed-slot (batch-of-1) generation exactly."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(4, 14), (4, 14), (7, 5), (3, 8)]]
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=20, max_slots=2, page_size=4, num_pages=7))
    ids = [eng.submit(p, m) for p, m in reqs]
    out = eng.run()
    assert eng.scheduler.preemptions >= 1, "pool sizing must force a swap"
    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24))
    for rid, (p, m) in zip(ids, reqs):
        np.testing.assert_array_equal(out[rid], fixed.generate(p[None], m)[0])


@pytest.mark.parametrize("decode_kernel", ["fused", "einsum"])
def test_decode_kernel_paths_token_identical_under_churn(decode_kernel):
    """Greedy-equivalence regression for the kernel-path switch: the fused
    flash-decode path (the engine default) and the einsum reference path
    must both stay token-identical to the fixed-slot engine under the
    churn + swap-preemption workload. The other scenarios in this file and
    tests/test_prefix_cache.py run the default ("fused") path, so
    prefix-sharing coverage rides on them.

    Diagnosis note: fused-vs-fixed identity holds on these pinned seeds
    but is bf16-rounding-level across numerics families (README
    §Serving). If the fused case alone starts failing with a *small*
    top-2 logit gap after a JAX/XLA upgrade, suspect f32 reduction-order
    drift, not the paging machinery — the einsum case is the bit-matched
    control that isolates which."""
    assert ServeConfig().decode_kernel == "fused", \
        "the serve engine must default to the fused kernel path"
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(4, 14), (4, 14), (7, 5), (3, 8)]]
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=20, max_slots=2, page_size=4, num_pages=7,
        decode_kernel=decode_kernel))
    assert eng.cfg_decode.decode_kernel == decode_kernel
    ids = [eng.submit(p, m) for p, m in reqs]
    out = eng.run()
    assert eng.scheduler.preemptions >= 1, "pool sizing must force a swap"
    fixed = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24))
    for rid, (p, m) in zip(ids, reqs):
        np.testing.assert_array_equal(out[rid], fixed.generate(p[None], m)[0])


def test_engine_rejects_unknown_decode_kernel():
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(params, cfg, ServeConfig(
            max_seq=24, decode_kernel="flash3"))


def test_eos_recycles_mid_stream():
    """A request hitting eos_id frees its slot for a queued request; output
    ends at (and includes) the eos token."""
    cfg = _cfg(False)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(1).integers(
        0, 128, (2, 6)).astype(np.int32)
    ref = FixedSlotEngine(params, cfg, ServeConfig(max_seq=24)).generate(
        prompts[:1], 8)[0]
    eos = int(ref[6 + 2])  # the 3rd greedy token becomes the eos id
    stop = 6 + 1 + int(np.argmax(ref[6:] == eos))  # first eos occurrence
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=24, max_slots=1, page_size=8, eos_id=eos))
    ids = [eng.submit(prompts[0], 8), eng.submit(prompts[1], 8)]
    out = eng.run()
    first = out[ids[0]]
    assert first[-1] == eos and len(first) == stop
    np.testing.assert_array_equal(first, ref[: len(first)])
    assert len(out[ids[1]]) == 6 + 8  # second request completed after


# ---------------------------------------------------------------------------
# cache byte accounting: the serving payoff
# ---------------------------------------------------------------------------


def test_paged_mx_cache_bytes_per_token_at_least_2x_under_bf16_fixed():
    """fp8 MX pages + paging beat the bf16 fixed-slot rectangle >= 2x on a
    ragged workload (compression ~1.9x times allocation utilization)."""
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, 128, (s,)).astype(np.int32), m)
            for s, m in [(3, 6), (8, 4), (5, 8), (4, 5)]]
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(
        max_seq=32, max_slots=2, page_size=4))
    for p, m in reqs:
        eng.submit(p, m)
    eng.run()
    stats = eng.cache_stats()
    resident = stats["resident_tokens_at_peak"]
    paged_bpt = (stats["peak_paged_bytes"] + stats["state_bytes"]) / resident
    bf16_cache = model.init_cache(_cfg(False), batch=2, max_seq=32)
    fixed_bpt = KV.cache_nbytes(bf16_cache) / resident
    assert fixed_bpt / paged_bpt >= 2.0, (fixed_bpt, paged_bpt)


def test_extract_restore_roundtrip():
    """Swap-out then swap-in onto different pages/slot is lossless."""
    cfg = _cfg(True)
    cache = model.init_paged_cache(cfg, num_slots=2, num_pages=6,
                                   page_size=4)
    # scribble recognizable values into pages [1, 3] / slot 0
    import jax.numpy as jnp

    def fill(leaf):
        return jnp.arange(leaf.size, dtype=jnp.float32).reshape(
            leaf.shape).astype(leaf.dtype)

    cache = jax.tree_util.tree_map(fill, cache)
    snap = KV.extract_seq(cache, slot=0, page_ids=jnp.asarray([1, 3]))
    zeroed = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), cache)
    back = KV.restore_seq(zeroed, snap, slot=0,
                          page_ids=jnp.asarray([1, 3]))
    for path, blk, grouped in KV._iter_blocks(back):
        orig = cache[path[0]] if len(path) == 1 else \
            cache["groups"][path[1]]
        if KV._is_pool(blk):
            for key in blk:
                idx = (slice(None), [1, 3]) if grouped else ([1, 3],)
                np.testing.assert_array_equal(
                    np.asarray(blk[key][idx], np.float32),
                    np.asarray(orig[key][idx], np.float32))
        else:
            for lb, lo in zip(jax.tree_util.tree_leaves(blk),
                              jax.tree_util.tree_leaves(orig)):
                idx = (slice(None), 0) if grouped else (0,)
                np.testing.assert_array_equal(np.asarray(lb[idx]),
                                              np.asarray(lo[idx]))


# ---------------------------------------------------------------------------
# speculative verify / rollback invariants (property-tested)
# ---------------------------------------------------------------------------


from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.serve import ScriptedDrafter  # noqa: E402


def _seq_cache_rows(eng, seq, n_rows):
    """The first ``n_rows`` K/V cache rows of ``seq``, per pool leaf,
    gathered through its page table — the sequence's *logical* cache, the
    thing speculation must leave byte-identical to plain decode."""
    out = {}
    pages = np.asarray(seq.pages, np.int32)
    for path, blk, grouped in KV._iter_blocks(eng.cache):
        if not KV._is_pool(blk):
            continue
        for key, leaf in blk.items():
            arr = np.asarray(leaf[:, pages] if grouped else leaf[pages])
            if grouped:
                arr = arr.reshape(arr.shape[0], -1,
                                  *arr.shape[3:])[:, :n_rows]
            else:
                arr = arr.reshape(-1, *arr.shape[2:])[:n_rows]
            out[(path, key)] = (arr if arr.dtype == np.uint8
                                else arr.astype(np.float32))
    return out


@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_spec_verify_rollback_cache_equivalence_property(seed):
    """Arbitrary draft prefixes ⇒ after every verify step, the sequence's
    cache pages, position, and token stream are byte-identical to having
    decoded the accepted tokens one at a time.

    A speculative engine (pseudo-random adversarial drafts, so accept
    counts vary 0..K per step) and a plain engine serve the same request
    in lock-step: after each verify step the plain engine decodes until
    its position catches up, then every pool leaf's rows [0, pos) must
    match bit-for-bit — rejected drafts' writes beyond pos are exactly
    rolled back (dead by truncation), accepted drafts' writes are exactly
    what one-at-a-time decode would have written.
    """
    rng = np.random.default_rng(seed)
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    prompt = rng.integers(0, 128, (int(rng.integers(2, 7)),)).astype(
        np.int32)
    max_new = int(rng.integers(4, 11))
    k = int(rng.integers(1, 5))
    base = dict(max_seq=24, max_slots=1, page_size=4, prefix_cache=False)
    spec = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base, spec_decode=True, num_draft_tokens=k,
        drafter=ScriptedDrafter(vocab=128, seed=seed)))
    plain = ContinuousBatchingEngine(params, cfg, ServeConfig(**base))
    sid = spec.submit(prompt, max_new)
    pid = plain.submit(prompt, max_new)

    guard = 0
    while spec.step():
        guard += 1
        assert guard < 100, "speculative engine failed to make progress"
        if not spec.scheduler.active():
            break
        sseq = spec.scheduler.active()[0]
        # engine invariant: pos counts exactly the accepted resident rows
        assert sseq.pos == len(prompt) + len(sseq.req.generated) - 1
        # catch the plain engine up to the speculative one's position
        # (identical token streams mean it gets there while still active)
        while not plain.scheduler.active() or \
                plain.scheduler.active()[0].pos < sseq.pos:
            assert plain.step() or plain.scheduler.active(), \
                "plain engine drained before reaching the spec position"
        pseq = plain.scheduler.active()[0]
        assert pseq.pos == sseq.pos
        assert pseq.req.generated == sseq.req.generated[:len(
            pseq.req.generated)]
        got = _seq_cache_rows(spec, sseq, sseq.pos)
        want = _seq_cache_rows(plain, pseq, pseq.pos)
        assert got.keys() == want.keys()
        for key in got:
            np.testing.assert_array_equal(got[key], want[key], err_msg=str(key))
        # every page either sequence maps is live in its pool
        for eng, seq in ((spec, sseq), (plain, pseq)):
            for pg in seq.pages:
                assert eng.scheduler.pool.ref(pg) >= 1
    out_s = spec.run()
    while plain.step():
        pass
    out_p = plain.run()
    np.testing.assert_array_equal(out_s[sid], out_p[pid])
    # drained engines hold no pages (no prefix tree in this scenario)
    assert spec.scheduler.pool.pages_in_use == 0
    assert plain.scheduler.pool.pages_in_use == 0


@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_spec_engine_churn_property_refcounts_and_identity(seed):
    """Randomized shared-head workloads under a speculative engine with
    adversarial drafts: outputs match the plain engine per request, and
    after draining, every page's refcount equals the prefix tree's holds
    (speculative growth/rollback neither leaks nor double-frees)."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(True)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    head = rng.integers(0, 128, (int(rng.integers(0, 9)),)).astype(np.int32)
    reqs = []
    for _ in range(int(rng.integers(2, 5))):
        tail = rng.integers(0, 128, (int(rng.integers(1, 5)),)).astype(
            np.int32)
        reqs.append((np.concatenate([head, tail]),
                     int(rng.integers(2, 8))))
    k = int(rng.integers(1, 4))
    base = dict(max_seq=28, max_slots=2, page_size=4, prefix_cache=True)
    plain = ContinuousBatchingEngine(params, cfg, ServeConfig(**base))
    ids_p = [plain.submit(p, m) for p, m in reqs]
    out_p = plain.run()
    spec = ContinuousBatchingEngine(params, cfg, ServeConfig(
        **base, spec_decode=True, num_draft_tokens=k,
        drafter=ScriptedDrafter(vocab=128, seed=seed + 1)))
    ids_s = [spec.submit(p, m) for p, m in reqs]
    out_s = spec.run()
    for i_s, i_p in zip(ids_s, ids_p):
        np.testing.assert_array_equal(out_s[i_s], out_p[i_p])
    pool = spec.scheduler.pool
    held = (spec.scheduler.prefix.pages_held
            if spec.scheduler.prefix is not None else [])
    for pg in range(pool.num_pages):
        assert pool.ref(pg) == held.count(pg), (pg, held)
    assert pool.pages_in_use == len(held)
