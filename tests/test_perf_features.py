"""Tests for the §Perf features: sorted MoE, MX-FSDP fallbacks, cache
shardings, microbatching, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP8, QuantConfig, WIDE
from repro.nn import BlockDef, ModelConfig, model, moe


def test_sorted_moe_matches_dense_quantized_and_wide():
    cfg_d = moe.MoEConfig(d_model=64, d_ff_expert=96, num_experts=4, top_k=2,
                          dispatch="dense")
    cfg_s = moe.MoEConfig(d_model=64, d_ff_expert=96, num_experts=4, top_k=2,
                          dispatch="sorted")
    params, _ = moe.init(jax.random.PRNGKey(0), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.bfloat16)
    for q in (QuantConfig(enabled=True, block_size=32), WIDE):
        yd, auxd = moe.apply(params, x, cfg_d, q)
        ys, auxs = moe.apply(params, x, cfg_s, q)
        # identical math; combine order differs (einsum vs scatter-add) so
        # allow one bf16 ulp
        np.testing.assert_allclose(np.asarray(yd, np.float32),
                                   np.asarray(ys, np.float32),
                                   rtol=0, atol=2 ** -7)
        assert float(auxd) == pytest.approx(float(auxs))


def test_sorted_moe_with_shared_experts():
    cfg = moe.MoEConfig(d_model=64, d_ff_expert=96, num_experts=4, top_k=2,
                        num_shared=1, d_ff_shared=96, dispatch="sorted")
    params, _ = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.bfloat16)
    y, aux = moe.apply(params, x, cfg, MXFP8.replace(block_size=32))
    assert y.shape == x.shape and bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_sorted_moe_grads_finite():
    cfg = tiny_moe_model("sorted")
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, cfg, {"tokens": tokens, "labels": tokens})
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all())
               for g in jax.tree_util.tree_leaves(grads))


def tiny_moe_model(dispatch):
    return ModelConfig(
        name="t", family="moe", d_model=64, vocab_size=256,
        pattern=(BlockDef("attn", ffn="moe"),), num_groups=2,
        num_heads=4, num_kv_heads=2, head_dim=16,
        num_experts=4, top_k=2, d_ff_expert=64,
        moe_dispatch=dispatch, quant=MXFP8.replace(block_size=16))


def test_cache_shardings_locates_batch_dim():
    from jax.sharding import PartitionSpec as P

    from repro.parallel import cache_shardings, make_abstract_mesh

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    shapes = {
        "stacked_kv": jax.ShapeDtypeStruct((26, 128, 1024, 512), jnp.bfloat16),
        "flat_kv": jax.ShapeDtypeStruct((128, 1024, 8, 64), jnp.bfloat16),
        "kpos": jax.ShapeDtypeStruct((26, 1024), jnp.int32),
    }
    sh = cache_shardings(mesh, shapes, batch_size=128)
    assert sh["stacked_kv"].spec == P(None, "data", None, None)
    assert sh["flat_kv"].spec == P("data", None, None, None)
    assert sh["kpos"].spec == P(None, None)


def test_microbatched_step_matches_single_batch_loss():
    from repro.train import OptimConfig, init_state, make_train_step

    cfg = ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, quant=WIDE)
    state, _ = init_state(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}
    s1 = jax.jit(make_train_step(cfg, OptimConfig(), num_microbatches=1))
    s4 = jax.jit(make_train_step(cfg, OptimConfig(), num_microbatches=4))
    _, m1 = s1(state, batch)
    _, m4 = s4(state, batch)
    # microbatched loss is the mean over microbatches of per-microbatch
    # means — equal here since microbatches have equal token counts
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]),
                                                   rel=5e-2)


def test_grad_compression_hook():
    from repro.train.loop import _compress_grads

    cfg = tiny_moe_model("dense").replace(
        quant=MXFP8.replace(quantize_grads=True))
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 64)).astype(np.float32))}
    cg = _compress_grads(grads, cfg)
    # compressed grads are on the e5m2 grid: requantizing is a fixpoint
    from repro.core import quantize_value

    np.testing.assert_array_equal(
        np.asarray(cg["w"]),
        np.asarray(quantize_value(cg["w"], "fp8_e5m2", 32)))


def test_mx_weight_gather_flag_off_path():
    """mx_weight_gather=False must keep the plain quantizer path working."""
    cfg = tiny_moe_model("dense").replace(
        quant=MXFP8.replace(block_size=16, mx_weight_gather=False))
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    logits, _ = model.forward(params, cfg, tokens)
    assert bool(jnp.isfinite(logits).all())


def test_bf16_accumulation_profile():
    """Paper Table I bf16-acc variant as a config switch."""
    cfg = tiny_moe_model("dense").replace(
        quant=MXFP8.replace(block_size=16, acc_dtype=jnp.bfloat16))
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    loss, _ = model.loss_fn(params, cfg, {"tokens": tokens, "labels": tokens})
    assert bool(jnp.isfinite(loss))
