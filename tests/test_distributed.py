"""Distributed correctness on 8 fake devices (subprocess: device count is
locked at first jax init, and the main pytest process must keep 1 device).

Checks sharded-vs-single-device numerical equivalence of a train step, and
that the dry-run machinery lowers + compiles a reduced arch on a small mesh.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.nn import model
from repro.parallel import batch_shardings, replicated, tree_shardings
from repro.parallel.ctx import use_mesh
from repro.launch import specs as S
from repro.train import OptimConfig, init_state, make_train_step

cfg = get_reduced("gemma2-2b")
state, axes = init_state(jax.random.PRNGKey(0), cfg)
step = make_train_step(cfg, OptimConfig())
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}

# single-device reference
_, m_ref = jax.jit(step)(state, batch)

# sharded on a 4x2 (data, model) mesh
mesh = make_host_mesh(model_parallel=2)
state_sh = tree_shardings(mesh, state, {"params": axes, "opt": {"m": axes, "v": axes, "step": ()}})
batch_sh = batch_shardings(mesh, jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
with use_mesh(mesh):
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, replicated(mesh)))
    new_state, m_sh = jitted(state, batch)

ref, got = float(m_ref["loss"]), float(m_sh["loss"])
assert abs(ref - got) < 5e-3, (ref, got)
gn_ref, gn_got = float(m_ref["grad_norm"]), float(m_sh["grad_norm"])
assert abs(gn_ref - gn_got) / gn_ref < 2e-2, (gn_ref, gn_got)

# serve path lowers sharded too (decode with cache)
from repro.configs.shapes import ShapeSpec
shape = ShapeSpec("d", 64, 8, "decode")
spec = S.input_specs(cfg, shape)
p_sh = tree_shardings(mesh, spec["params"], spec["axes"])
cache_sh = batch_shardings(mesh, spec["cache"])
tok_sh = batch_shardings(mesh, spec["tokens"])
def serve_step(params, cache, tokens, pos):
    return model.decode_step(params, cfg, cache, tokens=tokens.get("tokens"), pos=pos)
with use_mesh(mesh):
    c = jax.jit(serve_step,
                in_shardings=(p_sh, cache_sh, tok_sh, replicated(mesh))
                ).lower(spec["params"], spec["cache"], spec["tokens"], spec["pos"]).compile()
assert c.memory_analysis() is not None
print("DISTRIBUTED_OK", ref, got)
"""


@pytest.mark.slow
def test_sharded_equivalence_and_serve_lowering():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_OK" in proc.stdout
