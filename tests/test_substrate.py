"""Substrate tests: optimizer, checkpointing, fault tolerance, data, sharding
rules, HLO cost walker."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLMDataset
from repro.train import checkpoint, fault, optim
from repro.train.optim import OptimConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_quadratic_convergence():
    cfg = OptimConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, m = optim.apply(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_weight_decay_and_clip():
    cfg = OptimConfig(lr=1e-2, warmup_steps=0, clip_norm=1.0,
                      weight_decay=0.5, schedule="constant")
    params = {"w": jnp.ones((4,))}
    state = optim.init(params)
    grads = {"w": jnp.full((4,), 100.0)}  # huge grad, must clip
    new_params, state, m = optim.apply(cfg, params, grads, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    # post-clip grad norm is 1 -> update bounded by lr * (1 + wd)
    assert float(jnp.abs(params["w"] - new_params["w"]).max()) < 2e-2


def test_lr_schedule_shapes():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    lrs = [float(optim.lr_at(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 60, 110, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# checkpoint + fault tolerance
# ---------------------------------------------------------------------------


def _tiny_state():
    return {"params": {"w": jnp.arange(8, dtype=jnp.float32)},
            "opt": {"step": jnp.asarray(3)}}


def test_checkpoint_atomic_and_pruning():
    with tempfile.TemporaryDirectory() as d:
        state = _tiny_state()
        for s in (1, 2, 3, 4, 5):
            checkpoint.save(d, s, state, keep=2)
        assert checkpoint.list_steps(d) == [4, 5]
        restored, step, _ = checkpoint.restore(d, state)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.arange(8, dtype=np.float32))


def test_checkpoint_ignores_partial_writes():
    with tempfile.TemporaryDirectory() as d:
        state = _tiny_state()
        checkpoint.save(d, 1, state)
        # simulate a crash mid-save: stale tmp dir + incomplete step dir
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        os.makedirs(os.path.join(d, "step_00000003"))  # no manifest
        assert checkpoint.latest_step(d) == 1
        _, step, _ = checkpoint.restore(d, state)
        assert step == 1


def test_run_with_restarts_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        crashes = {"n": 0}

        def loop(resume):
            start = checkpoint.latest_step(d) or 0
            state = _tiny_state()
            for s in range(start + 1, 11):
                if s == 5 and crashes["n"] == 0:
                    crashes["n"] += 1
                    raise RuntimeError("injected node failure")
                checkpoint.save(d, s, state)
            return 10

        final = fault.run_with_restarts(loop, max_restarts=2)
        assert final == 10
        assert crashes["n"] == 1
        assert checkpoint.latest_step(d) == 10


def test_straggler_watchdog_flags_outliers():
    import time

    wd = fault.StragglerWatchdog(window=16, threshold=2.0)
    for i in range(10):
        wd.step_start()
        time.sleep(0.002)
        wd.step_end()
    wd.step_start()
    time.sleep(0.05)
    assert wd.step_end() is True
    assert wd.flagged == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism_and_restart_replay():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    ds1 = SyntheticLMDataset(cfg)
    ds2 = SyntheticLMDataset(cfg)
    b1 = ds1.batch_at(7)
    b2 = ds2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, -1] == -1).all()
    # host sharding partitions the global batch deterministically
    h0 = SyntheticLMDataset(DataConfig(vocab_size=128, seq_len=16,
                                       global_batch=4, process_index=0,
                                       process_count=2))
    h1 = SyntheticLMDataset(DataConfig(vocab_size=128, seq_len=16,
                                       global_batch=4, process_index=1,
                                       process_count=2))
    assert h0.batch_at(0)["tokens"].shape == (2, 16)
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_markov_data_is_learnable():
    """Markov mode must beat uniform entropy (structure exists to learn)."""
    cfg = DataConfig(vocab_size=128, seq_len=64, global_batch=8)
    ds = SyntheticLMDataset(cfg)
    b = ds.batch_at(0)
    # bigram conditional entropy << unigram entropy for markov data
    tokens = b["tokens"].reshape(-1)
    pairs = {}
    for a, c in zip(tokens[:-1], tokens[1:]):
        pairs.setdefault(int(a), []).append(int(c))
    ents = []
    for a, nxt in pairs.items():
        if len(nxt) < 4:
            continue
        _, counts = np.unique(nxt, return_counts=True)
        p = counts / counts.sum()
        ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < np.log(128) * 0.6


# ---------------------------------------------------------------------------
# sharding rules (AbstractMesh: no devices needed)
# ---------------------------------------------------------------------------


def test_spec_for_rules():
    from jax.sharding import PartitionSpec as P

    from repro.parallel import make_abstract_mesh, spec_for

    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    # TP on d_ff, FSDP on d_model
    assert spec_for(mesh, (2560, 7680), ("d_model", "d_ff")) == \
        P(("pod", "data"), "model")
    # MQA kv projection width (1 head x 256) still shards over head_dim
    assert spec_for(mesh, (2560, 256), ("d_model", "kv_heads")) == \
        P(("pod", "data"), "model")
    # a width that doesn't divide the axis falls back to replication
    assert spec_for(mesh, (2560, 8), ("d_model", "kv_heads")) == \
        P(("pod", "data"), None)
    # mixtral experts=8 don't divide model=16 -> d_ff takes TP instead
    assert spec_for(mesh, (8, 6144, 16384), ("expert", "d_model", "d_ff")) == \
        P(None, ("pod", "data"), "model")
    # deepseek 64 experts take the model axis; d_ff then replicates
    assert spec_for(mesh, (64, 2048, 1408), ("expert", "d_model", "d_ff")) == \
        P("model", ("pod", "data"), None)
    # layers axis never sharded
    assert spec_for(mesh, (26, 2304), ("layers", "d_model")) == \
        P(None, ("pod", "data"))


# ---------------------------------------------------------------------------
# HLO cost walker
# ---------------------------------------------------------------------------


def test_hlo_walker_scan_multiplicity():
    from repro.launch.hlo_analysis import analyze

    def scanned(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["dot_flops"] == pytest.approx(8 * 2 * 64**3, rel=0.01)
    assert r["loops"] and r["loops"][0]["trips"] == 8


def test_hlo_walker_nested_scan():
    from repro.launch.hlo_analysis import analyze

    def inner(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y

    def outer(x, w):
        y, _ = jax.lax.scan(lambda c, _: (inner(c, w), None), x, None, length=3)
        return y

    c = jax.jit(outer).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["dot_flops"] == pytest.approx(3 * 4 * 2 * 64**3, rel=0.01)


def test_metrics_logger_roundtrip(tmp_path):
    from repro.train.metrics import MetricsLogger, read_metrics

    p = str(tmp_path / "metrics.jsonl")
    ml = MetricsLogger(p)
    ml.log(0, {"loss": jnp.asarray(2.5), "lr": 1e-3})
    ml.log(1, {"loss": 2.4}, tokens_per_step=1024,
           model_flops_per_step=1e12, num_chips=2)
    ml.close()
    recs = read_metrics(p)
    assert len(recs) == 2
    assert recs[0]["loss"] == 2.5
    assert "tokens_per_s" in recs[1] and "mfu" in recs[1]


def test_elastic_restore_across_device_counts():
    """Checkpoints are mesh-agnostic: save on N devices, restore on M.

    Two subprocesses with different forced device counts share one
    checkpoint directory; values must round-trip exactly.
    """
    import tempfile

    script = r'''
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.parallel import tree_shardings
from repro.train import checkpoint, init_state, state_axes
cfg = get_reduced("phi4-mini-3.8b")
state, axes = init_state(jax.random.PRNGKey(0), cfg)
mesh = make_host_mesh(model_parallel=2)
sh = tree_shardings(mesh, state, state_axes(axes))
state = jax.device_put(state, sh)
d = sys.argv[2]
if sys.argv[3] == "save":
    checkpoint.save(d, 1, state)
    print("SAVED", float(jax.tree_util.tree_leaves(state)[0].sum()))
else:
    restored, step, _ = checkpoint.restore(d, state, shardings=sh)
    match = all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(
        jax.tree_util.tree_leaves(jax.device_get(state)),
        jax.tree_util.tree_leaves(jax.device_get(restored))))
    print("RESTORED", step, match)
    assert match
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    with tempfile.TemporaryDirectory() as d:
        r1 = subprocess.run([sys.executable, "-c", script, "8", d, "save"],
                            env=env, capture_output=True, text=True,
                            timeout=600)
        assert r1.returncode == 0, r1.stderr[-2000:]
        r2 = subprocess.run([sys.executable, "-c", script, "4", d, "load"],
                            env=env, capture_output=True, text=True,
                            timeout=600)
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "RESTORED 1 True" in r2.stdout
