"""Test bootstrap: make ``import repro`` work from a bare checkout.

Puts ``src/`` on sys.path so ``python -m pytest`` works without exporting
PYTHONPATH (the tier-1 command still sets it; both paths agree).
"""
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
_SRC = _ROOT / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

# Persistent XLA compilation cache: the suite is compile-dominated on CPU,
# so repeat runs (local dev, CI re-runs) skip most XLA work. Repo-local and
# gitignored; harmless if the backend doesn't support it.
try:
    import jax

    jax.config.update("jax_compilation_cache_dir",
                      str(_ROOT / ".jax_cache"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
except Exception:  # pragma: no cover - cache is best-effort
    pass
