"""Stochastic sampling: filtering semantics, per-slot RNG determinism,
and losslessness of rejection-sampling speculative verification.

The statistical cases use fixed seeds, so they are deterministic — a
chi-square "test" here is a frozen numerical check against the exact
filtered target distribution, with Wilson-Hilferty critical values (no
scipy in the CI image).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig, model
from repro.serve import (ContinuousBatchingEngine, SamplingParams,
                         ServeConfig)
from repro.serve import sampling as S


def _chi2_crit(df, z=3.0902):
    """Wilson-Hilferty chi-square critical value (alpha ~= 1e-3)."""
    return df * (1 - 2 / (9 * df) + z * np.sqrt(2 / (9 * df))) ** 3


def _vec(n, temps=1.0, top_ps=1.0, top_ks=0, seeds=0, counters=0):
    def arr(x, dt):
        return jnp.full((n,), x, dt) if np.isscalar(x) else jnp.asarray(
            x, dt)
    return (arr(temps, jnp.float32), arr(top_ps, jnp.float32),
            arr(top_ks, jnp.int32), arr(seeds, jnp.uint32),
            arr(counters, jnp.int32))


# ---------------------------------------------------------------------------
# SamplingParams validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    dict(temperature=-0.1), dict(temperature=float("nan")),
    dict(top_p=0.0), dict(top_p=1.5), dict(top_k=-1),
    dict(seed="abc")])
def test_sampling_params_validate_rejects(bad):
    with pytest.raises(ValueError):
        SamplingParams(**bad).validate()


def test_resolve_seed_explicit_and_derived():
    assert S.resolve_seed(SamplingParams(seed=42), 0, 7) == 42
    a = S.resolve_seed(SamplingParams(), 0, 1)
    b = S.resolve_seed(SamplingParams(), 0, 2)
    assert a != b  # distinct requests draw distinct streams by default
    assert 0 <= a < 2 ** 32


# ---------------------------------------------------------------------------
# filtering semantics (mass properties)
# ---------------------------------------------------------------------------


def test_top_k_keeps_exactly_k_largest():
    logits = jnp.asarray([[3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0, 3.5]])
    t, p, k, _, _ = _vec(1, top_ks=3)
    out = np.asarray(S.filter_logits(logits, t, p, k))
    keep = np.isfinite(out[0])
    assert keep.sum() == 3
    assert set(np.flatnonzero(keep)) == {4, 6, 2}  # the 3 largest


def test_top_p_keeps_smallest_covering_prefix():
    probs = np.asarray([0.4, 0.3, 0.2, 0.1])
    logits = jnp.log(jnp.asarray(probs))[None]
    t, p, k, _, _ = _vec(1, top_ps=0.6)
    out = np.asarray(S.filter_logits(logits, t, p, k))
    keep = np.isfinite(out[0])
    # {0.4} covers only 0.4 < 0.6, {0.4, 0.3} reaches 0.7 >= 0.6
    assert set(np.flatnonzero(keep)) == {0, 1}
    # renormalized mass of the kept set is the filtered distribution
    pt = np.asarray(jax.nn.softmax(jnp.asarray(out[0])))
    np.testing.assert_allclose(pt[:2], probs[:2] / probs[:2].sum(),
                               rtol=1e-6)
    assert pt[2:].sum() == 0


def test_no_filter_is_noop_and_temperature_scales():
    logits = jnp.asarray([[2.0, 0.0, -1.0]])
    t, p, k, _, _ = _vec(1, temps=2.0)
    out = np.asarray(S.filter_logits(logits, t, p, k))
    np.testing.assert_allclose(out, [[1.0, 0.0, -0.5]], rtol=1e-6)


def test_greedy_rows_are_exact_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(16, 33)).astype(np.float32))
    t, p, k, s, c = _vec(16, temps=0.0, seeds=np.arange(16))
    toks = np.asarray(S.sample(logits, t, p, k, s, c))
    np.testing.assert_array_equal(toks, np.argmax(np.asarray(logits), -1))


# ---------------------------------------------------------------------------
# per-slot RNG determinism (module level)
# ---------------------------------------------------------------------------


def test_sample_is_pure_function_of_seed_and_counter():
    """The same (seed, counter) row must sample the same token no matter
    where it sits in a batch or who its neighbours are."""
    rng = np.random.default_rng(1)
    row = rng.normal(size=(1, 17)).astype(np.float32)
    noise = rng.normal(size=(7, 17)).astype(np.float32)

    def tok_at(batch_pos, n, seed, ctr):
        logits = np.concatenate([noise[:batch_pos], row,
                                 noise[batch_pos:n - 1]], axis=0)
        seeds = np.arange(100, 100 + n)
        seeds[batch_pos] = seed
        ctrs = np.full(n, 9)
        ctrs[batch_pos] = ctr
        t, p, k, s, c = _vec(n, temps=0.8, top_ps=0.9, seeds=seeds,
                             counters=ctrs)
        return int(np.asarray(S.sample(jnp.asarray(logits), t, p, k, s,
                                       c))[batch_pos])

    want = tok_at(0, 1, seed=7, ctr=3)
    assert tok_at(0, 4, seed=7, ctr=3) == want
    assert tok_at(2, 5, seed=7, ctr=3) == want
    assert tok_at(7, 8, seed=7, ctr=3) == want
    # the counter advances the stream: over many counters the same seed
    # must not be stuck on one token
    toks = {tok_at(0, 1, seed=7, ctr=i) for i in range(32)}
    assert len(toks) > 1


# ---------------------------------------------------------------------------
# distribution correctness: plain sampling and rejection verification
# both match the exact filtered target distribution (chi-square GOF)
# ---------------------------------------------------------------------------

_PROBS = np.asarray([0.30, 0.22, 0.16, 0.12, 0.08, 0.06, 0.04, 0.02])


def _target_dist(temps, top_ps, top_ks):
    logits = jnp.log(jnp.asarray(_PROBS, jnp.float32))[None]
    t, p, k, _, _ = _vec(1, temps=temps, top_ps=top_ps, top_ks=top_ks)
    return np.asarray(jax.nn.softmax(S.filter_logits(logits, t, p, k)[0]))


def _chisq_gof(counts, expected_probs, n):
    support = expected_probs > 0
    assert counts[~support].sum() == 0, "mass outside the filtered support"
    exp = expected_probs[support] * n
    stat = float((((counts[support] - exp) ** 2) / exp).sum())
    df = int(support.sum()) - 1
    return stat, _chi2_crit(df)


def test_sample_matches_filtered_distribution():
    n = 4000
    temps, top_ps, top_ks = 0.9, 0.92, 6
    logits = jnp.tile(jnp.log(jnp.asarray(_PROBS, jnp.float32)), (n, 1))
    t, p, k, s, c = _vec(n, temps=temps, top_ps=top_ps, top_ks=top_ks,
                         seeds=np.arange(n))
    toks = np.asarray(S.sample(logits, t, p, k, s, c))
    counts = np.bincount(toks, minlength=len(_PROBS)).astype(np.float64)
    stat, crit = _chisq_gof(counts, _target_dist(temps, top_ps, top_ks), n)
    assert stat < crit, (stat, crit)


def test_rejection_verification_is_lossless():
    """Marginal of the first emitted token under point-mass rejection
    sampling == plain filtered sampling, for ANY draft choice — including
    drafts outside the filtered support (always rejected) and the modal
    draft (usually accepted)."""
    n = 4000
    temps, top_ps, top_ks = 0.9, 0.92, 6
    v = len(_PROBS)
    row = np.log(_PROBS, dtype=np.float32)
    logits = jnp.asarray(np.tile(row, (n, 2, 1)))  # K=1: draft + bonus
    drafts = jnp.asarray((np.arange(n) % v).reshape(n, 1), jnp.int32)
    t, p, k, s, c = _vec(n, temps=temps, top_ps=top_ps, top_ks=top_ks,
                         seeds=np.arange(n))
    n_emit, emitted = S.verify_rejection(logits, drafts, t, p, k, s, c)
    n_emit, emitted = np.asarray(n_emit), np.asarray(emitted)
    assert set(np.unique(n_emit)) == {1, 2}  # both branches exercised
    first = emitted[:, 0]
    counts = np.bincount(first, minlength=v).astype(np.float64)
    stat, crit = _chisq_gof(counts, _target_dist(temps, top_ps, top_ks), n)
    assert stat < crit, (stat, crit)
    # accepted rows emitted their draft verbatim
    acc = n_emit == 2
    np.testing.assert_array_equal(first[acc], np.asarray(drafts)[acc, 0])
    # rejected rows never emit the rejected draft (removed and renormed)
    assert not np.any(first[~acc] == np.asarray(drafts)[~acc, 0])


def test_rejection_greedy_rows_are_exact_prefix_match():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(8, 4, 11)).astype(np.float32))
    targets = np.argmax(np.asarray(logits), -1)
    drafts = targets[:, :3].copy()
    drafts[::2, 1] ^= 1  # break the match at position 1 on even rows
    t, p, k, s, c = _vec(8, temps=0.0, seeds=np.arange(8))
    n_emit, emitted = S.verify_rejection(
        logits, jnp.asarray(drafts), t, p, k, s, c)
    n_emit, emitted = np.asarray(n_emit), np.asarray(emitted)
    np.testing.assert_array_equal(n_emit[::2], 2)  # accept 1 + correction
    np.testing.assert_array_equal(n_emit[1::2], 4)  # all + bonus
    for i in range(8):
        np.testing.assert_array_equal(emitted[i, :n_emit[i]],
                                      targets[i, :n_emit[i]])


# ---------------------------------------------------------------------------
# engine level: determinism under batch composition, churn, preemption;
# spec decode at temperature > 0
# ---------------------------------------------------------------------------


def _cfg():
    return ModelConfig(
        name="t", family="dense", d_model=64, vocab_size=128,
        pattern=(BlockDef("attn"),), num_groups=1, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16, quantize_acts=False,
                            quantize_kv_cache=True))


@pytest.fixture(scope="module")
def model_and_cfg():
    cfg = _cfg()
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _run(params, cfg, reqs, **sc_kwargs):
    eng = ContinuousBatchingEngine(params, cfg, ServeConfig(**sc_kwargs))
    ids = [eng.submit(p, m, sampling_params=sp) for p, m, sp in reqs]
    out = eng.run()
    return eng, {i: out[i] for i in ids}


def test_engine_stream_independent_of_batch_composition(model_and_cfg):
    """Same request (prompt, seed): identical sampled tokens alone, in a
    mixed batch, and under a pool tight enough to force swap preemption."""
    params, cfg = model_and_cfg
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 128, (4,)).astype(np.int32)
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=123)
    others = [(rng.integers(0, 128, (s,)).astype(np.int32), m,
               SamplingParams(temperature=1.2, seed=i))
              for i, (s, m) in enumerate([(4, 14), (7, 5), (3, 8)])]

    _, alone = _run(params, cfg, [(prompt, 14, sp)],
                    max_seq=20, max_slots=2, page_size=4)
    want = alone[0]
    _, mixed = _run(params, cfg, [(prompt, 14, sp)] + others[:2],
                    max_seq=20, max_slots=3, page_size=4)
    np.testing.assert_array_equal(mixed[0], want)
    eng, churn = _run(params, cfg, [(prompt, 14, sp)] + others,
                      max_seq=20, max_slots=2, page_size=4, num_pages=7)
    assert eng.scheduler.preemptions >= 1, "pool sizing must force a swap"
    np.testing.assert_array_equal(churn[0], want)


def test_engine_same_seed_reproducible_across_engines(model_and_cfg):
    params, cfg = model_and_cfg
    prompt = np.arange(1, 9, dtype=np.int32)
    sp = SamplingParams(temperature=1.0, top_k=40, seed=7)
    _, a = _run(params, cfg, [(prompt, 10, sp)], max_seq=24, max_slots=2,
                page_size=8)
    _, b = _run(params, cfg, [(prompt, 10, sp)], max_seq=24, max_slots=2,
                page_size=8)
    np.testing.assert_array_equal(a[0], b[0])
    # a different seed must (for this prompt) give a different stream
    _, d = _run(params, cfg,
                [(prompt, 10, SamplingParams(temperature=1.0, top_k=40,
                                             seed=8))],
                max_seq=24, max_slots=2, page_size=8)
    assert not np.array_equal(a[0], d[0])


def test_spec_decode_runs_sampled_and_is_deterministic(model_and_cfg):
    """Speculative decoding at temperature > 0: the greedy-only
    restriction is gone, the engine emits the full token budget, and the
    (seed, counter) contract holds across engine instances."""
    params, cfg = model_and_cfg
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, 128, (6,)).astype(np.int32), 12,
             SamplingParams(temperature=0.8, top_p=0.95, seed=i))
            for i in range(3)]
    kw = dict(max_seq=32, max_slots=3, page_size=8, spec_decode=True,
              num_draft_tokens=3)
    eng1, a = _run(params, cfg, reqs, **kw)
    _, b = _run(params, cfg, reqs, **kw)
    for i in range(3):
        assert a[i].shape[0] == reqs[i][0].shape[0] + 12
        np.testing.assert_array_equal(a[i], b[i])
    assert eng1.cache_stats()["spec_steps"] >= 1
