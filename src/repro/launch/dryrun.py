import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the appropriate step (train_step / prefill /
serve_step) against the production mesh with full-size ShapeDtypeStruct
inputs (no allocation), compiles it, and records:

  * memory_analysis()      — per-device argument/output/temp bytes,
  * cost_analysis()        — HLO FLOPs and bytes accessed,
  * collective traffic     — parsed from the optimized HLO text,

into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``, which §Roofline
reads. Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.nn import model
from repro.parallel import (batch_shardings, cache_shardings, replicated,
                            tree_shardings)
from repro.parallel.ctx import use_mesh
from repro.train import OptimConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = S.input_specs(cfg, shape)

    with use_mesh(mesh):
        if shape.kind == "train":
            state, axes = spec["state"], spec["axes"]
            state_sh = tree_shardings(mesh, state, axes)
            batch_sh = batch_shardings(mesh, spec["batch"])
            step = make_train_step(cfg, OptimConfig(),
                                   num_microbatches=cfg.train_microbatches,
                                   param_shardings=state_sh["params"])
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, replicated(mesh)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, spec["batch"])
        elif shape.kind == "prefill":
            params, axes = spec["params"], spec["axes"]
            p_sh = tree_shardings(mesh, params, axes)
            batch_sh = batch_shardings(mesh, spec["batch"])

            def prefill_step(params, batch):
                return model.prefill(
                    params, cfg, tokens=batch.get("tokens"),
                    embeds=batch.get("embeds"), max_seq=shape.seq_len)

            jitted = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh))
            lowered = jitted.lower(params, spec["batch"])
        else:  # decode
            params, axes = spec["params"], spec["axes"]
            p_sh = tree_shardings(mesh, params, axes)
            cache = spec["cache"]
            cache_sh = cache_shardings(mesh, cache, shape.global_batch)
            tok_sh = batch_shardings(mesh, spec["tokens"])

            def serve_step(params, cache, tokens, pos):
                return model.decode_step(
                    params, cfg, cache, tokens=tokens.get("tokens"),
                    embeds=tokens.get("embeds"), pos=pos)

            jitted = jax.jit(
                serve_step,
                in_shardings=(p_sh, cache_sh, tok_sh, replicated(mesh)),
                out_shardings=(replicated(mesh), cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params, cache, spec["tokens"], spec["pos"])
        compiled = lowered.compile()
    return lowered, compiled, mesh


def run_cell(arch: str, shape_name: str, mesh_kind: str, save_hlo=False):
    t0 = time.time()
    multi = mesh_kind == "multi"
    lowered, compiled, mesh = _lower_cell(arch, shape_name, multi)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    walk = analyze(hlo)  # loop-trip-aware accounting (hlo_analysis.py)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "devices": int(len(mesh.devices.flat)),
        "compile_s": round(time.time() - t0, 1),
        # per-device, loop-aware (the roofline inputs):
        "dot_flops": walk["dot_flops"],
        "hbm_bytes": walk["hbm_bytes"],
        "collectives": {
            "bytes_by_op": walk["collective_bytes"],
            "counts": walk["collective_counts"],
            "total_bytes": walk["collective_total"],
        },
        "loops": walk["loops"],
        # raw XLA aggregates (loop bodies counted ONCE — kept for reference):
        "xla_cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    print(f"[dryrun] OK {arch} {shape_name} {mesh_kind}: "
          f"dotF={rec['dot_flops']:.3e} hbmB={rec['hbm_bytes']:.3e} "
          f"collB={walk['collective_total']:.3e} "
          f"temp={mem.temp_size_in_bytes:.3e} ({rec['compile_s']}s)")
    return rec


def cells(mesh_kinds=("single", "multi")):
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if not shape_applicable(cfg, shape):
                continue
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    mesh_kinds = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    todo = (list(cells(mesh_kinds)) if args.all
            else [(args.arch, args.shape, mk) for mk in mesh_kinds])
    failures = []
    for arch, shape_name, mk in todo:
        path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mk}.json")
        if args.skip_done and os.path.exists(path):
            print(f"[dryrun] skip (done) {arch} {shape_name} {mk}")
            continue
        try:
            run_cell(arch, shape_name, mk, save_hlo=args.save_hlo)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape_name, mk, repr(e)))
            print(f"[dryrun] FAIL {arch} {shape_name} {mk}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
