"""Optimized-HLO cost walker with loop-trip-count accounting.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, which
undercounts scanned-layer models by ~num_layers x (verified in
EXPERIMENTS.md §Dry-run). This walker parses the optimized HLO text into
computations, recovers each while loop's trip count from its condition's
compare-against-constant, propagates multiplicities through nested loops,
and then accounts, per device:

  * dot FLOPs           (2 * prod(result dims) * prod(contracting dims)),
  * HBM traffic         (operand + result bytes of every top-level op in
                         entry/loop-body computations; fusions count as one
                         op — the standard XLA bytes-accessed model),
  * collective traffic  (ring-model bytes by op type and replica-group size).

This is the substrate for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e8m0fnu": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f4e2m1fn": 0.5,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(%[\w\.\-]+)\s*\(.*->.*\{\s*$")
_ENTRY_RE = re.compile(r"^ENTRY\s+(%[\w\.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=([%\w\.\-]+),\s*body=([%\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_OP_RE = re.compile(r"^(\w+)\[")


def _dtype_bytes(dt: str) -> float:
    return _DTYPE_BYTES.get(dt, 4)


def shape_bytes(type_str: str) -> float:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0.0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES and not dt.startswith(("f", "s", "u", "b", "p")):
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _dtype_bytes(dt)
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    whiles: List[tuple]  # (cond_name, body_name)

    def operand_read_bytes(self, comps: "Dict[str, Computation]",
                           operand_names, shapes) -> float:
        """Effective read traffic of this *fusion call*'s operands.

        A fusion parameter consumed only through ``dynamic-slice`` inside
        the fused computation reads just the slice, not the buffer (scan
        reading its stacked xs). Likewise an operand that is only the
        target of an in-place ``dynamic-update-slice`` touches only the
        update region. Everything else reads its full shape.
        """
        params = [op for op in self.ops if op.opcode == "parameter"]
        by_index = {}
        for op in params:
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                by_index[int(m.group(1))] = op.name
        total = 0.0
        for i, oname in enumerate(operand_names):
            full = shape_bytes(shapes.get(oname, ""))
            pname = by_index.get(i)
            if pname is None:
                total += full
                continue
            uses = [op for op in self.ops if pname in op.operands]
            if uses and all(u.opcode == "dynamic-slice" and
                            u.operands and u.operands[0] == pname
                            for u in uses):
                total += sum(shape_bytes(u.result_type) for u in uses)
            elif uses and all(u.opcode == "dynamic-update-slice" and
                              u.operands and u.operands[0] == pname
                              for u in uses):
                # in-place update: write counted at result; read ~ update
                total += sum(shape_bytes(shapes.get(u.operands[1], ""))
                             if len(u.operands) > 1 else 0.0 for u in uses)
            else:
                total += full
        return total

    def write_bytes(self) -> float:
        """Effective write traffic of this fusion's result (in-place DUS
        roots write the update region, not the whole aliased buffer)."""
        root = self.ops[-1] if self.ops else None
        if root is not None and root.opcode == "dynamic-update-slice":
            shapes = {op.name: op.result_type for op in self.ops}
            if len(root.operands) > 1:
                return shape_bytes(shapes.get(root.operands[1], ""))
        return -1.0  # sentinel: use result shape

    def dot_flops_recursive(self, comps: "Dict[str, Computation]",
                            seen=frozenset()) -> float:
        """Dot FLOPs in this computation including called fusions.

        XLA (CPU especially) fuses dots into kLoop/kOutput fusion
        computations; flops must be attributed through the ``calls=`` edge.
        Traffic is NOT recursed — fusions read/write only at their boundary.
        """
        if self.name in seen:
            return 0.0
        shapes = {op.name: op.result_type for op in self.ops}
        total = 0.0
        for op in self.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, shapes)
            m = re.search(r"calls=(%[\w\.\-]+)", op.line)
            if m and op.opcode == "fusion":
                callee = comps.get(m.group(1).lstrip("%"))
                if callee is not None:
                    total += callee.dot_flops_recursive(
                        comps, seen | {self.name})
        return total


def _opcode_of(rhs: str) -> str:
    """Extract the opcode from an HLO def right-hand side."""
    m = re.match(r"(?:\([^)]*\)|\S+)\s+([\w\-]+)\(", rhs)
    return m.group(1) if m else ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        em = _ENTRY_RE.match(line)
        hdr = em or _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            name = hdr.group(1)
            if em:
                entry = name
            current = Computation(name=name.lstrip("%"), ops=[], whiles=[])
            comps[current.name] = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.groups()
        opcode = _opcode_of(rhs)
        tm = re.match(r"(\([^)]*\)|\S+)", rhs)
        result_type = tm.group(1) if tm else ""
        operands = re.findall(r"(%[\w\.\-]+)", rhs[rhs.find("("):])
        current.ops.append(OpInfo(name.lstrip("%"), result_type, opcode,
                                  [o.lstrip("%") for o in operands], line))
        wm = _WHILE_RE.search(line)
        if wm:
            current.whiles.append((wm.group(1).lstrip("%"),
                                   wm.group(2).lstrip("%")))
    if entry:
        comps["__entry__"] = comps[entry.lstrip("%")]
    return comps


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Trip bound from the loop condition's compare-vs-constant."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        for m in _CONST_RE.finditer(op.line):
            best = max(best, int(m.group(1)))
    return best


def multiplicities(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Effective execution count per computation (nested loops multiply)."""
    entry = comps.get("__entry__")
    mult: Dict[str, float] = defaultdict(float)

    def visit(comp: Computation, m: float, seen):
        if comp.name in seen:  # guard against cycles
            return
        mult[comp.name] += m
        for cond_name, body_name in comp.whiles:
            t = trip_count(comps, cond_name)
            body = comps.get(body_name)
            if body is not None:
                visit(body, m * t, seen | {comp.name})

    if entry is not None:
        visit(entry, 1.0, frozenset())
    return dict(mult)


_TRAFFIC_OPS = {
    "fusion", "dot", "convert", "copy", "transpose", "reshape", "broadcast",
    "dynamic-update-slice", "dynamic-slice", "slice", "concatenate", "pad",
    "reduce", "reduce-window", "select-and-scatter", "gather", "scatter",
    "iota", "compare", "select", "add", "multiply", "subtract", "divide",
    "exponential", "tanh", "rsqrt", "sort", "bitcast-convert",
    "custom-call",
}


def _dot_flops(op: OpInfo, shapes: Dict[str, str]) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m:
        return 0.0
    lhs_type = shapes.get(op.operands[0], "") if op.operands else ""
    sm = _SHAPE_RE.match(lhs_type)
    if not sm:
        return 0.0
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    for idx in m.group(1).split(","):
        if idx:
            contract *= lhs_dims[int(idx)]
    rm = _SHAPE_RE.match(op.result_type)
    if not rm:
        return 0.0
    out = 1
    for d in rm.group(2).split(","):
        if d:
            out *= int(d)
    return 2.0 * out * contract


def _collective_traffic(op: OpInfo, shapes=None) -> Optional[tuple]:
    opcode = op.opcode.replace("-start", "")
    if opcode not in _COLL_OPS or op.opcode.endswith("-done"):
        return None
    size = shape_bytes(op.result_type)
    # XLA:CPU emulates bf16 dots in f32, so reductions of bf16 values show
    # up as f32 collectives fed by convert fusions. On the TPU target the
    # collective runs at the source width; charge bf16 bytes when every
    # operand is a convert-from-narrower fusion (name carries "convert").
    if shapes is not None and "f32[" in op.result_type and op.operands:
        if all("convert" in o for o in op.operands
               if not o.startswith(("constant", "iota"))):
            size *= 0.5
    g = re.search(r"replica_groups=\{\{([^}]*)\}", op.line)
    if g:
        n = len(g.group(1).split(","))
    else:
        g2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
        n = int(g2.group(2)) if g2 else 2
    n = max(n, 2)
    if opcode == "all-reduce":
        traffic = 2 * size * (n - 1) / n
    elif opcode == "all-gather":
        traffic = size * (n - 1) / n
    elif opcode == "reduce-scatter":
        traffic = size * (n - 1)
    elif opcode == "all-to-all":
        traffic = size * (n - 1) / n
    else:
        traffic = size
    return opcode, traffic


def analyze(hlo_text: str, top_k: int = 0) -> dict:
    """Full analysis: loop-aware flops / traffic / collectives per device.

    ``top_k`` > 0 additionally returns the largest individual collective
    and HBM-traffic contributors (op line head + effective bytes) — the
    profile view the perf iteration loop reads.
    """
    comps = parse_hlo(hlo_text)
    mult = multiplicities(comps)
    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    coll_n = defaultdict(float)
    loops = []
    top_coll = []
    top_hbm = []
    for cname, m in mult.items():
        comp = comps[cname]
        if cname == "__entry__":
            continue
        shapes = {op.name: op.result_type for op in comp.ops}
        is_body_or_entry = (m > 0)
        if not is_body_or_entry:
            continue
        # only walk entry + while bodies (fusions are accounted as single
        # ops by their callers; their internals must not be double counted)
        is_entry = comp is comps["__entry__"]
        called_as_body = any(
            cname == b for c in comps.values() for (_, b) in c.whiles)
        if not (is_entry or called_as_body):
            continue
        flops += m * comp.dot_flops_recursive(comps)
        for op in comp.ops:
            ct = _collective_traffic(op, shapes)
            if ct:
                coll[ct[0]] += m * ct[1]
                coll_n[ct[0]] += m
                if top_k:
                    top_coll.append((m * ct[1], m,
                                     op.line.strip()[:160]))
            if op.opcode in _TRAFFIC_OPS or op.opcode.replace("-start", "") in _COLL_OPS:
                out_b = shape_bytes(op.result_type)
                in_b = None
                if op.opcode == "fusion":
                    mm = re.search(r"calls=(%[\w\.\-]+)", op.line)
                    callee = comps.get(mm.group(1).lstrip("%")) if mm else None
                    if callee is not None:
                        in_b = callee.operand_read_bytes(comps, op.operands,
                                                         shapes)
                        wb = callee.write_bytes()
                        if wb >= 0:
                            out_b = wb
                elif op.opcode == "dynamic-slice":
                    in_b = out_b  # reads only the slice
                elif op.opcode == "dynamic-update-slice":
                    upd = (shape_bytes(shapes.get(op.operands[1], ""))
                           if len(op.operands) > 1 else 0.0)
                    in_b, out_b = upd, upd  # in-place slice write
                if in_b is None:
                    in_b = sum(shape_bytes(shapes.get(o, ""))
                               for o in op.operands)
                hbm += m * (out_b + in_b)
                if top_k:
                    top_hbm.append((m * (out_b + in_b), m,
                                    op.opcode, op.result_type[:60]))
        for cond_name, body_name in comp.whiles:
            loops.append({"body": body_name,
                          "trips": trip_count(comps, cond_name),
                          "outer_mult": m})
    out = {
        "dot_flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_counts": dict(coll_n),
        "collective_total": float(sum(coll.values())),
        "loops": loops,
        "num_computations": len(comps) - 1,
    }
    if top_k:
        out["top_collectives"] = sorted(top_coll, reverse=True)[:top_k]
        out["top_hbm"] = sorted(top_hbm, reverse=True)[:top_k]
    return out
