"""Production training launcher.

Single-process usage (CPU dev / one TPU host):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 200 --ckpt-dir /tmp/ckpt

Multi-host posture: call ``jax.distributed.initialize()`` (env-driven) when
``--multihost`` is set; data sharding comes from process_index/count; only
process 0 writes checkpoints. Fault tolerance: auto-resume from the latest
complete checkpoint, SIGTERM-graceful save, straggler logging, periodic
checkpoints every ``--ckpt-every`` steps.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.parallel import batch_shardings, replicated, tree_shardings
from repro.parallel.ctx import use_mesh
from repro.train import (OptimConfig, checkpoint, fault, init_state,
                         make_train_step, state_axes)

log = logging.getLogger("repro.train")


def build(cfg, opt_cfg, mesh, num_microbatches):
    state, axes = init_state(jax.random.PRNGKey(0), cfg)
    st_axes = state_axes(axes)
    state_sh = tree_shardings(mesh, state, st_axes)
    state = jax.device_put(state, state_sh)
    step_fn = make_train_step(cfg, opt_cfg, num_microbatches)
    return state, state_sh, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--multihost", action="store_true")
    ap.add_argument("--quant", default="",
                    choices=["", "wide", "mxfp8", "mxfp4"])
    args = ap.parse_args(argv)

    if args.multihost:
        jax.distributed.initialize()
    logging.basicConfig(level=logging.INFO)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.quant:
        from repro.core import MXFP4, MXFP8, WIDE

        cfg = cfg.replace(quant={"wide": WIDE, "mxfp8": MXFP8,
                                 "mxfp4": MXFP4}[args.quant].replace(
            block_size=cfg.quant.block_size))
    opt_cfg = OptimConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    ds = SyntheticLMDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, num_codebooks=cfg.num_codebooks,
        process_index=jax.process_index(), process_count=jax.process_count()))

    guard = fault.PreemptionGuard()
    watchdog = fault.StragglerWatchdog()

    def loop(_resume):
        state, state_sh, step_fn = build(cfg, opt_cfg, mesh,
                                         args.microbatches)
        start = 0
        if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
            state, start, extra = checkpoint.restore(
                args.ckpt_dir, state, shardings=state_sh)
            log.info("resumed from step %d", start)
        batch_sh = batch_shardings(mesh, jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.asarray(x).dtype),
            ds.batch_at(0)))
        with use_mesh(mesh):
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, replicated(mesh)),
                             donate_argnums=(0,))
            for s in range(start, args.steps):
                watchdog.step_start()
                batch = jax.device_put(
                    {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()},
                    batch_sh)
                state, metrics = jitted(state, batch)
                watchdog.step_end()
                if s % 10 == 0 or s == args.steps - 1:
                    log.info("step %d loss %.4f gnorm %.3f lr %.2e", s,
                             float(metrics["loss"]),
                             float(metrics["grad_norm"]),
                             float(metrics["lr"]))
                should_save = args.ckpt_dir and (
                    (s + 1) % args.ckpt_every == 0 or s == args.steps - 1
                    or guard.should_stop)
                if should_save and jax.process_index() == 0:
                    checkpoint.save(args.ckpt_dir, s + 1, state,
                                    extra={"data_step": s + 1})
                if guard.should_stop:
                    log.warning("preempted: saved at step %d, exiting", s + 1)
                    return s + 1
        return args.steps

    final = fault.run_with_restarts(loop, max_restarts=3)
    log.info("training done at step %d (stragglers flagged: %d)", final,
             watchdog.flagged)
    return final


if __name__ == "__main__":
    main()
