"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Weak-type-correct, shardable stand-ins; nothing is allocated. ``train``
shapes produce the train_step signature (state, batch); ``prefill`` the
prompt-processing signature; ``decode`` the serve_step signature (one new
token against a seq_len KV cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.nn import model
from repro.nn.config import ModelConfig
from repro.train import loop as train_loop
from repro.train import optim


def batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Training/prefill batch ShapeDtypeStructs for one arch."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "vlm":
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cfg.num_codebooks > 1:
        return {
            "tokens": jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), i32),
            "labels": jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }


def state_specs(cfg: ModelConfig):
    """(train-state ShapeDtypeStructs, axes pytree) without allocating."""
    def go(key):
        state, _ = train_loop.init_state(key, cfg)
        return state

    shapes = jax.eval_shape(go, jax.random.PRNGKey(0))
    return shapes, train_loop.state_axes(model_axes(cfg))


def model_axes(cfg: ModelConfig):
    """Static axes pytree (no array work: init under eval_shape)."""
    out = {}

    def grab(key):
        params, axes = model.init(key, cfg)
        out["axes"] = axes
        return params

    jax.eval_shape(grab, jax.random.PRNGKey(0))
    return out["axes"]


def params_specs(cfg: ModelConfig):
    shapes = jax.eval_shape(
        lambda key: model.init(key, cfg)[0], jax.random.PRNGKey(0))
    return shapes, model_axes(cfg)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Decode cache ShapeDtypeStructs (ring buffers bound windowed layers)."""
    return jax.eval_shape(
        lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))


def decode_token_specs(cfg: ModelConfig, shape: ShapeSpec):
    b = shape.global_batch
    if cfg.family == "vlm":
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                               jnp.bfloat16)}
    if cfg.num_codebooks > 1:
        return {"tokens": jax.ShapeDtypeStruct((b, 1, cfg.num_codebooks),
                                               jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """All ShapeDtypeStructs for the step this shape lowers."""
    if shape.kind == "train":
        state, axes = state_specs(cfg)
        return {"state": state, "axes": axes,
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        params, axes = params_specs(cfg)
        return {"params": params, "axes": axes,
                "batch": batch_specs(cfg, shape)}
    params, axes = params_specs(cfg)
    return {
        "params": params, "axes": axes,
        "cache": cache_specs(cfg, shape),
        "tokens": decode_token_specs(cfg, shape),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
