"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run forces 512 host devices via XLA_FLAGS before first jax init, while
smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import math

import jax


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across versions: older JAX has no ``axis_types``."""
    try:
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (dryrun.py does this)")
    return _make_mesh(shape, axes, devices[:n])


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    dp = n // model_parallel
    return _make_mesh((dp, model_parallel), ("data", "model"),
                      jax.devices()[: dp * model_parallel])
