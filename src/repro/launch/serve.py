"""Serving launcher: MX weights + paged MX KV cache, continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 --quant mxfp8 --quantize-kv

``--engine continuous`` (default) runs the paged continuous-batching
engine with ragged arrivals; ``--engine fixed`` runs the fixed-slot
reference loop. ``--ragged`` staggers prompt lengths so paging has
something to win on.

``--serve`` starts the asyncio HTTP/SSE front end instead of the batch
workload: POST /v1/generate streams tokens as server-sent events,
/v1/cancel aborts a request mid-flight, /v1/health reports engine and
overload stats. ``--slo-ms``/``--max-queue`` arm load shedding (429),
``--temperature/--top-p/--top-k/--seed`` set the default sampling each
request can override in its own body:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --serve --port 8000 --temperature 0.8 --top-p 0.95 --slo-ms 500
"""
from __future__ import annotations

import argparse
import asyncio
import logging
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.nn import model
from repro.serve import (AsyncServeEngine, FixedSlotEngine, ServeConfig,
                         ServeEngine, ServeHTTPServer, TierPolicy)

log = logging.getLogger("repro.serve")


def build_engine(cfg, serve_cfg, params, kind: str):
    if kind == "fixed":
        return FixedSlotEngine(params, cfg, serve_cfg)
    return ServeEngine(params, cfg, serve_cfg)


def _run_server(engine, args):
    """Run the HTTP/SSE front end until interrupted; graceful drain and
    prefix-snapshot write-back on the way out."""
    import os

    async def serve():
        if args.prefix_snapshot and os.path.exists(args.prefix_snapshot):
            n = engine.load_prefix_cache(args.prefix_snapshot)
            log.info("warm-started prefix cache: %d entries from %s",
                     n, args.prefix_snapshot)
        async_engine = AsyncServeEngine(engine)
        server = ServeHTTPServer(async_engine, host=args.host,
                                 port=args.port)
        await server.start()
        log.info("serving on http://%s:%d (POST /v1/generate, "
                 "/v1/cancel, /v1/drain; GET /v1/health)",
                 args.host, server.port)
        try:
            await server.serve_forever()
        except (KeyboardInterrupt, asyncio.CancelledError):
            pass
        finally:
            log.info("draining...")
            await async_engine.drain()
            await server.stop()
            if args.prefix_snapshot:
                n = engine.save_prefix_cache(args.prefix_snapshot)
                log.info("saved prefix cache: %d pages to %s",
                         n, args.prefix_snapshot)

    try:
        asyncio.run(serve())
    except KeyboardInterrupt:
        pass


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="default sampling temperature (0 = exact greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="default nucleus-sampling mass (1.0 = disabled)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="default top-k cutoff (0 = disabled)")
    ap.add_argument("--seed", type=int, default=0,
                    help="engine base RNG seed; each request's stream is "
                         "derived from (seed, request id) unless the "
                         "request carries its own seed")
    ap.add_argument("--slo-ms", type=float, default=0,
                    help="admission-latency SLO in ms: shed submissions "
                         "(429) once the predicted first-token latency "
                         "exceeds it (0 = no latency-model shedding)")
    ap.add_argument("--max-queue", type=int, default=-1,
                    help="hard queue-depth cap; submissions past it are "
                         "shed (429). -1 = unbounded")
    ap.add_argument("--serve", action="store_true",
                    help="start the HTTP/SSE server instead of running "
                         "the batch workload")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--prefix-snapshot", default="",
                    help="path to a prefix-cache snapshot "
                         "(save_prefix_cache): loaded at startup if it "
                         "exists, written back on clean server exit — "
                         "restarts warm-start shared prompt heads")
    ap.add_argument("--quant", default="",
                    choices=["", "wide", "mxfp8", "mxfp4"])
    ap.add_argument("--quantize-kv", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "fixed"])
    ap.add_argument("--max-slots", type=int, default=0,
                    help="decode slots for continuous batching "
                         "(default: --batch)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across requests")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of common system-prompt head across "
                         "requests (exercises the prefix cache)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix-tree prompt sharing")
    ap.add_argument("--decode-kernel", default="fused",
                    choices=["fused", "einsum"],
                    help="paged decode attention path: single-pass fused "
                         "Pallas flash-decode (default) or the reference "
                         "gather-and-dequantize einsum")
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "monolithic"],
                    help="prompt prefill path: 'chunked' (default) streams "
                         "fixed-size chunks straight into MX pages "
                         "(fused quantize-into-pages kernel, O(1) jit "
                         "traces, decode-interleaved admission); "
                         "'monolithic' is the dense reference oracle")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="chunked-prefill chunk length in tokens (must be "
                         "a multiple of --page-size)")
    ap.add_argument("--prefill-token-budget", type=int, default=0,
                    help="max prefill tokens per engine step, spent "
                         "round-robin across admitted prompts "
                         "(default: one chunk)")
    ap.add_argument("--tiered", action="store_true",
                    help="tiered mixed-format KV cache: new pages are "
                         "written in the base 8-bit MX format, idle pages "
                         "are background-repacked down the "
                         "fp8 -> fp6 -> fp4 ladder under a per-step "
                         "budget; --max-seq worth of fp8 bytes is "
                         "reinterpreted as a unit-metered byte budget")
    ap.add_argument("--tier-mid-fmt", default="fp6_e3m2",
                    choices=["fp6_e3m2", "fp6_e2m3", "fp4_e2m1"],
                    help="format warm pages repack to after "
                         "--tier-hot-steps idle steps")
    ap.add_argument("--tier-cold-fmt", default="fp4_e2m1",
                    choices=["fp6_e3m2", "fp6_e2m3", "fp4_e2m1"],
                    help="format cold pages repack to after "
                         "--tier-cold-steps idle steps")
    ap.add_argument("--tier-hot-steps", type=int, default=8,
                    help="engine steps without a write before a page "
                         "leaves the hot fp8 tier")
    ap.add_argument("--tier-cold-steps", type=int, default=32,
                    help="engine steps without a write before a mid-tier "
                         "page goes cold")
    ap.add_argument("--tier-repack-pages", type=int, default=4,
                    help="max pages repacked per engine step (bounds the "
                         "background repack work on the decode path)")
    ap.add_argument("--step-mode", default="ragged",
                    choices=["ragged", "split", "megakernel"],
                    help="engine step dispatch shape: 'ragged' (default) "
                         "packs decode tokens, speculative verify windows "
                         "and prefill chunks into ONE fused Pallas "
                         "dispatch per step with the K/V write done "
                         "in-kernel; 'split' runs the per-mode dispatches "
                         "(the validated oracle). Ragged needs the fused "
                         "kernel + a quantized KV cache and falls back to "
                         "split otherwise. 'megakernel' additionally "
                         "fuses the whole layer stack — norms, QKV+RoPE, "
                         "the paged MX page walk, output projection and "
                         "the gated MLP for EVERY layer — into ONE "
                         "pallas_call per step (the ragged step pays one "
                         "per layer); configs the fused stack cannot "
                         "serve fall back to the per-layer ragged step "
                         "with a logged reason")
    ap.add_argument("--prefill-max-chunks", type=int, default=1,
                    help="ragged-aware prefill budgeting: chunks one "
                         "prefilling sequence may stream in a single "
                         "ragged step while the batch is undersubscribed "
                         "(fewer active sequences than slots); a full "
                         "batch always drops back to 1 chunk/step so "
                         "decode rows are never starved")
    ap.add_argument("--mesh", type=int, default=0,
                    help="sharded serving: KV-head-parallel ways over a "
                         "(1, M) device mesh — the page pool and q/k/v "
                         "projections split along the KV-head axis, wo "
                         "stays replicated behind the step's one "
                         "all-gather, tokens stay identical to "
                         "single-device. Needs M devices (on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=M), the ragged step mode, and "
                         "num_kv_heads divisible by M. 0 = unsharded")
    ap.add_argument("--spec-decode", action="store_true",
                    help="greedy speculative decoding: draft K tokens per "
                         "step (prompt-lookup n-gram, no second model) and "
                         "verify them in one batched multi-token pass over "
                         "the paged MX cache — token-identical output, "
                         "fewer steps")
    ap.add_argument("--num-draft-tokens", type=int, default=4,
                    help="drafts per sequence per verify step (K)")
    args = ap.parse_args(argv)
    if args.spec_decode and args.engine != "continuous":
        ap.error("--spec-decode requires --engine continuous (the "
                 "fixed-slot reference engine has no verify path)")
    if args.serve and args.engine != "continuous":
        ap.error("--serve requires --engine continuous (the async front "
                 "end drives the continuous-batching step loop)")
    if args.mesh > 1 and args.engine != "continuous":
        ap.error("--mesh requires --engine continuous (sharding wraps "
                 "the continuous-batching ragged step)")
    if args.tiered:
        if args.engine != "continuous":
            ap.error("--tiered requires --engine continuous")
        if args.quant not in ("", "mxfp8") or not args.quantize_kv:
            ap.error("--tiered requires --quant mxfp8 --quantize-kv "
                     "(new writes land in the 8-bit base format)")
        args.quant = args.quant or "mxfp8"
    logging.basicConfig(level=logging.INFO)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.quant:
        from repro.core import MXFP4, MXFP8, WIDE

        q = {"wide": WIDE, "mxfp8": MXFP8, "mxfp4": MXFP4}[args.quant]
        cfg = cfg.replace(quant=q.replace(
            block_size=cfg.quant.block_size,
            quantize_acts=False,  # weight-only for serving
            quantize_kv_cache=args.quantize_kv))
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    max_seq = args.shared_prefix + args.prompt_len + args.new_tokens
    if args.spec_decode:
        # room for the worst-case verify window near the end of a request
        max_seq += args.num_draft_tokens
    serve_cfg = ServeConfig(
        max_seq=max_seq, temperature=args.temperature,
        top_p=args.top_p, top_k=args.top_k, seed=args.seed,
        slo_ms=args.slo_ms or None,
        max_queue=args.max_queue if args.max_queue >= 0 else None,
        max_slots=args.max_slots or args.batch, page_size=args.page_size,
        prefix_cache=not args.no_prefix_cache,
        decode_kernel=args.decode_kernel,
        spec_decode=args.spec_decode,
        num_draft_tokens=args.num_draft_tokens,
        prefill_mode=args.prefill_mode,
        prefill_chunk=args.prefill_chunk,
        prefill_token_budget=args.prefill_token_budget or None,
        step_mode=args.step_mode,
        prefill_max_chunks=args.prefill_max_chunks,
        mesh_shape=(1, args.mesh) if args.mesh > 1 else None,
        tiered=args.tiered,
        tier_policy=TierPolicy(
            mid_fmt=args.tier_mid_fmt, cold_fmt=args.tier_cold_fmt,
            hot_steps=args.tier_hot_steps, cold_steps=args.tier_cold_steps,
            repack_pages_per_step=args.tier_repack_pages)
        if args.tiered else None)
    engine = build_engine(cfg, serve_cfg, params, args.engine)
    if args.mesh > 1:
        if getattr(engine, "mesh", None) is not None:
            log.info("sharded serving: %d KV-head shards over a (1, %d) "
                     "device mesh", engine.tp, args.mesh)
        else:
            log.info("sharded serving fell back to single-device "
                     "(see engine log above for the reason)")
    if args.serve:
        return _run_server(engine, args)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    if args.engine == "continuous":
        lens = (rng.integers(max(1, args.prompt_len // 2),
                             args.prompt_len + 1, size=args.batch)
                if args.ragged else [args.prompt_len] * args.batch)
        head = rng.integers(0, cfg.vocab_size,
                            size=(args.shared_prefix,)).astype(np.int32)
        ids = [engine.submit(
            np.concatenate([head, rng.integers(
                0, cfg.vocab_size, size=(int(s),)).astype(np.int32)]),
            args.new_tokens) for s in lens]
        results = engine.run()
        dt = time.perf_counter() - t0
        prompt_toks = int(np.sum(lens)) + args.shared_prefix * len(ids)
        toks = sum(len(results[i]) for i in ids) - prompt_toks
        stats = engine.cache_stats()
        log.info("served %d requests in %.2fs (%.1f tok/s); peak pages %d "
                 "(%.1f KiB paged cache), %d preemptions, prefix hit rate "
                 "%.2f (%d/%d prompt tokens prefilled)",
                 len(ids), dt, toks / dt, stats["peak_pages"],
                 stats["peak_paged_bytes"] / 1024, stats["preemptions"],
                 stats["prefix_hit_rate"], stats["prefill_tokens_computed"],
                 stats["prompt_tokens"])
        if "dispatches_total" in stats:
            mode = ("megakernel" if getattr(engine, "megakernel", False)
                    else "ragged" if engine.ragged else "split")
            log.info("device dispatches: %d total over %d steps "
                     "(%.2f/step; %.2f per mixed decode+prefill step over "
                     "%d mixed steps) — ragged %d, decode %d, verify %d, "
                     "prefill %d, write %d, repack %d [step mode: %s]",
                     stats["dispatches_total"], engine.steps,
                     stats["dispatches_per_step"],
                     stats["dispatches_per_mixed_step"],
                     stats["mixed_steps"], stats["dispatches_ragged"],
                     stats["dispatches_decode"], stats["dispatches_verify"],
                     stats["dispatches_prefill"], stats["dispatches_write"],
                     stats["dispatches_repack"], mode)
            # the serving claim, measured end to end: every mixed
            # decode+prefill step is ONE jitted call, and (megakernel)
            # that call traces to ONE device kernel for the whole stack
            if stats["mixed_steps"] and mode in ("ragged", "megakernel"):
                gate = stats["dispatches_per_mixed_step"] == 1.0
                log.info("dispatch gate: dispatches_per_mixed_step == 1 "
                         "%s", "HELD" if gate else "FAILED")
            if stats.get("pallas_calls_per_step") is not None:
                log.info("step audit: %d pallas_call(s) per engine step "
                         "(%.1f prefill tokens retired per prefill-"
                         "carrying dispatch)",
                         stats["pallas_calls_per_step"],
                         stats["prefill_rows_per_step"])
        if "admission_latency_p95" in stats:
            log.info("admission latency (submit -> first token): "
                     "p50 %.3fs p95 %.3fs mean %.3fs over %d requests "
                     "(%s prefill, %d chunks, %d live prefill traces)",
                     stats["admission_latency_p50"],
                     stats["admission_latency_p95"],
                     stats["admission_latency_mean"],
                     len(engine.admission_latencies) or len(ids),
                     "chunked" if engine.chunked else "monolithic",
                     stats["prefill_chunks"], stats["prefill_traces"])
        if args.spec_decode:
            log.info("speculative decode: %.2f accepted tokens/step over "
                     "%d verify steps (draft acceptance %.2f)",
                     stats["accepted_per_step"], stats["spec_steps"],
                     stats["draft_acceptance_rate"])
        if args.tiered:
            fmt_counts = ", ".join(
                f"{k[len('pages_'):]}: {v}" for k, v in stats.items()
                if k.startswith("pages_"))
            log.info("tiered KV: %d/%d quarter-page units in use (peak "
                     "%d); live pages by format: %s; %d pages repacked "
                     "over %d dispatches (max %d in one step)",
                     stats["units_in_use"], stats["unit_budget"],
                     stats["peak_units"], fmt_counts,
                     stats["repacked_pages"], stats["repack_dispatches"],
                     stats["max_repacked_in_step"])
        return results
    # same workload shape as the continuous branch (minus raggedness): a
    # shared head plus per-request tails, so --engine A/Bs compare like
    # for like even though the fixed engine cannot exploit the sharing
    head = rng.integers(0, cfg.vocab_size,
                        size=(args.shared_prefix,)).astype(np.int32)
    prompts = np.concatenate(
        [np.broadcast_to(head, (args.batch, args.shared_prefix)),
         rng.integers(0, cfg.vocab_size,
                      size=(args.batch, args.prompt_len)).astype(np.int32)],
        axis=1).astype(np.int32)
    out = engine.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    log.info("generated %s in %.2fs (%.1f tok/s, first row: %s...)",
             out.shape, dt, toks / dt, out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
