"""Serving launcher: MX-compressed weights, batched generation.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 --quant mxfp8
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.nn import model
from repro.serve import ServeConfig, ServeEngine

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", default="",
                    choices=["", "wide", "mxfp8", "mxfp4"])
    ap.add_argument("--quantize-kv", action="store_true")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.quant:
        from repro.core import MXFP4, MXFP8, WIDE

        q = {"wide": WIDE, "mxfp8": MXFP8, "mxfp4": MXFP4}[args.quant]
        cfg = cfg.replace(quant=q.replace(
            block_size=cfg.quant.block_size,
            quantize_acts=False,  # weight-only for serving
            quantize_kv_cache=args.quantize_kv))
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.new_tokens
    engine = ServeEngine(params, cfg, ServeConfig(
        max_seq=max_seq, temperature=args.temperature))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    log.info("generated %s in %.2fs (%.1f tok/s, first row: %s...)",
             out.shape, dt, toks / dt, out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
