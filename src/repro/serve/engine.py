"""Serving engines: MX-compressed weights + (paged) MX KV cache.

Two engines share one numerics contract:

  * ``FixedSlotEngine`` — the original continuous-batching-lite loop: a
    fixed batch of slots, one shared position counter, ring-buffer caches
    sized batch x max_seq. Kept as the golden reference: its greedy
    outputs define correctness for the paged path.
  * ``ContinuousBatchingEngine`` (exported as ``ServeEngine``) — requests
    enter and leave mid-stream. Admission prefills one request into pages
    drawn from a global MX page pool (``kv_cache``), the jitted decode
    step runs at fixed shapes (max_slots rows, padding rows masked by
    dropped writes), and EOS/max_new recycles the slot and pages the same
    step (``scheduler``). Per-request greedy outputs are token-identical
    to the fixed-slot engine because every op on the path — projection,
    RoPE, cache quantize/dequantize, masked softmax — is batch-row
    independent and shared between the two paths.

Why this is the paper's serving payoff at production shape: the decode
step's HBM traffic is dominated by the KV cache; MX storage cuts it ~2x
(fp8+E8M0 vs bf16) and paging cuts the *allocated* footprint to what is
actually resident, so ragged, churning traffic stops paying for max_seq
rectangles. ``benchmarks/serve_throughput.py`` measures both.

The decode step runs the single-pass fused Pallas flash-decode kernel by
default (``ServeConfig.decode_kernel="fused"``): attention walks the page
table in-kernel, dequantizes compact MX tiles in-register, and skips
unallocated pages, so per-step attention *work* also scales with resident
tokens — not just the footprint.

Speculative decoding (``ServeConfig.spec_decode``) feeds that kernel
properly: instead of one token per step, each sequence drafts K cheap
candidates (prompt-lookup n-gram by default — no second model) and one
batched multi-token verify pass (``model.verify_step_paged`` over the
Tq > 1 fused kernel) checks them all, amortizing the page walk and
in-register dequant across the chunk. Greedy acceptance + page-exact
rollback keep the output token stream identical to non-speculative
decode for any drafter (see ``spec_decode``).

Prefill is chunked by default (``ServeConfig.prefill_mode="chunked"``):
instead of one monolithic dense prefill per prompt — which materializes
wide bf16 K/V for the whole prompt, installs it into pages afterwards,
retraces per prompt length, and blocks every resident decoder for the
full prompt duration — each prompt streams through fixed-size
page-aligned chunks that run straight against the MX page pool
(``model.prefill_chunk_paged`` over ``mx_attention_prefill_fused``: the
chunk's K/V is quantized and written into its pages *inside* the kernel,
and the chunk attends over everything resident plus itself). Chunks are
interleaved with decode steps under a per-step token budget
(Sarathi-style), so admission latency is O(chunk), head-of-line blocking
disappears, and the engine needs exactly ONE jitted prefill trace.
``prefill_mode="monolithic"`` keeps the dense path as the validated
reference oracle (its per-length trace caches now LRU-bounded); both
modes produce token-identical greedy streams because prefill, decode and
verify share one projection/RoPE/quantize path.

``decode_kernel="einsum"`` is the escape
hatch back to the gather-and-dequantize reference path (what wide bf16
pools fall back to, and what ``benchmarks/decode_attention.py`` compares
against). Numerics caveat: the fused kernel keeps the softmax in f32
while the einsum path rounds probabilities to bf16 before the value
matmul, so across-path logits differ at bf16-rounding level and a greedy
step whose top-2 gap sits inside that band can flip (README §Serving);
within a path, determinism and the paging machinery's exactness
(snapshot/restore, COW, prefix sharing) are unchanged.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import time
from collections import OrderedDict, deque
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FORMAT_BY_ID, FORMAT_IDS
from repro.core.mx_tensor import MXTensor
from repro.kernels import mx_repack_pages
from repro.nn import blocks, model
from repro.nn.config import ModelConfig

from . import kv_cache, sampling, spec_decode
from .kv_cache import PAGE_UNITS_FULL, UNITS_BY_BITS
from .overload import OverloadConfig, OverloadController
from .sampling import SamplingParams
from .scheduler import Scheduler

log = logging.getLogger("repro.serve")

_PAGED_MIXERS = {"attn", "rglru", "ssd"}

#: element bit width per MX format name (drives quarter-page unit costs)
_FMT_BITS = {"fp8_e4m3": 8, "fp8_e5m2": 8, "fp6_e3m2": 6, "fp6_e2m3": 6,
             "fp4_e2m1": 4}


@dataclasses.dataclass
class TierPolicy:
    """Hot/cold tiering knobs for the mixed-format KV page pool.

    A page is *hot* while it was written within the last ``hot_steps``
    engine steps; past that it is repacked down the format ladder
    (base fp8 -> ``mid_fmt`` -> ``cold_fmt``) by a background budget of
    ``repack_pages_per_step`` pages per step. Repacking requantizes the
    page's elements+scales in place via the exact ``core.quantize`` math
    (``kernels/mx_repack.py``) and credits quarter-page units back to
    the pool's HBM budget, so colder residency buys capacity: more
    resident tokens per byte at a bounded accuracy cost.
    """

    mid_fmt: str = "fp6_e3m2"  # first demotion step (3/4 of a page)
    cold_fmt: str = "fp4_e2m1"  # final demotion step (1/2 of a page)
    hot_steps: int = 8  # steps since last write before base -> mid
    cold_steps: int = 32  # steps since last write before mid -> cold
    repack_pages_per_step: int = 4  # background repack budget per step
    # fixed kernel page-list length: repack dispatches pad to this, so
    # the jitted trace population stays O(1) regardless of batch shape
    repack_list_len: int = 8


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    # default sampling for requests that don't carry their own
    # SamplingParams: temperature 0 => exact greedy; top_k 0 => disabled;
    # ``seed`` is the engine's base seed, mixed with each request id into
    # that request's own RNG stream (see serve.sampling.resolve_seed)
    temperature: float = 0.0  # 0 => greedy
    top_p: float = 1.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None
    # overload control (serve.overload): shed submissions (ShedError /
    # HTTP 429) once the predicted first-token latency exceeds slo_ms,
    # and unconditionally once the queue reaches max_queue. None = admit
    # everything (the pre-overload-control behavior).
    slo_ms: Optional[float] = None
    max_queue: Optional[int] = None
    # continuous batching (ignored by FixedSlotEngine)
    max_slots: int = 8
    page_size: int = 16
    num_pages: Optional[int] = None  # default: max_slots * pages_per_slot
    # prefix caching: share page-aligned prompt heads across requests via
    # the radix tree (attention-only models; auto-disabled otherwise)
    prefix_cache: bool = True
    # admission: how far past a stuck queue head to scan for a request
    # that fits (1 = strict FCFS)
    admit_window: int = 4
    # paged decode attention: "fused" (default) runs the single-pass Pallas
    # flash-decode kernel over the page table — per-step work scales with
    # resident tokens; "einsum" is the escape hatch back to the reference
    # gather-and-dequantize path (also what wide bf16 pools fall back to)
    decode_kernel: str = "fused"
    # speculative decoding: draft num_draft_tokens per sequence per step
    # and verify them all in one batched multi-token pass over the paged
    # MX cache. At temperature 0 acceptance is exact greedy prefix
    # matching (token-identical to non-speculative decode for ANY
    # drafter); at temperature > 0 it is rejection sampling against the
    # filtered target distribution (serve.sampling.verify_rejection), so
    # emitted tokens keep exactly the distribution plain sampling would
    # produce — a good drafter only raises tokens/step, never changes
    # what is sampled.
    # ``drafter`` is "ngram" (prompt-lookup, no second model needed) or a
    # spec_decode.Drafter instance.
    spec_decode: bool = False
    num_draft_tokens: int = 4
    drafter: object = "ngram"
    # prefill path: "chunked" (default) streams each prompt through
    # fixed-size page-aligned chunks straight against the MX page pool
    # (fused quantize-into-pages kernel, O(1) jitted traces, admission
    # interleaved with decode under a per-step token budget);
    # "monolithic" is the validated reference oracle — one dense prefill
    # per prompt + page install, retracing per prompt length. Models with
    # recurrent mixers fall back to monolithic automatically (their state
    # is per-slot, not paged — chunks have nothing to resume from).
    prefill_mode: str = "chunked"
    # chunk length in tokens; must be a multiple of page_size so chunk
    # starts stay page-aligned (no page ever blends two chunks)
    prefill_chunk: int = 64
    # max prefill tokens processed per engine step (Sarathi-style budget;
    # default = one chunk). The budget is spent round-robin across
    # admitted-but-prefilling sequences, so a short prompt's first token
    # never waits for a long neighbour's full prompt.
    prefill_token_budget: Optional[int] = None
    # ragged-aware prefill budgeting: how many chunks one prefilling
    # sequence may advance in a single ragged step WHEN the row budget is
    # undersubscribed (fewer active sequences than slots). The ragged
    # trace width grows to prefill_chunk * prefill_max_chunks, and the
    # starvation bound is built in: the moment every slot is occupied,
    # rows fall back to one chunk per step so resident decoders' per-step
    # latency is not taxed by wide prefill rows. 1 (default) = the
    # original one-chunk-per-step behavior.
    prefill_max_chunks: int = 1
    # LRU bound on the monolithic path's per-(length, prefix) jitted
    # prefill traces — a long-running server on the fallback path must
    # not grow trace memory without limit (the chunked path's trace
    # population is bounded by max_slots: one compiled shape per
    # distinct prefill batch size)
    prefill_trace_cache: int = 32
    # tiered mixed-format KV cache: new writes land in the base (fp8)
    # format; pages not written for a while are background-repacked down
    # the ladder (fp8 -> fp6 -> fp4) under ``tier_policy``, and the page
    # pool is metered in quarter-page units so narrower pages genuinely
    # buy capacity (num_pages is then the *fp8-equivalent* byte budget;
    # the physical pool over-provisions 2x). Requires the fused decode
    # kernel, chunked prefill, attention-only mixers, and an 8-bit
    # quantized base KV format.
    tiered: bool = False
    tier_policy: Optional[TierPolicy] = None
    # chunked admission: bound on how many times a request may be
    # deferred waiting for a still-prefilling shared-prefix leader
    # before it gives up on sharing and prefills independently (a
    # preempted or budget-starved leader must not starve followers)
    max_deferrals: int = 8
    # engine step assembly: "ragged" (default) packs every decode-ready
    # sequence's pending token (+ drafts under speculative decoding) and
    # one prompt chunk per prefilling sequence into ONE fused Pallas
    # dispatch per step — attention, the in-kernel quantize-write of each
    # row's new K/V, sampling and draft verification all ride the single
    # call, so a steady mixed batch costs exactly one device dispatch.
    # "split" keeps the separate decode / verify / prefill-chunk / K/V
    # write dispatches as the validated oracle. Ragged requires the fused
    # decode kernel, a quantized (MX) KV cache and attention-only mixers;
    # unsupported configs fall back to split automatically.
    # "megakernel" goes one rung further: the ENTIRE layer stack of the
    # ragged step runs as ONE pallas_call per engine step
    # (kernels.mx_megakernel_step) — per-layer weights stacked along a
    # leading layer axis, the residual stream carried across layer grid
    # steps in VMEM — collapsing device dispatches per mixed step from
    # O(num_layers) to exactly 1. Ragged assembly, the scheduler,
    # speculative rollback, tiering and prefix sharing are unchanged;
    # configs the megakernel cannot serve (nn.blocks.
    # megakernel_reject_reason, plus the runtime conditions: ragged
    # prerequisites, unsharded mesh, wide weight masters) fall back to
    # the per-layer ragged path with a logged reason.
    step_mode: str = "ragged"
    # sharded serving: (data, model) device-mesh shape, e.g. (1, 8). The
    # ragged step then runs KV-head-parallel under shard_map: the page
    # pool's K/V (+ per-page scale) leaves and the wq/wk/wv projections
    # are partitioned along the KV-head axis over the "model" axis, page
    # tables / row metadata / sampling vectors are replicated, and the
    # ONE collective per step is an all-gather of the attention output
    # before the (replicated) output projection — so per-device HBM
    # holds only KVH/M of the pool while token streams stay identical to
    # the single-device engine. Requires the ragged step (falls back to
    # unsharded otherwise) and num_kv_heads divisible by the model dim.
    # None (default) = single-device, no mesh.
    mesh_shape: Optional[tuple] = None


def _sample(logits, key, temperature: float):
    logits = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


def _sub_jaxprs(params):
    """Inner jaxprs held by one equation's params (jit/scan/cond/...)."""
    import jax.extend.core as jex

    for v in params.values():
        if isinstance(v, jex.ClosedJaxpr):
            yield v.jaxpr
        elif hasattr(v, "eqns"):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jex.ClosedJaxpr):
                    yield x.jaxpr
                elif hasattr(x, "eqns"):
                    yield x


def _pallas_calls_in(jaxpr) -> int:
    """Device-kernel launches one execution of ``jaxpr`` performs.

    Counts ``pallas_call`` equations, multiplying through ``scan`` trip
    counts — the per-layer ragged step scans its pattern over
    ``num_groups``, so its ONE lexical pallas_call runs L times, while
    the layer-fused megakernel's single call runs once. This is the
    measured (not asserted) form of the step's dispatch claim.
    """
    n = 0
    for eqn in jaxpr.eqns:
        inner = sum(_pallas_calls_in(s) for s in _sub_jaxprs(eqn.params))
        if eqn.primitive.name == "pallas_call":
            n += 1
        elif eqn.primitive.name == "scan":
            n += inner * int(eqn.params.get("length", 1))
        else:
            n += inner
    return n


class FixedSlotEngine:
    """Fixed batch of slots, one shared position (the golden reference)."""

    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, cfg, tokens=toks,
                                          max_seq=serve_cfg.max_seq))
        self._decode = jax.jit(
            lambda p, cache, tok, pos: model.decode_step(
                p, cfg, cache, tokens=tok, pos=pos))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        logits, cache = self._prefill(self.params, prompts)
        out = [prompts]
        tok = _sample(logits, key, self.serve_cfg.temperature)
        for i in range(max_new_tokens):
            out.append(tok[:, None])
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(s0 + i, jnp.int32)
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            tok = _sample(logits, sub, self.serve_cfg.temperature)
        return np.asarray(jnp.concatenate(out, axis=1))


class ContinuousBatchingEngine:
    """Continuous batching over a paged MX KV cache."""

    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        unsupported = {bd.mixer for bd in
                       (*cfg.prologue, *cfg.pattern, *cfg.epilogue)
                       } - _PAGED_MIXERS
        if unsupported:
            raise NotImplementedError(
                f"continuous batching does not support mixers {unsupported} "
                "— use FixedSlotEngine (launch/serve.py --engine fixed)")
        if cfg.num_codebooks > 1:
            raise NotImplementedError(
                "continuous batching with codebook heads is a follow-on")
        if serve_cfg.decode_kernel not in ("einsum", "fused"):
            raise ValueError(
                f"unknown decode_kernel {serve_cfg.decode_kernel!r} "
                "(expected 'fused' or 'einsum')")
        mixers = {bd.mixer for bd in (*cfg.prologue, *cfg.pattern,
                                      *cfg.epilogue)}
        self.spec_enabled = bool(serve_cfg.spec_decode)
        if self.spec_enabled:
            if serve_cfg.num_draft_tokens < 1:
                raise ValueError("spec_decode needs num_draft_tokens >= 1")
            if mixers - {"attn"}:
                raise NotImplementedError(
                    f"speculative decoding requires attention-only models, "
                    f"got mixers {sorted(mixers - {'attn'})}: recurrent "
                    "state has no position axis to roll rejected drafts "
                    "back through")
            self.drafter = spec_decode.resolve_drafter(
                serve_cfg.drafter, cfg.vocab_size)
        self.params = params
        self.cfg = cfg
        # full-length (non-ring) prefill caches: slot == absolute position,
        # so a prompt cache reshapes exactly into its pages
        self.cfg_prefill = cfg.replace(serve_full_cache=True)
        # the decode step runs the fused flash-decode kernel by default;
        # ServeConfig.decode_kernel="einsum" is the escape hatch back to
        # the gather-and-dequantize reference path
        self.cfg_decode = cfg.replace(decode_kernel=serve_cfg.decode_kernel)
        self.serve_cfg = serve_cfg
        ps = serve_cfg.page_size
        pages_per_slot = kv_cache.pages_for(serve_cfg.max_seq, ps)
        self.num_pages = (serve_cfg.num_pages
                          or serve_cfg.max_slots * pages_per_slot)
        # prefix sharing needs every mixer to be attention: K/V pages are a
        # pure function of the token prefix, but recurrent state is not
        # paged (per-prefix snapshots are a follow-on — see ROADMAP)
        self.prefix_enabled = bool(serve_cfg.prefix_cache
                                   and mixers <= {"attn"})
        if serve_cfg.prefix_cache and not self.prefix_enabled:
            log.info("prefix cache disabled: mixers %s are not attention-only",
                     sorted(mixers - {"attn"}))
        if serve_cfg.prefill_mode not in ("chunked", "monolithic"):
            raise ValueError(
                f"unknown prefill_mode {serve_cfg.prefill_mode!r} "
                "(expected 'chunked' or 'monolithic')")
        # chunked prefill streams prompts through the paged attention
        # pools, so it needs every mixer paged — recurrent state is
        # per-slot and has no chunk to resume from; fall back like the
        # prefix cache does rather than failing the whole engine
        self.chunked = (serve_cfg.prefill_mode == "chunked"
                        and mixers <= {"attn"})
        if serve_cfg.prefill_mode == "chunked" and not self.chunked:
            log.info("chunked prefill disabled: mixers %s are not "
                     "attention-only; using monolithic prefill",
                     sorted(mixers - {"attn"}))
        if self.chunked:
            if serve_cfg.prefill_chunk <= 0:
                raise ValueError("prefill_chunk must be >= 1")
            budget = serve_cfg.prefill_token_budget
            if budget is not None and budget <= 0:
                raise ValueError("prefill_token_budget must be >= 1")
            # budget in whole chunks; anything below one chunk still
            # makes progress (one chunk per step)
            self._chunks_per_step = max(
                1, (budget or serve_cfg.prefill_chunk)
                // serve_cfg.prefill_chunk)
        if serve_cfg.prefill_trace_cache < 1:
            raise ValueError("prefill_trace_cache must be >= 1")
        if serve_cfg.step_mode not in ("ragged", "split", "megakernel"):
            raise ValueError(
                f"unknown step_mode {serve_cfg.step_mode!r} "
                "(expected 'ragged', 'split' or 'megakernel')")
        if serve_cfg.prefill_max_chunks < 1:
            raise ValueError("prefill_max_chunks must be >= 1")
        # the one-dispatch ragged step needs every row to run the fused
        # quantize-into-pages attention path: attention-only mixers, the
        # fused decode kernel, an MX-quantized KV pool, and chunked
        # prefill (monolithic admission would dispatch outside the step)
        ragged_ok = (mixers <= {"attn"}
                     and serve_cfg.decode_kernel == "fused"
                     and cfg.quant.quantize_kv_cache
                     and self.chunked)
        # "megakernel" is ragged assembly with a fused layer stack, so it
        # inherits every ragged prerequisite (and falls all the way back
        # to split dispatches when those are unmet)
        ragged_like = serve_cfg.step_mode in ("ragged", "megakernel")
        self.ragged = ragged_like and ragged_ok
        if ragged_like and not self.ragged:
            log.info("ragged step disabled: needs attention-only mixers, "
                     "decode_kernel='fused', a quantized KV cache and "
                     "chunked prefill; using split dispatches")
        # the ragged kernel routes inactive rows' writes to a reserved
        # trash page (page-table entries of -1 map to the pool's last
        # physical page in-kernel), so the physical pool carries one page
        # the scheduler never hands out
        self._trash_pages = 1 if self.ragged else 0
        # sharded serving: KV-head-parallel ragged step over a
        # (data, model) mesh (see ServeConfig.mesh_shape). Fallback
        # ladder: a 1x1 mesh or a non-ragged config runs unsharded; an
        # indivisible KV-head count or missing devices is a hard error
        # (silent replication there would just waste the machine).
        self.mesh = None
        self._tp_axis: Optional[str] = None
        self.tp = 1
        if serve_cfg.mesh_shape is not None:
            shape = tuple(int(s) for s in serve_cfg.mesh_shape)
            if len(shape) != 2 or any(s < 1 for s in shape):
                raise ValueError(
                    f"mesh_shape must be a (data, model) pair of positive "
                    f"ints, got {serve_cfg.mesh_shape!r}")
            if shape[0] != 1:
                raise ValueError(
                    "sharded serving is KV-head (model) parallel only: "
                    f"mesh_shape[0] (data) must be 1, got {shape[0]} — "
                    "data-parallel replicas are a router-level follow-on")
            ndev = shape[0] * shape[1]
            if ndev == 1:
                log.info("mesh_shape %s is a single device; running "
                         "unsharded", shape)
            elif not self.ragged:
                log.info("sharded serving disabled: it requires the ragged "
                         "step (attention-only mixers, decode_kernel="
                         "'fused', a quantized KV cache, chunked prefill); "
                         "running unsharded")
            else:
                if cfg.num_kv_heads % shape[1] != 0:
                    raise ValueError(
                        f"sharded serving splits KV heads over the model "
                        f"axis: num_kv_heads={cfg.num_kv_heads} is not "
                        f"divisible by mesh model dim {shape[1]}")
                if len(jax.devices()) < ndev:
                    raise ValueError(
                        f"mesh_shape {shape} needs {ndev} devices, found "
                        f"{len(jax.devices())} — set XLA_FLAGS="
                        f"--xla_force_host_platform_device_count={ndev} "
                        "before any jax import")
                from repro.launch.mesh import _make_mesh
                self.mesh = _make_mesh(shape, ("data", "model"),
                                       jax.devices()[:ndev])
                self._tp_axis = "model"
                self.tp = shape[1]
        # layer-fused megakernel: the whole attention-only decoder step —
        # every layer's norm/QKV/RoPE/page-walk/output-proj/FFN plus the
        # in-kernel quantized K/V writes — as ONE pallas_call, with the
        # per-layer ragged step kept as the validated oracle. The ladder
        # is static (config + params), decided once at init; any rung
        # that fails drops to the per-layer ragged step with a log line.
        self.megakernel = False
        self._megakernel_fallback_reason = None
        if serve_cfg.step_mode == "megakernel":
            if not self.ragged:
                reason = ("ragged prerequisites unmet (the megakernel is "
                          "the ragged step fused over layers)")
            elif self.tp > 1:
                reason = ("sharded mesh — megakernel under shard_map is a "
                          "follow-on (see ROADMAP)")
            elif any(isinstance(leaf, MXTensor)
                     for leaf in jax.tree_util.tree_leaves(
                         self.params,
                         is_leaf=lambda x: isinstance(x, MXTensor))):
                reason = ("MXTensor (pre-quantized) weights — the "
                          "megakernel pre-quantizes wide masters itself")
            else:
                reason = blocks.megakernel_reject_reason(self.cfg_decode)
            if reason is None:
                self.megakernel = True
            else:
                self._megakernel_fallback_reason = reason
                log.info("megakernel step disabled: %s; falling back to "
                         "the %s step", reason,
                         "per-layer ragged" if self.ragged
                         else "split-dispatch")
        # tiered mixed-format pool: num_pages is reinterpreted as the
        # fp8-equivalent byte budget (unit-metered); the physical pool
        # over-provisions 2x so repacked (narrower) pages buy residency
        self.tiered = bool(serve_cfg.tiered)
        unit_budget = None
        if self.tiered:
            self.tier = serve_cfg.tier_policy or TierPolicy()
            self._validate_tiering(cfg, mixers)
            unit_budget = self.num_pages * PAGE_UNITS_FULL
            self.num_pages *= 2
        else:
            self.tier = None
        self.scheduler = Scheduler(
            max_slots=serve_cfg.max_slots, num_pages=self.num_pages,
            page_size=ps, max_seq=serve_cfg.max_seq,
            prefix_cache=self.prefix_enabled,
            admit_window=serve_cfg.admit_window,
            num_draft_tokens=(serve_cfg.num_draft_tokens
                              if self.spec_enabled else 0),
            prefill_chunk=(serve_cfg.prefill_chunk if self.chunked else 0),
            prefill_max_chunks=serve_cfg.prefill_max_chunks,
            max_deferrals=serve_cfg.max_deferrals,
            unit_budget=unit_budget, track_allocs=self.tiered)
        self.cache = model.init_paged_cache(
            cfg, serve_cfg.max_slots, self.num_pages + self._trash_pages,
            ps, tiered=self.tiered)
        # donate the cache pytree: without donation every decode step /
        # install / restore copies the whole multi-layer page pool, which
        # would cancel the paged-cache footprint win. CPU has no donation
        # (it only warns), so gate on backend. _extract must NOT donate —
        # the cache lives on after a snapshot.
        cpu = jax.default_backend() == "cpu"
        if self.tiered:
            # every step function threads the shared per-page format-id
            # array (one array for all layers, like the page table); the
            # candidate-format tuple is static, baked into the kernels
            mf = self._mixed_fmts = tuple(dict.fromkeys(
                (cfg.quant.fmt, self.tier.mid_fmt, self.tier.cold_fmt)))
        else:
            mf = None

        # sharded placement: the pool's KV-head axis and the attention
        # projections' head columns land on their mesh shards ONCE, at
        # init — every step then runs shard-local, no per-step reshards.
        # wo and everything outside attention stay replicated (see
        # parallel.sharding.serve_param_specs for why that — not a
        # sharded-wo psum — is what keeps tokens bit-identical).
        if self.mesh is not None:
            from repro.parallel.sharding import serve_param_specs
            self._param_specs = serve_param_specs(self.params)
            self._pool_specs = kv_cache.pool_specs(self.cache,
                                                   self._tp_axis)
            self.params = self._shard_put(self.params, self._param_specs)
            self.cache = self._shard_put(self.cache, self._pool_specs)

        # sampling happens INSIDE the jitted step, fed per-slot parameter
        # vectors (temperature / top-p / top-k / seed / stream counter):
        # a batch mixing greedy and stochastic requests at different
        # temperatures still costs one dispatch, and greedy rows take the
        # exact f32 argmax the pre-sampling engine took. The verify step
        # likewise runs rejection-sampling acceptance in-dispatch and
        # returns (num_emitted, emitted) instead of raw logits.
        def _decode_step(p, c, tok, rows, pos, temps, tps, tks, seeds,
                         ctrs, fmts=None):
            kw = ({"page_fmts": fmts, "mixed_fmts": mf}
                  if fmts is not None else {})
            logits, c = model.decode_step_paged(
                p, self.cfg_decode, c, tok, rows, pos, **kw)
            toks = sampling.sample(logits[:, -1], temps, tps, tks, seeds,
                                   ctrs)
            return toks, c

        def _verify_step(p, c, tok, rows, pos, temps, tps, tks, seeds,
                         ctrs, fmts=None):
            kw = ({"page_fmts": fmts, "mixed_fmts": mf}
                  if fmts is not None else {})
            logits, c = model.verify_step_paged(
                p, self.cfg_decode, c, tok, rows, pos, **kw)
            n_emit, emitted = sampling.verify_rejection(
                logits, tok[:, 1:], temps, tps, tks, seeds, ctrs)
            return n_emit, emitted, c

        self._decode = jax.jit(_decode_step,
                               donate_argnums=() if cpu else (1,))
        self._verify = jax.jit(_verify_step,
                               donate_argnums=() if cpu else (1,))
        # prefill-logits sampler (first token of each admitted request);
        # one compiled shape per batch size, bounded by max_slots
        self._sample_fn = jax.jit(sampling.sample)
        self._install = jax.jit(
            lambda c, pf, slot, ids: kv_cache.install_prefill(
                c, pf, slot, ids, ps),
            donate_argnums=() if cpu else (0, 1))
        self._extract = jax.jit(kv_cache.extract_seq)
        self._restore = jax.jit(kv_cache.restore_seq,
                                donate_argnums=() if cpu else (0, 1))
        self._copy_page = jax.jit(kv_cache.copy_page,
                                  donate_argnums=() if cpu else (0,))
        # monolithic-path trace caches, LRU-bounded (satellite of the
        # chunked-prefill work: a long-running server on the fallback
        # path must not grow trace memory with every novel length)
        self._prefill_fns = OrderedDict()  # prompt length -> jitted
        self._prefill_tail_fns = OrderedDict()  # (tail, prefix, pos0) ->
        # partial-page prefix hits: offset-install traces, LRU-cached per
        # (tail pages, offset, rows)
        self._install_offset_fns = OrderedDict()
        # the chunked path's jitted trace: fixed (B, C) tokens, full
        # page-table rows, dynamic scalars — every prompt length and
        # prefix hit reuses it, and concurrently-prefilling sequences'
        # same-shape chunks batch into ONE dispatch (B rows). Compiled
        # shapes are keyed by B only, so the trace population is bounded
        # by max_slots — constant per deployment, independent of the
        # workload's prompt lengths.
        if self.tiered:
            self._prefill_chunk = jax.jit(
                lambda p, c, toks, rows, pos, nv, idx, fmts:
                model.prefill_chunk_paged(
                    p, self.cfg_decode, c, toks, rows, pos, nv, idx,
                    page_fmts=fmts, mixed_fmts=self._mixed_fmts),
                donate_argnums=() if cpu else (1,))
        else:
            self._prefill_chunk = jax.jit(
                lambda p, c, toks, rows, pos, nv, idx:
                model.prefill_chunk_paged(
                    p, self.cfg_decode, c, toks, rows, pos, nv, idx),
                donate_argnums=() if cpu else (1,))
        # the ragged step's single jitted trace: fixed (max_slots, W)
        # tokens — W wide enough for one prefill chunk and one verify
        # window — with per-row (row_start, seq_lens, logit_idx) scalars,
        # so EVERY batch composition (decode-only, decode+verify,
        # decode+prefill, all three) reuses the one compiled executable.
        # Sampling always runs on each row's first gathered logits row
        # (decode's next token / a prompt-final chunk's first token);
        # draft verification additionally runs when speculative decoding
        # is on. The host picks per row by mode; unused lanes are
        # discarded exactly like inactive slots' logits always were.
        if self.ragged:
            self._ragged_k = (serve_cfg.num_draft_tokens
                              if self.spec_enabled else 0)
            self._ragged_width = max(
                1 + self._ragged_k,
                (serve_cfg.prefill_chunk * serve_cfg.prefill_max_chunks)
                if self.chunked else 1)
            nl = 1 + self._ragged_k
            rk = self._ragged_k
            # the megakernel step is call-compatible with the per-layer
            # ragged step; it takes the layer-stacked params instead
            step_model = (model.megakernel_step_paged if self.megakernel
                          else model.ragged_step_paged)
            self._step_params = (
                model.pack_megakernel_params(self.params, self.cfg_decode)
                if self.megakernel else self.params)

            def _ragged_step_fn(p, c, tok, rows, start, lens, lidx, temps,
                                tps, tks, seeds, ctrs, fmts=None):
                kw = ({"page_fmts": fmts, "mixed_fmts": mf}
                      if fmts is not None else {})
                logits, c = step_model(
                    p, self.cfg_decode, c, tok, rows, start, lens, lidx,
                    num_logits=nl, **kw)
                toks = sampling.sample(logits[:, 0], temps, tps, tks,
                                       seeds, ctrs)
                if rk:
                    n_emit, emitted = sampling.verify_rejection(
                        logits, tok[:, 1:1 + rk], temps, tps, tks, seeds,
                        ctrs)
                    return toks, n_emit, emitted, c
                return toks, c

            if self.mesh is not None:
                from jax.sharding import PartitionSpec as P

                from repro.parallel.ctx import shard_map_compat, use_serve_tp
                axis = self._tp_axis

                def _sharded_step(p, c, *rest):
                    # trace-time signal: attention.apply_ragged reads it
                    # to size reshapes by the local head slice and to
                    # insert the step's one all-gather
                    with use_serve_tp(axis):
                        return _ragged_step_fn(p, c, *rest)

                # page tables, row metadata and sampling vectors are
                # replicated (every device runs the same host schedule
                # in lockstep); only params' head columns and the pool's
                # KV-head axis are sharded. Outputs: sampled tokens /
                # verify results are factually replicated — each device
                # computed them from the identical post-gather tensor.
                n_meta = 10 + (1 if self.tiered else 0)
                out_specs = ((P(), P(), P(), self._pool_specs) if rk
                             else (P(), self._pool_specs))
                fn = shard_map_compat(
                    _sharded_step, mesh=self.mesh,
                    in_specs=(self._param_specs, self._pool_specs)
                    + (P(),) * n_meta,
                    out_specs=out_specs, check_vma=False)
                self._ragged_fn = jax.jit(
                    fn, donate_argnums=() if cpu else (1,))
            else:
                self._ragged_fn = jax.jit(
                    _ragged_step_fn, donate_argnums=() if cpu else (1,))
            # unjitted handle for the dispatch audit (jaxpr pallas_call
            # count, measured lazily at the first ragged step)
            self._ragged_fn_raw = _ragged_step_fn
        self.pallas_calls_per_step = None
        self._key = jax.random.PRNGKey(0)
        # requests that don't carry SamplingParams sample with these
        self._default_sampling = SamplingParams(
            temperature=serve_cfg.temperature, top_p=serve_cfg.top_p,
            top_k=serve_cfg.top_k).validate()
        # admission gate: sheds submissions (ShedError) once the predicted
        # first-token latency misses slo_ms or the queue hits max_queue;
        # with neither knob set it only keeps stats
        self.overload = OverloadController(OverloadConfig(
            slo_ms=serve_cfg.slo_ms, max_queue=serve_cfg.max_queue))
        self.steps = 0
        # device-dispatch accounting: every jitted call an engine step
        # issues lands in one bucket, so the ragged step's whole claim —
        # dispatches_per_mixed_step == 1 — is measured, never asserted
        self.dispatch_counts = {"decode": 0, "verify": 0, "prefill": 0,
                                "ragged": 0, "write": 0, "repack": 0}
        self.dispatches_last_step = 0
        self._step_dispatches = 0
        self.mixed_steps = 0  # steps doing decode AND prefill work
        self.mixed_step_dispatches = 0
        self._step_had_prefill = False
        self._step_had_decode = False
        self.prompt_tokens = 0  # total prompt tokens admitted
        self.prefill_tokens = 0  # prompt tokens actually computed
        self.prefill_chunks = 0  # per-sequence chunks processed
        self.prefill_dispatches = 0  # chunked-prefill kernel invocations
        self._rr_clock = 0  # cross-step round-robin cursor over prefills
        # admission latency: wall seconds from submit() to the request's
        # first sampled token (the serving-side tail-latency metric
        # chunked prefill exists to improve). Bounded sliding window so a
        # long-running server's stats stay O(1) memory — the same
        # unbounded-growth class the LRU trace cap closes.
        self._submit_time: Dict[int, float] = {}
        self.admission_latencies: deque = deque(maxlen=4096)
        # speculative decoding stats
        self.spec_steps = 0  # verify steps run
        self.spec_seq_steps = 0  # (sequence, verify step) participations
        self.drafted_tokens = 0  # k per active sequence per verify step
        self.accepted_tokens = 0  # drafts that matched the greedy target
        self.emitted_tokens = 0  # tokens recorded by verify steps
        # tiered mixed-format pool state (host-authoritative, mirrored to
        # device on change): one format id + last-write tick per physical
        # page, shared by every layer like the page table
        self._tick = 0  # advances every step(); drives page ages
        if self.tiered:
            self._base_fmt_id = FORMAT_IDS[cfg.quant.fmt]
            self.page_fmts = np.full(
                (self.num_pages + self._trash_pages,), self._base_fmt_id,
                np.int32)
            self._page_fmts_dev = jnp.asarray(self.page_fmts)
            self._fmts_dirty = False
            self._last_write = np.zeros(
                (self.num_pages + self._trash_pages,), np.int64)
            # swap snapshots preserve raw page bytes, so the pages'
            # format ids must survive the free/realloc cycle with them
            self._swap_fmts: Dict[int, list] = {}
            self._repack_fns: Dict[str, object] = {}  # dst fmt -> jitted
            self.repacked_pages = 0
            self.repack_dispatches = 0
            self.max_repacked_in_step = 0
            self._repacked_this_step = 0

    def _validate_tiering(self, cfg: ModelConfig, mixers) -> None:
        tp = self.tier
        scfg = self.serve_cfg
        if scfg.decode_kernel != "fused":
            raise ValueError(
                "tiered KV cache requires decode_kernel='fused': the "
                "einsum gather path dequantizes without per-page formats")
        if not self.chunked:
            raise ValueError(
                "tiered KV cache requires chunked prefill on an "
                "attention-only model: the monolithic gather path reads "
                "pages without per-page formats")
        if not cfg.quant.quantize_kv_cache:
            raise ValueError("tiered KV cache requires quantize_kv_cache")
        if _FMT_BITS.get(cfg.quant.fmt) != 8:
            raise ValueError(
                f"tiered KV cache needs an 8-bit base KV format (new "
                f"writes land full-width), got {cfg.quant.fmt!r}")
        for name, fmt in (("mid_fmt", tp.mid_fmt), ("cold_fmt", tp.cold_fmt)):
            if fmt not in FORMAT_IDS:
                raise ValueError(f"unknown tier {name} {fmt!r}")
        if not (_FMT_BITS[cfg.quant.fmt] > _FMT_BITS[tp.mid_fmt]
                >= _FMT_BITS[tp.cold_fmt]):
            raise ValueError(
                f"tier ladder must narrow monotonically, got "
                f"{cfg.quant.fmt} -> {tp.mid_fmt} -> {tp.cold_fmt}")
        if tp.hot_steps < 1 or tp.cold_steps < tp.hot_steps:
            raise ValueError(
                "tier_policy needs hot_steps >= 1 and "
                "cold_steps >= hot_steps")
        if tp.repack_pages_per_step < 0 or tp.repack_list_len < 1:
            raise ValueError(
                "tier_policy needs repack_pages_per_step >= 0 and "
                "repack_list_len >= 1")

    # -- internals ----------------------------------------------------------

    def _shard_put(self, tree, specs):
        """Place ``tree`` per a matching PartitionSpec tree on the mesh.

        Flattened with ``flatten_up_to`` so the spec tree's P entries are
        treated as leaves even on JAX versions where PartitionSpec is
        itself a pytree container (it subclasses tuple on some)."""
        from jax.sharding import NamedSharding

        flat, treedef = jax.tree_util.tree_flatten(tree)
        flat_s = treedef.flatten_up_to(specs)
        placed = [jax.device_put(x, NamedSharding(self.mesh, s))
                  for x, s in zip(flat, flat_s)]
        return jax.tree_util.tree_unflatten(treedef, placed)

    def _lru_trace(self, store: OrderedDict, key, build):
        """Fetch-or-build a jitted trace with LRU eviction at the cap.

        The monolithic path traces per prompt length (and per
        (tail, prefix) pair), so an unbounded dict grows with every novel
        length a long-running server sees; evicting the LRU entry drops
        the jit wrapper and its compiled executables with it.
        """
        fn = store.get(key)
        if fn is None:
            fn = build()
            store[key] = fn
        else:
            store.move_to_end(key)
        while len(store) > self.serve_cfg.prefill_trace_cache:
            store.popitem(last=False)
        return fn

    def _prefill_for(self, length: int):
        """Jitted single-request prefill, LRU-cached per prompt length.

        max_seq rounds up to the page boundary so the cache T dim factors
        into whole pages. No padding of the tokens themselves: prefill
        numerics stay exactly those of the fixed-slot batch prefill.
        """
        ps = self.serve_cfg.page_size
        max_seq = kv_cache.pages_for(length, ps) * ps
        return self._lru_trace(
            self._prefill_fns, length,
            lambda: jax.jit(lambda p, toks: model.prefill(
                p, self.cfg_prefill, tokens=toks, max_seq=max_seq)))

    def _prefill_tail_for(self, tail_len: int, n_gather: int, pos0: int):
        """Jitted tail prefill, LRU-cached per (tail length, gathered
        prefix pages, prefix tokens).

        Reads the shared prefix pages out of the live paged cache and
        prefills only the uncached tail at absolute positions — the
        prefix-cache fast path of the monolithic mode. ``pos0`` (the hit
        length) need not be a page multiple: a partial-page hit gathers
        ``n_gather = ceil(pos0 / page_size)`` pages and the model masks
        the last page's rows past ``pos0``.
        """
        max_seq = kv_cache.pages_for(tail_len, self.serve_cfg.page_size) \
            * self.serve_cfg.page_size
        return self._lru_trace(
            self._prefill_tail_fns, (tail_len, n_gather, pos0),
            lambda: jax.jit(lambda p, c, toks, rows: model.prefill_with_prefix(
                p, self.cfg_prefill, c, toks, rows, pos0,
                max_seq=max_seq)))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _count_dispatch(self, kind: str, n: int = 1) -> None:
        """Record ``n`` device dispatches of ``kind`` against the current
        engine step (see ``dispatch_counts`` / ``cache_stats``)."""
        self.dispatch_counts[kind] += n
        self._step_dispatches += n

    def _audit_dispatches(self, call_args) -> None:
        """Measure ``pallas_calls_per_step`` from the traced step's jaxpr.

        Runs ONCE, lazily, on the first ragged step's real argument
        shapes (abstract trace only — nothing executes), so the number
        in ``cache_stats()`` / the serve log is derived from the same
        program the engine dispatches, not asserted from code structure.
        """
        jaxpr = jax.make_jaxpr(self._ragged_fn_raw)(*call_args)
        self.pallas_calls_per_step = _pallas_calls_in(jaxpr.jaxpr)
        log.info(
            "step audit: %d pallas_call(s) per engine step (%s)",
            self.pallas_calls_per_step,
            "layer-fused megakernel" if self.megakernel
            else "per-layer ragged step")

    def _record_first_token(self, req_id: int) -> None:
        """Admission-latency sample: submit() -> first sampled token."""
        t0 = self._submit_time.pop(req_id, None)
        if t0 is not None:
            lat = time.perf_counter() - t0
            self.admission_latencies.append(lat)
            self.overload.observe_first_token(lat)

    # -- sampling parameter plumbing ----------------------------------------

    def _req_sampling(self, req) -> SamplingParams:
        return req.sampling if req.sampling is not None \
            else self._default_sampling

    def _slot_sampling(self, seqs):
        """Per-slot sampling parameter vectors for one jitted step.

        Inactive slots stay at the neutral greedy defaults (their sampled
        token is computed and discarded, like their logits always were).
        Each active row's counter is its request's next stream index —
        ``len(generated)`` — which is what makes the stream a pure
        function of (seed, index): slot id, batch composition, and
        preemption history never enter the key.
        """
        arrs = sampling.slot_arrays(self.serve_cfg.max_slots)
        for seq in seqs:
            sp = self._req_sampling(seq.req)
            slot = seq.slot
            arrs["temps"][slot] = sp.temperature
            arrs["top_ps"][slot] = sp.top_p
            arrs["top_ks"][slot] = sp.top_k
            arrs["seeds"][slot] = seq.req.seed
            arrs["counters"][slot] = len(seq.req.generated)
        return (jnp.asarray(arrs["temps"]), jnp.asarray(arrs["top_ps"]),
                jnp.asarray(arrs["top_ks"]), jnp.asarray(arrs["seeds"]),
                jnp.asarray(arrs["counters"]))

    def _sample_prefill_rows(self, seqs, logits):
        """Sample each row's first token from prefill logits (N, V) —
        counter 0 of each request's stream; one dispatch per batch."""
        n = len(seqs)
        temps = np.zeros((n,), np.float32)
        tps = np.ones((n,), np.float32)
        tks = np.zeros((n,), np.int32)
        seeds = np.zeros((n,), np.uint32)
        for i, seq in enumerate(seqs):
            sp = self._req_sampling(seq.req)
            temps[i], tps[i], tks[i] = sp.temperature, sp.top_p, sp.top_k
            seeds[i] = seq.req.seed
        self._count_dispatch("prefill")
        return np.asarray(self._sample_fn(
            logits, jnp.asarray(temps), jnp.asarray(tps),
            jnp.asarray(tks), jnp.asarray(seeds),
            jnp.zeros((n,), jnp.int32)))

    # -- tiered mixed-format pool internals ---------------------------------

    def _sync_fmts(self):
        """Device mirror of the per-page format ids (refresh on change)."""
        if self._fmts_dirty:
            self._page_fmts_dev = jnp.asarray(self.page_fmts)
            self._fmts_dirty = False
        return self._page_fmts_dev

    def _drain_allocs(self) -> None:
        """Reset recycled pages to the base format.

        Every page the pool handed out since the last drain starts life
        hot: its next write is full-width fp8. A page that was repacked
        to fp4, freed, and re-allocated would otherwise keep its stale
        narrow format id — the reader would then misdecode the fresh fp8
        bytes. Idempotent; called before every device dispatch and
        before swap-restore format fix-ups.
        """
        if not self.tiered:
            return
        for pid in self.scheduler.pool.alloc_log:
            if self.page_fmts[pid] != self._base_fmt_id:
                self.page_fmts[pid] = self._base_fmt_id
                self._fmts_dirty = True
            self._last_write[pid] = self._tick
        self.scheduler.pool.alloc_log.clear()

    def _mark_write(self, pids) -> None:
        """Record that this step writes rows into ``pids`` (keeps hot)."""
        if self.tiered:
            for pid in pids:
                self._last_write[pid] = self._tick

    def _set_page_fmt(self, pid: int, fmt: str) -> None:
        """Flip one page's format id + unit cost (after a device repack).

        The flip is the atomic commit point: every holder of the page —
        other sequences' tables, the prefix tree, the next dispatch —
        reads the one shared ``page_fmts`` array, so a shared page is
        repacked once and all readers switch together.
        """
        self.page_fmts[pid] = FORMAT_IDS[fmt]
        self._fmts_dirty = True
        self.scheduler.pool.set_cost(pid, UNITS_BY_BITS[_FMT_BITS[fmt]])

    def _repack_fn_for(self, dst_fmt: str):
        """Jitted whole-cache repack to ``dst_fmt``, one trace per target
        format (the page list is padded to a fixed length)."""
        fn = self._repack_fns.get(dst_fmt)
        if fn is None:
            cpu = jax.default_backend() == "cpu"
            mf = self._mixed_fmts
            bs_cfg = self.cfg.quant.block_size
            keys = ("k_elems", "k_scales", "v_elems", "v_scales")

            def run(cache, ids, fmts, count):
                for path, blk, grouped in kv_cache._iter_blocks(cache):
                    if not kv_cache._is_pool(blk):
                        continue
                    leaves = [blk[key] for key in keys]
                    bs = min(bs_cfg, leaves[0].shape[-1])
                    if grouped:
                        outs = [mx_repack_pages(
                            *(leaf[g] for leaf in leaves), ids, fmts,
                            count, dst_fmt_name=dst_fmt, mixed_fmts=mf,
                            block_size=bs)
                            for g in range(leaves[0].shape[0])]
                        new = {key: jnp.stack([o[j] for o in outs])
                               for j, key in enumerate(keys)}
                    else:
                        new = dict(zip(keys, mx_repack_pages(
                            *leaves, ids, fmts, count,
                            dst_fmt_name=dst_fmt, mixed_fmts=mf,
                            block_size=bs)))
                    cache = kv_cache._set_block(cache, path, new)
                return cache

            run_fn = run
            if self.mesh is not None:
                # the repack kernel's grid is (page-list, KVH): with the
                # pool's KV-head axis sharded it runs shard-local on each
                # device's head slice — the page ids / formats / count
                # are replicated, no collective anywhere
                from jax.sharding import PartitionSpec as P

                from repro.parallel.ctx import shard_map_compat
                run_fn = shard_map_compat(
                    run, mesh=self.mesh,
                    in_specs=(self._pool_specs, P(), P(), P()),
                    out_specs=self._pool_specs, check_vma=False)
            fn = jax.jit(run_fn, donate_argnums=() if cpu else (0,))
            self._repack_fns[dst_fmt] = fn
        return fn

    def _repack_pages_to(self, pids, dst_fmt: str) -> None:
        """Requantize ``pids`` (current formats per ``page_fmts``) to
        ``dst_fmt`` in place, in fixed-length padded dispatches."""
        ll = self.tier.repack_list_len
        for lo in range(0, len(pids), ll):
            group = pids[lo:lo + ll]
            # pad by repeating the last live id: the kernel predicates
            # on count, so padding rows are never written
            ids = group + [group[-1]] * (ll - len(group))
            fmts = [int(self.page_fmts[p]) for p in ids]
            self.cache = self._repack_fn_for(dst_fmt)(
                self.cache, jnp.asarray(ids, jnp.int32),
                jnp.asarray(fmts, jnp.int32),
                jnp.asarray(len(group), jnp.int32))
            self.repack_dispatches += 1
            self._count_dispatch("repack")
            for pid in group:
                self._set_page_fmt(pid, dst_fmt)
            self.repacked_pages += len(group)
            self._repacked_this_step += len(group)

    def _protected_pages(self) -> set:
        """Pages the tiering pass must not touch this step: every page of
        a still-prefilling sequence from its resume point on (chunk
        writes land there in the base format), and every decode-ready
        sequence's live write window (decode/verify writes land there).
        """
        sched = self.scheduler
        ps = self.serve_cfg.page_size
        protected = set()
        for seq in sched.prefilling():
            protected.update(seq.pages[seq.prefill_pos // ps:])
        span = 1 + (self.serve_cfg.num_draft_tokens
                    if self.spec_enabled else 0)
        for seq in sched.decode_ready():
            lo = seq.pos // ps
            hi = min(len(seq.pages), (seq.pos + span - 1) // ps + 1)
            protected.update(seq.pages[lo:hi])
        return protected

    def _run_repack(self) -> None:
        """One background tiering pass: demote aged pages down the ladder
        under the per-step page budget (coldest candidates first)."""
        if not self.tiered or self.tier.repack_pages_per_step <= 0:
            return
        self._drain_allocs()
        tp, pool = self.tier, self.scheduler.pool
        protected = self._protected_pages()
        mid_id = FORMAT_IDS[tp.mid_fmt]
        to_mid, to_cold = [], []
        for pid in range(self.num_pages):
            if pool.ref(pid) == 0 or pid in protected:
                continue
            age = self._tick - int(self._last_write[pid])
            fmt = int(self.page_fmts[pid])
            if fmt == self._base_fmt_id and age >= tp.hot_steps:
                to_mid.append((age, pid))
            elif fmt == mid_id and mid_id != FORMAT_IDS[tp.cold_fmt] \
                    and age >= tp.cold_steps:
                to_cold.append((age, pid))
        budget = tp.repack_pages_per_step
        self._repacked_this_step = 0
        for cands, dst in ((to_cold, tp.cold_fmt), (to_mid, tp.mid_fmt)):
            if budget <= 0 or not cands:
                continue
            cands.sort(key=lambda t: -t[0])  # oldest first
            take = [pid for _, pid in cands[:budget]]
            self._repack_pages_to(take, dst)
            budget -= len(take)
        self.max_repacked_in_step = max(self.max_repacked_in_step,
                                        self._repacked_this_step)

    def _admit(self):
        sched = self.scheduler
        while True:
            seq = sched.admit_next()
            if seq is None:
                return
            if seq.req.swap is not None:
                # swapped-out sequence: restore the exact bytes of the
                # pages it exclusively owned into their fresh replacements
                # (shared prefix pages stayed resident under other refs);
                # its pending token decodes — or its prefill resumes —
                # next step
                snapshot, owned_idx, *_ = seq.req.swap
                seq.req.swap = None
                if owned_idx:
                    self.cache = self._restore(
                        self.cache, snapshot,
                        jnp.asarray(seq.slot, jnp.int32),
                        jnp.asarray([seq.pages[i] for i in owned_idx],
                                    jnp.int32))
                    self._count_dispatch("write")
                if self.tiered:
                    # the snapshot restored the pages' raw bytes, narrow
                    # encodings included — re-apply the format ids they
                    # were extracted with (drain first: alloc just reset
                    # these fresh pages to base)
                    self._drain_allocs()
                    saved = self._swap_fmts.pop(seq.req.id, None)
                    if saved is not None:
                        for i, fid in zip(owned_idx, saved):
                            self._set_page_fmt(seq.pages[i],
                                               FORMAT_BY_ID[fid])
                continue
            prompt = seq.req.prompt
            self.prompt_tokens += len(prompt)
            if seq.prefill_pos is not None:
                # chunked mode: admission only binds the slot and pages;
                # the prompt streams through _run_prefill_chunks under
                # the per-step token budget
                continue
            cached = seq.cached_tokens
            if cached:
                # prefix hit: prefill only the uncached tail against the
                # shared pages already resident in the pool. The hit may
                # end mid-page (partial-page entry): the tail then
                # extends the partial page in place — COW it first (the
                # tree and possibly other holders reference it) and
                # scatter the tail rows at the page-internal offset.
                ps_ = self.serve_cfg.page_size
                n_full, valid = cached // ps_, cached % ps_
                n_gather = n_full + (1 if valid else 0)
                tail = prompt[cached:]
                if valid and sched.pool.ref(seq.pages[n_full]) > 1:
                    old = seq.pages[n_full]
                    new = self._alloc_one(seq)
                    if new is not None:
                        self.cache = self._copy_page(
                            self.cache, jnp.asarray(old, jnp.int32),
                            jnp.asarray(new, jnp.int32))
                        self._count_dispatch("write")
                        sched.pool.free([old])
                        seq.pages[n_full] = new
                        sched.cow_copies += 1
                    elif not self._unpin_partial(old):
                        raise RuntimeError(
                            "page pool exhausted for a lone sequence")
                logits, pfcache = self._prefill_tail_for(
                    len(tail), n_gather, cached)(
                        self.params, self.cache,
                        jnp.asarray(tail, jnp.int32)[None],
                        jnp.asarray(seq.pages[:n_gather], jnp.int32))
                self._count_dispatch("prefill")
                self.prefill_tokens += len(tail)
                if valid:
                    install = self._lru_trace(
                        self._install_offset_fns,
                        (len(seq.pages) - n_full, valid, len(tail)),
                        lambda: jax.jit(
                            lambda c, pf, slot, ids,
                            off=valid, nr=len(tail):
                            kv_cache.install_prefill_offset(
                                c, pf, slot, ids, ps_, off, nr),
                            donate_argnums=()
                            if jax.default_backend() == "cpu" else (0, 1)))
                    self.cache = install(
                        self.cache, pfcache,
                        jnp.asarray(seq.slot, jnp.int32),
                        jnp.asarray(seq.pages[n_full:], jnp.int32))
                else:
                    self.cache = self._install(
                        self.cache, pfcache,
                        jnp.asarray(seq.slot, jnp.int32),
                        jnp.asarray(seq.pages[n_full:], jnp.int32))
                self._count_dispatch("write")
            else:
                logits, pfcache = self._prefill_for(len(prompt))(
                    self.params, jnp.asarray(prompt, jnp.int32)[None])
                self._count_dispatch("prefill")
                self.prefill_tokens += len(prompt)
                self.cache = self._install(
                    self.cache, pfcache, jnp.asarray(seq.slot, jnp.int32),
                    jnp.asarray(seq.pages, jnp.int32))
                self._count_dispatch("write")
            sched.register_prefix(seq)
            tok = int(self._sample_prefill_rows([seq], logits[:, -1])[0])
            self._record_first_token(seq.req.id)
            sched.record_token(seq, tok, eos_id=self.serve_cfg.eos_id)

    def _run_prefill_chunks(self) -> None:
        """Advance chunked prefills by up to the per-step token budget.

        The budget is spent round-robin across prefilling sequences, with
        the rotation carried *across* steps (``_rr_clock``): a short
        prompt admitted behind a long one gets its first token after its
        own few chunks, not after the long prompt completes — the
        processor-sharing schedule that moves the admission-latency tail
        (a per-step restart from the oldest sequence would let a long
        prompt hog every one-chunk budget). Each chunk is one call of
        the single jitted trace; the final chunk of a prompt samples the
        request's first token and flips the sequence to decoding.
        """
        if not self.chunked:
            return
        sched = self.scheduler
        budget = self._chunks_per_step
        while budget > 0:
            pref = sched.prefilling()
            if not pref:
                return
            # one chunk per selected sequence, all in ONE kernel dispatch
            # (B rows) — the fix for the old per-sequence B=1 dispatch
            # loop, which serialized concurrently-prefilling sequences'
            # same-shape chunks into separate kernel launches. Only real
            # chunks enter the batch: the kernel unconditionally writes
            # at least one row per batch row (num_valid is clamped to
            # >= 1 in-kernel), so a padding row would scribble on a page.
            start = self._rr_clock % len(pref)
            take = min(budget, len(pref))
            batch = [pref[(start + i) % len(pref)] for i in range(take)]
            self._rr_clock += take
            self._prefill_chunk_batch(batch)
            budget -= take

    def _prefill_chunk_batch(self, seqs) -> None:
        """Run one fixed-size chunk for each sequence in ``seqs`` through
        a single batched paged-prefill dispatch; sequences on their final
        chunk sample their first token from their own logits row."""
        sched = self.scheduler
        c = self.serve_cfg.prefill_chunk
        bsz = len(seqs)
        tokens = np.zeros((bsz, c), np.int32)
        rows = np.full((bsz, sched.pages_per_slot), -1, np.int32)
        starts = np.zeros((bsz,), np.int32)
        reals = np.zeros((bsz,), np.int32)
        for i, seq in enumerate(seqs):
            prompt = seq.req.prompt
            st = seq.prefill_pos
            real = min(c, len(prompt) - st)
            tokens[i, :real] = prompt[st:st + real]
            rows[i, : len(seq.pages)] = seq.pages
            starts[i], reals[i] = st, real
        args = ()
        if self.tiered:
            self._drain_allocs()
            ps = self.serve_cfg.page_size
            for i, seq in enumerate(seqs):
                self._mark_write(seq.pages[starts[i] // ps:
                                           (starts[i] + reals[i] - 1)
                                           // ps + 1])
            args = (self._sync_fmts(),)
        logits, self.cache = self._prefill_chunk(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(rows), jnp.asarray(starts), jnp.asarray(reals),
            jnp.asarray(reals - 1), *args)
        self._count_dispatch("prefill")
        self._step_had_prefill = True
        self.prefill_tokens += int(reals.sum())
        self.prefill_chunks += bsz
        self.prefill_dispatches += 1
        sampled = None
        for i, seq in enumerate(seqs):
            st, real = int(starts[i]), int(reals[i])
            final = st + real >= len(seq.req.prompt)
            seq.pos = st + real
            seq.prefill_pos = st + c
            if final:
                seq.prefill_pos = None
                sched.register_prefix(seq)
                if sampled is None:
                    sampled = self._sample_prefill_rows(seqs, logits[:, -1])
                tok = int(sampled[i])
                self._record_first_token(seq.req.id)
                sched.record_token(seq, tok, eos_id=self.serve_cfg.eos_id)

    def _swap_out(self, victim) -> None:
        """Preempt ``victim``: snapshot + free only the pages it
        exclusively owns; shared pages keep their other references."""
        sched = self.scheduler
        owned_idx, owned_ids = sched.exclusive_pages(victim)
        snapshot = None
        if owned_ids:
            snapshot = self._extract(
                self.cache, jnp.asarray(victim.slot, jnp.int32),
                jnp.asarray(owned_ids, jnp.int32))
            self._count_dispatch("write")
        if self.tiered:
            # snapshots carry raw page bytes, so the element format of
            # each owned page must travel with them — restore re-applies
            # these after the fresh allocation resets fmts to base
            self._swap_fmts[victim.req.id] = [
                int(self.page_fmts[p]) for p in owned_ids]
        sched.preempt(victim, snapshot, owned_idx)

    def _reclaim_swapped_refs(self) -> bool:
        """Last-resort pool reclamation: queued swapped-out requests still
        retain references on shared pages (normally the cheap choice — the
        pages stay resident under the tree's reference too). When those
        pins would starve a live sequence, extract the shared pages' exact
        bytes into the swap snapshots and drop the references, turning the
        pages evictable/freeable. Restore then treats them like any other
        owned page, so generation stays bit-identical. Returns True if any
        reference was dropped.
        """
        sched = self.scheduler
        released = False
        for req in sched.queue:
            if req.swap is None:
                continue
            snapshot, owned_idx, pages, pos, cached, prefill_pos = req.swap
            owned = set(owned_idx)
            shared_idx = [i for i in range(len(pages)) if i not in owned]
            if not shared_idx:
                continue
            extra = self._extract(
                self.cache, jnp.asarray(0, jnp.int32),
                jnp.asarray([pages[i] for i in shared_idx], jnp.int32))
            self._count_dispatch("write")
            req.swap = (kv_cache.merge_snapshots(snapshot, extra),
                        owned_idx + shared_idx, pages, pos, cached,
                        prefill_pos)
            if self.tiered:
                self._swap_fmts.setdefault(req.id, []).extend(
                    int(self.page_fmts[pages[i]]) for i in shared_idx)
            sched.pool.free([pages[i] for i in shared_idx])
            released = True
        return released

    def _relieve_pressure(self, seq) -> bool:
        """One escalation step when ``seq`` can't get a page (tree LRU
        eviction already ran inside ``_alloc_with_evict``): swap out the
        youngest other sequence, else reclaim swapped requests' pinned
        shared refs. False means the pool is genuinely exhausted. Single
        source of the escalation order for the grow and COW paths."""
        victim = self.scheduler.pick_victim(exclude=seq)
        if victim is not None:
            self._swap_out(victim)
            return True
        return self._reclaim_swapped_refs()

    def _alloc_one(self, seq) -> Optional[int]:
        """One fresh page for ``seq``, evicting / preempting as needed."""
        while True:
            ids = self.scheduler._alloc_with_evict(1)
            if ids is not None:
                return ids[0]
            if not self._relieve_pressure(seq):
                return None

    def _unpin_partial(self, pid: int) -> bool:
        """Pool-exhaustion fallback for the COW guard: when the copy a
        shared write page needs can't be allocated and the page's only
        other holder is the prefix tree's partial-tail entry, drop that
        entry so the writer owns the page outright. Trades a future hit
        opportunity for liveness — a pool sized exactly to its sequences
        must never deadlock on the pin the tree itself added."""
        prefix = self.scheduler.prefix
        return (prefix is not None and prefix.release_partial(pid)
                and self.scheduler.pool.ref(pid) == 1)

    def _ensure_pages(self, num_tokens: int = 1):
        """Grow each active sequence's page list for this step's write
        window (``num_tokens`` rows at ``seq.pos..`` — 1 for decode,
        1 + K for a speculative verify chunk), swapping out the youngest
        sequences when the pool runs dry, and give it exclusive ownership
        of *every* page in the window (copy-on-write: shared pages are
        never scribbled on — which is also what makes speculative
        rollback safe: a rejected draft's write only ever landed in a
        page this sequence owns alone)."""
        sched = self.scheduler
        ps = self.serve_cfg.page_size
        for seq in list(sched.decode_ready()):
            if sched.slots[seq.slot] is not seq:
                continue  # already preempted by an elder this pass
            while not sched.try_grow(seq, num_tokens):
                if not self._relieve_pressure(seq):
                    raise RuntimeError(
                        "page pool exhausted for a lone sequence")
            last = seq.pos + num_tokens - 1
            for wp in range(seq.pos // ps, last // ps + 1):
                pid = seq.pages[wp]
                if sched.pool.ref(pid) > 1:
                    # copy-on-write: this step writes into a page other
                    # holders reference — copy it to a fresh page and
                    # repoint
                    src_fmt = (int(self.page_fmts[pid])
                               if self.tiered else None)
                    new = self._alloc_one(seq)
                    if new is None:
                        if self._unpin_partial(pid):
                            continue  # sole holder now; write in place
                        raise RuntimeError(
                            "page pool exhausted for a lone sequence")
                    self.cache = self._copy_page(
                        self.cache, jnp.asarray(pid, jnp.int32),
                        jnp.asarray(new, jnp.int32))
                    self._count_dispatch("write")
                    sched.pool.free([pid])
                    seq.pages[wp] = new
                    sched.cow_copies += 1
                    if self.tiered and src_fmt != self._base_fmt_id:
                        # copy_page moved raw bytes, so the fresh page
                        # inherited the source's narrow encoding; this
                        # step's fp8 write would corrupt it. Promote the
                        # copy back to the base format (decode +
                        # re-encode — widening is lossless) first.
                        self._drain_allocs()
                        self._set_page_fmt(new, FORMAT_BY_ID[src_fmt])
                        self._repack_pages_to(
                            [new], FORMAT_BY_ID[self._base_fmt_id])
        if self.tiered:
            self._drain_allocs()
            for seq in sched.decode_ready():
                if sched.slots[seq.slot] is not seq:
                    continue
                last = seq.pos + num_tokens - 1
                self._mark_write(seq.pages[seq.pos // ps: last // ps + 1])

    def step(self) -> bool:
        """Admit what fits, advance prefill chunks under the token
        budget, run one decode (or speculative verify) step over the
        decode-ready slots — as ONE ragged dispatch by default
        (``step_mode="ragged"``), or as the split decode / verify /
        prefill dispatch sequence (``"split"``, the validated oracle).
        Returns True if any work remains afterwards."""
        self._step_dispatches = 0
        self._step_had_prefill = False
        self._step_had_decode = False
        try:
            return self._step_inner()
        finally:
            self.dispatches_last_step = self._step_dispatches
            if self._step_had_decode and self._step_had_prefill:
                self.mixed_steps += 1
                self.mixed_step_dispatches += self._step_dispatches

    def _step_inner(self) -> bool:
        sched = self.scheduler
        self._tick += 1
        self._admit()
        if not sched.active():
            if sched.queue and self._reclaim_swapped_refs():
                self._admit()  # pinned shared pages were the blocker
            if not sched.active():
                if sched.queue:
                    raise RuntimeError("scheduler stalled with queued work")
                return sched.has_work
        if self.ragged:
            self._run_repack()
            self._ragged_step()
            return sched.has_work
        self._run_prefill_chunks()
        self._run_repack()
        if not sched.decode_ready():
            # every active sequence is still streaming its prompt; the
            # chunk(s) above were this step's progress
            return sched.has_work
        if self.spec_enabled:
            self._spec_step()
            return sched.has_work
        self._ensure_pages()
        tokens, pos, page_rows, act = sched.assemble()
        args = (self._sync_fmts(),) if self.tiered else ()
        toks_dev, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(page_rows), jnp.asarray(pos),
            *self._slot_sampling(act), *args)
        self._count_dispatch("decode")
        self._step_had_decode = True
        toks = np.asarray(toks_dev)
        self.steps += 1
        for seq in act:
            sched.advance(seq)
            sched.record_token(seq, int(toks[seq.slot]),
                               eos_id=self.serve_cfg.eos_id)
        return sched.has_work

    def _ragged_step(self) -> None:
        """One single-dispatch ragged engine step.

        Every decode-ready sequence contributes its pending token (plus K
        drafter proposals under speculative decoding) and every prefilling
        sequence contributes its next prompt chunk; the packed
        (max_slots, W) row batch runs through ONE jitted call of
        ``model.ragged_step_paged`` — attention over the paged MX cache,
        the in-kernel quantize-write of every row's new K/V (no
        ``.at[].set`` round-trip anywhere), next-token sampling and draft
        verification all inside the dispatch. Token streams match the
        split path bit-for-bit: each row runs the same projection / RoPE
        / quantize / flash math its split counterpart ran, and sampling
        keys are (request seed, stream index) in both modes. Unlike the
        split path's budgeted round-robin, every prefilling sequence
        advances one chunk per step — the per-step prefill cost is
        bounded by the batch width instead of ``prefill_token_budget``.
        """
        sched = self.scheduler
        k = self._ragged_k
        self._ensure_pages(1 + k)
        if self.tiered:
            self._drain_allocs()
            ps = self.serve_cfg.page_size
            for seq in sched.prefilling():
                st = seq.prefill_pos
                # same formula assemble_ragged is about to apply — the
                # pre-pass must mark exactly the pages the step writes
                real = sched.planned_prefill_real(seq, self._ragged_width)
                if real > 0:
                    self._mark_write(
                        seq.pages[st // ps: (st + real - 1) // ps + 1])
        (tokens, row_start, seq_lens, logit_idx, page_rows, modes,
         decode, prefill) = sched.assemble_ragged(self._ragged_width,
                                                  extra_tokens=k)
        if not decode and not prefill:
            return
        if k:
            for seq in decode:
                history = np.concatenate(
                    [seq.req.prompt,
                     np.asarray(seq.req.generated, np.int32)])
                drafts = np.asarray(self.drafter.propose(history, k),
                                    np.int32)
                if drafts.shape != (k,):
                    raise ValueError(
                        f"drafter returned shape {drafts.shape}, "
                        f"wanted ({k},)")
                tokens[seq.slot, 1:1 + k] = drafts
        # prefill-final rows sample at stream index 0 (len(generated) is
        # 0), decode/verify rows at their next index — one parameter
        # vector covers every mode
        samp = self._slot_sampling(decode + [t[0] for t in prefill])
        args = (self._sync_fmts(),) if self.tiered else ()
        call_args = (self._step_params, self.cache, jnp.asarray(tokens),
                     jnp.asarray(page_rows), jnp.asarray(row_start),
                     jnp.asarray(seq_lens), jnp.asarray(logit_idx),
                     *samp, *args)
        if self.pallas_calls_per_step is None and self.mesh is None:
            self._audit_dispatches(call_args)
        out = self._ragged_fn(*call_args)
        self._count_dispatch("ragged")
        if k:
            toks_dev, n_emit_dev, emitted_dev, self.cache = out
            n_emit = np.asarray(n_emit_dev)
            emitted = np.asarray(emitted_dev)
        else:
            toks_dev, self.cache = out
        toks = np.asarray(toks_dev)
        if decode:
            self.steps += 1
            self._step_had_decode = True
        if prefill:
            self._step_had_prefill = True
            self.prefill_chunks += len(prefill)
            self.prefill_tokens += int(sum(t[2] for t in prefill))
            self.prefill_dispatches += 1
        # decode / verify rows: the advance-then-record pairing of the
        # split loops, EOS and max_new recycling the slot the same step
        if k:
            if decode:
                self.spec_steps += 1
            for seq in decode:
                cnt = int(n_emit[seq.slot])
                self.spec_seq_steps += 1
                self.drafted_tokens += k
                self.accepted_tokens += cnt - 1
                for tok in emitted[seq.slot, :cnt]:
                    sched.advance(seq)
                    self.emitted_tokens += 1
                    if not sched.record_token(
                            seq, int(tok), eos_id=self.serve_cfg.eos_id):
                        break
        else:
            for seq in decode:
                sched.advance(seq)
                sched.record_token(seq, int(toks[seq.slot]),
                                   eos_id=self.serve_cfg.eos_id)
        # prefill rows: the chunk's K/V already landed in-dispatch; a
        # prompt-final chunk samples its request's first token from its
        # own logits row and flips the sequence to decoding
        for seq, st, real, final in prefill:
            seq.pos = st + real
            seq.prefill_pos = None if final else st + real
            if final:
                sched.register_prefix(seq)
                self._record_first_token(seq.req.id)
                sched.record_token(seq, int(toks[seq.slot]),
                                   eos_id=self.serve_cfg.eos_id)

    def _spec_step(self) -> None:
        """One speculative draft + batched verify + rollback step.

        Each active slot feeds its pending token plus K drafter
        proposals; one ``verify_step_paged`` call writes all K + 1
        tokens' K/V into the slot's (exclusively owned — see
        ``_ensure_pages``) pages and returns per-position logits under
        causal intra-chunk masking; acceptance runs in the same dispatch
        (``sampling.verify_rejection``). Greedy rows keep the longest
        draft prefix matching the model's own argmaxes plus one bonus
        token — token-identical to non-speculative decode regardless of
        the drafter. Stochastic rows run point-mass rejection sampling
        against the filtered target distribution, so every emitted token
        is distributed exactly as plain sampling at that stream position
        (lossless; see ``serve.sampling``). Rejected drafts are rolled
        back page-exactly by simply not advancing ``seq.pos`` past the
        accepted point: their rows are dead by position masking and the
        next write there overwrites them (nothing zeroed, nothing
        copied, shared pages never touched).
        """
        sched = self.scheduler
        k = self.serve_cfg.num_draft_tokens
        self._ensure_pages(1 + k)
        tokens, pos, page_rows, act = sched.assemble(extra_tokens=k)
        for seq in act:
            history = np.concatenate(
                [seq.req.prompt,
                 np.asarray(seq.req.generated, np.int32)])
            drafts = np.asarray(self.drafter.propose(history, k), np.int32)
            if drafts.shape != (k,):
                raise ValueError(
                    f"drafter returned shape {drafts.shape}, wanted ({k},)")
            tokens[seq.slot, 1:] = drafts
        args = (self._sync_fmts(),) if self.tiered else ()
        n_emit_dev, emitted_dev, self.cache = self._verify(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(page_rows), jnp.asarray(pos),
            *self._slot_sampling(act), *args)
        self._count_dispatch("verify")
        self._step_had_decode = True
        n_emit = np.asarray(n_emit_dev)
        emitted = np.asarray(emitted_dev)
        self.steps += 1
        self.spec_steps += 1
        for seq in act:
            cnt = int(n_emit[seq.slot])
            self.spec_seq_steps += 1
            self.drafted_tokens += k
            self.accepted_tokens += cnt - 1
            for tok in emitted[seq.slot, :cnt]:
                # each emitted token validates one more written row
                # (advance) before it is recorded — the verify-time
                # mirror of the decode loop's advance/record pair; the
                # loop stopping early (EOS / max_new) is the rollback
                sched.advance(seq)
                self.emitted_tokens += 1
                if not sched.record_token(seq, int(tok),
                                          eos_id=self.serve_cfg.eos_id):
                    break

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               sampling_params: Optional[SamplingParams] = None) -> int:
        """Queue one request; returns its id. Use with :meth:`run`.

        ``sampling_params`` overrides the engine-default temperature /
        top-p / top-k / seed for this request alone (None = defaults).
        Raises :class:`~.overload.ShedError` when overload control is
        configured and admitting this request would already miss the
        SLO — shed at the door, before it costs a slot, pages, and
        prefill work.
        """
        self.overload.admit(len(self.scheduler.queue))
        sp = (sampling_params.validate() if sampling_params is not None
              else self._default_sampling)
        seed = sampling.resolve_seed(sp, self.serve_cfg.seed,
                                     self.scheduler._next_id)
        rid = self.scheduler.submit(prompt, max_new_tokens,
                                    sampling=sp, seed=seed)
        self._submit_time[rid] = time.perf_counter()
        return rid

    def cancel(self, request_id: int) -> bool:
        """Abandon a request mid-flight (client disconnect): frees its
        slot, exclusively-owned pages, and prefix-cache retains the same
        step, wherever it currently lives — queued, mid-prefill,
        decoding, or swapped out. True if the request was found (False:
        it already finished and its resources are long gone)."""
        found = self.scheduler.cancel(request_id)
        if found:
            self._submit_time.pop(request_id, None)
            if self.tiered:
                self._swap_fmts.pop(request_id, None)
        return found

    def save_prefix_cache(self, path) -> int:
        """Persist the prefix cache — radix-tree structure AND the exact
        device bytes of every page it holds — to ``path`` (npz).

        A restarted engine :meth:`load_prefix_cache`-s this and
        warm-starts shared prompt heads without recomputing (or even
        re-quantizing) them: the restored pages are bit-identical, so
        decode over an imported hit is token-identical to decode over
        the original cache. Tiered engines save each page's element
        format alongside its bytes (an fp4-repacked page must be read as
        fp4 after import). Returns the number of pages saved.
        """
        prefix = self.scheduler.prefix
        if prefix is None:
            raise RuntimeError("engine has no prefix cache to save")
        state = prefix.export_state()
        pids = sorted({nd["page"] for nd in state["nodes"]}
                      | {ent["page"] for ent in state["partials"]})
        payload = {
            "structure": np.frombuffer(json.dumps(state).encode(),
                                       np.uint8),
            "page_ids": np.asarray(pids, np.int64),
        }
        if self.tiered:
            payload["page_fmts"] = np.asarray(
                [int(self.page_fmts[p]) for p in pids], np.int32)
        if pids:
            snap = self._extract(self.cache, jnp.asarray(0, jnp.int32),
                                 jnp.asarray(pids, jnp.int32))
            for i, leaf in enumerate(jax.tree_util.tree_leaves(snap)):
                arr = np.asarray(leaf)
                # raw bytes + dtype name + shape: survives MX element /
                # bf16-scale dtypes that plain savez may not round-trip
                payload[f"leaf_{i}_bytes"] = np.frombuffer(
                    arr.tobytes(), np.uint8)
                payload[f"leaf_{i}_dtype"] = np.asarray(arr.dtype.name)
                payload[f"leaf_{i}_shape"] = np.asarray(arr.shape,
                                                        np.int64)
        np.savez(path, **payload)
        return len(pids)

    def load_prefix_cache(self, path) -> int:
        """Warm-start the prefix cache from :meth:`save_prefix_cache`
        output: allocates fresh pages, restores the saved bytes into
        them verbatim, and rebuilds the radix tree over the new ids.
        Requires an empty prefix cache (call it before serving traffic).
        Returns the number of tree entries (nodes + partials) imported.
        """
        prefix = self.scheduler.prefix
        if prefix is None:
            raise RuntimeError("engine has no prefix cache to load into")
        data = np.load(path)
        state = json.loads(bytes(data["structure"]).decode())
        old_ids = [int(x) for x in data["page_ids"]]
        new_ids = []
        if old_ids:
            new_ids = self.scheduler._alloc_with_evict(len(old_ids))
            if new_ids is None:
                raise RuntimeError(
                    f"page pool cannot hold {len(old_ids)} imported "
                    "prefix pages")
            # the reference extract supplies the authoritative treedef,
            # dtypes, and shapes — the snapshot must match this engine's
            # model/page geometry exactly
            ref = self._extract(self.cache, jnp.asarray(0, jnp.int32),
                                jnp.asarray(new_ids, jnp.int32))
            leaves_ref, treedef = jax.tree_util.tree_flatten(ref)
            leaves = []
            for i, lr in enumerate(leaves_ref):
                dtype = np.dtype(lr.dtype)
                shape = tuple(int(s) for s in data[f"leaf_{i}_shape"])
                if str(data[f"leaf_{i}_dtype"]) != dtype.name \
                        or shape != tuple(lr.shape):
                    raise ValueError(
                        f"prefix snapshot leaf {i} is "
                        f"{str(data[f'leaf_{i}_dtype'])}{shape}, this "
                        f"engine expects {dtype.name}{tuple(lr.shape)} — "
                        "saved under a different model or page config")
                leaves.append(jnp.asarray(np.frombuffer(
                    data[f"leaf_{i}_bytes"].tobytes(),
                    dtype).reshape(shape)))
            self.cache = self._restore(
                self.cache, jax.tree_util.tree_unflatten(treedef, leaves),
                jnp.asarray(0, jnp.int32), jnp.asarray(new_ids, jnp.int32))
        count = prefix.import_state(state,
                                    dict(zip(old_ids, new_ids)))
        if self.tiered:
            # alloc reset the fresh pages to the base format; re-apply
            # the formats the bytes were saved under
            self._drain_allocs()
            for pid, fid in zip(new_ids, data["page_fmts"]):
                if int(fid) != self._base_fmt_id:
                    self._set_page_fmt(pid, FORMAT_BY_ID[int(fid)])
        return count

    def run(self) -> Dict[int, np.ndarray]:
        """Serve until drained. Returns {request_id: prompt + generated}."""
        while self.step():
            pass
        out = {}
        for req in self.scheduler.finished:
            out[req.id] = np.concatenate(
                [req.prompt, np.asarray(req.generated, np.int32)])
        self.scheduler.finished.clear()
        return out

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """Batch API, shape-compatible with ``FixedSlotEngine.generate``.

        Rows that hit EOS early are right-padded with ``eos_id``.
        """
        if key is not None:
            self._key = key
        prompts = np.asarray(prompts, np.int32)
        b, s0 = prompts.shape
        ids = [self.submit(prompts[i], max_new_tokens) for i in range(b)]
        results = self.run()
        pad = self.serve_cfg.eos_id if self.serve_cfg.eos_id is not None else 0
        out = np.full((b, s0 + max_new_tokens), pad, np.int32)
        for row, rid in enumerate(ids):
            toks = results[rid]
            out[row, : len(toks)] = toks
        return out

    def cache_stats(self) -> Dict[str, float]:
        """Allocation + peak-usage + prefix-sharing + dispatch stats."""
        page_bytes = kv_cache.pool_page_nbytes(
            self.cache, self.num_pages + self._trash_pages)
        sched = self.scheduler
        stats = {
            "allocated_bytes": kv_cache.cache_nbytes(self.cache),
            "page_bytes": page_bytes,
            "state_bytes": kv_cache.state_nbytes(self.cache),
            "peak_pages": sched.peak_pages,
            "resident_tokens_at_peak": sched.resident_at_peak,
            "preemptions": sched.preemptions,
            "peak_paged_bytes": page_bytes * sched.peak_pages,
            "skipped_admissions": sched.skipped_admissions,
            "deferred_admissions": sched.deferred_admissions,
            "cancellations": sched.cancellations,
            "shed_count": self.overload.shed_count,
            "cow_copies": sched.cow_copies,
            "prompt_tokens": self.prompt_tokens,
            "prefill_tokens_computed": self.prefill_tokens,
            "prefix_hit_rate": (
                1.0 - self.prefill_tokens / self.prompt_tokens
                if self.prompt_tokens else 0.0),
            "prefill_chunks": self.prefill_chunks,
            "prefill_dispatches": self.prefill_dispatches,
            "deferral_fallbacks": sched.deferral_fallbacks,
            # the monolithic fallback's live jitted-trace population
            # (LRU-bounded); the chunked path's traces are keyed by
            # batch size only, bounded by max_slots
            "prefill_traces": (len(self._prefill_fns)
                               + len(self._prefill_tail_fns)),
            # sharded serving: KV-head shards the pool/projections are
            # split over (1 = single-device / unsharded fallback)
            "kv_head_shards": self.tp,
        }
        # device-dispatch accounting: the ragged step's claim is
        # dispatches_per_mixed_step == 1 — every step that does decode
        # AND prefill work issues exactly one jitted call
        for kind, n in self.dispatch_counts.items():
            stats[f"dispatches_{kind}"] = n
        total_dispatches = sum(self.dispatch_counts.values())
        stats.update({
            "dispatches_total": total_dispatches,
            "dispatches_last_step": self.dispatches_last_step,
            "dispatches_per_step": (total_dispatches / self.steps
                                    if self.steps else 0.0),
            "mixed_steps": self.mixed_steps,
            "dispatches_per_mixed_step": (
                self.mixed_step_dispatches / self.mixed_steps
                if self.mixed_steps else 0.0),
            # jaxpr-derived device-kernel count of ONE traced engine step
            # (measured at the first ragged dispatch; None before then or
            # off the ragged path): the layer-fused megakernel's whole
            # claim is that this is 1 where the per-layer step pays L
            "pallas_calls_per_step": self.pallas_calls_per_step,
            "megakernel": getattr(self, "megakernel", False),
            # ragged-aware prefill budgeting: prompt rows retired per
            # ragged dispatch that carried prefill work (> chunk size
            # means multi-chunk bites were taken on undersubscribed steps)
            "prefill_rows_per_step": (
                self.prefill_tokens / self.prefill_dispatches
                if self.prefill_dispatches else 0.0),
        })
        if self.tiered:
            pool = sched.pool
            for fmt in self._mixed_fmts:
                fid = FORMAT_IDS[fmt]
                stats[f"pages_{fmt}"] = sum(
                    1 for pid in range(self.num_pages)
                    if pool.ref(pid) > 0 and self.page_fmts[pid] == fid)
            stats.update({
                "unit_budget": pool.unit_budget,
                "units_in_use": pool.units_in_use,
                "peak_units": pool.peak_units,
                "repacked_pages": self.repacked_pages,
                "repack_dispatches": self.repack_dispatches,
                "max_repacked_in_step": self.max_repacked_in_step,
            })
        if self.admission_latencies:
            lat = np.sort(np.asarray(self.admission_latencies))
            stats["admission_latency_p50"] = float(
                lat[int(0.50 * (len(lat) - 1))])
            stats["admission_latency_p95"] = float(
                lat[int(round(0.95 * (len(lat) - 1)))])
            stats["admission_latency_mean"] = float(lat.mean())
        if self.spec_enabled:
            stats.update({
                "spec_steps": self.spec_steps,
                "drafted_tokens": self.drafted_tokens,
                "accepted_tokens": self.accepted_tokens,
                "emitted_tokens": self.emitted_tokens,
                # the speculative payoff: tokens a sequence emits per
                # verify step it takes part in (1 = no better than plain
                # decode, K+1 = perfect drafts) — normalized per sequence
                # so continuous-batching parallelism doesn't inflate it
                "accepted_per_step": (
                    self.emitted_tokens / self.spec_seq_steps
                    if self.spec_seq_steps else 0.0),
                "draft_acceptance_rate": (
                    self.accepted_tokens / self.drafted_tokens
                    if self.drafted_tokens else 0.0),
            })
        if sched.prefix is not None:
            stats.update(sched.prefix.stats())
        return stats


# the default engine: continuous batching over the paged MX cache
ServeEngine = ContinuousBatchingEngine


def make_serve_step(cfg: ModelConfig):
    """The (cache, token, pos) -> (logits, cache) step used by the dry-run.

    This is what ``decode_*`` shapes lower: one new token against a KV cache
    of seq_len, global_batch requests in flight.
    """

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cfg, cache, tokens=tokens, pos=pos)

    return serve_step
