"""Serving engines: MX-compressed weights + (paged) MX KV cache.

Two engines share one numerics contract:

  * ``FixedSlotEngine`` — the original continuous-batching-lite loop: a
    fixed batch of slots, one shared position counter, ring-buffer caches
    sized batch x max_seq. Kept as the golden reference: its greedy
    outputs define correctness for the paged path.
  * ``ContinuousBatchingEngine`` (exported as ``ServeEngine``) — requests
    enter and leave mid-stream. Admission prefills one request into pages
    drawn from a global MX page pool (``kv_cache``), the jitted decode
    step runs at fixed shapes (max_slots rows, padding rows masked by
    dropped writes), and EOS/max_new recycles the slot and pages the same
    step (``scheduler``). Per-request greedy outputs are token-identical
    to the fixed-slot engine because every op on the path — projection,
    RoPE, cache quantize/dequantize, masked softmax — is batch-row
    independent and shared between the two paths.

Why this is the paper's serving payoff at production shape: the decode
step's HBM traffic is dominated by the KV cache; MX storage cuts it ~2x
(fp8+E8M0 vs bf16) and paging cuts the *allocated* footprint to what is
actually resident, so ragged, churning traffic stops paying for max_seq
rectangles. ``benchmarks/serve_throughput.py`` measures both.

The decode step runs the single-pass fused Pallas flash-decode kernel by
default (``ServeConfig.decode_kernel="fused"``): attention walks the page
table in-kernel, dequantizes compact MX tiles in-register, and skips
unallocated pages, so per-step attention *work* also scales with resident
tokens — not just the footprint.

Speculative decoding (``ServeConfig.spec_decode``) feeds that kernel
properly: instead of one token per step, each sequence drafts K cheap
candidates (prompt-lookup n-gram by default — no second model) and one
batched multi-token verify pass (``model.verify_step_paged`` over the
Tq > 1 fused kernel) checks them all, amortizing the page walk and
in-register dequant across the chunk. Greedy acceptance + page-exact
rollback keep the output token stream identical to non-speculative
decode for any drafter (see ``spec_decode``).

Prefill is chunked by default (``ServeConfig.prefill_mode="chunked"``):
instead of one monolithic dense prefill per prompt — which materializes
wide bf16 K/V for the whole prompt, installs it into pages afterwards,
retraces per prompt length, and blocks every resident decoder for the
full prompt duration — each prompt streams through fixed-size
page-aligned chunks that run straight against the MX page pool
(``model.prefill_chunk_paged`` over ``mx_attention_prefill_fused``: the
chunk's K/V is quantized and written into its pages *inside* the kernel,
and the chunk attends over everything resident plus itself). Chunks are
interleaved with decode steps under a per-step token budget
(Sarathi-style), so admission latency is O(chunk), head-of-line blocking
disappears, and the engine needs exactly ONE jitted prefill trace.
``prefill_mode="monolithic"`` keeps the dense path as the validated
reference oracle (its per-length trace caches now LRU-bounded); both
modes produce token-identical greedy streams because prefill, decode and
verify share one projection/RoPE/quantize path.

``decode_kernel="einsum"`` is the escape
hatch back to the gather-and-dequantize reference path (what wide bf16
pools fall back to, and what ``benchmarks/decode_attention.py`` compares
against). Numerics caveat: the fused kernel keeps the softmax in f32
while the einsum path rounds probabilities to bf16 before the value
matmul, so across-path logits differ at bf16-rounding level and a greedy
step whose top-2 gap sits inside that band can flip (README §Serving);
within a path, determinism and the paging machinery's exactness
(snapshot/restore, COW, prefix sharing) are unchanged.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import OrderedDict, deque
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import model
from repro.nn.config import ModelConfig

from . import kv_cache, spec_decode
from .scheduler import Scheduler

log = logging.getLogger("repro.serve")

_PAGED_MIXERS = {"attn", "rglru", "ssd"}


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0  # 0 => greedy
    eos_id: Optional[int] = None
    # continuous batching (ignored by FixedSlotEngine)
    max_slots: int = 8
    page_size: int = 16
    num_pages: Optional[int] = None  # default: max_slots * pages_per_slot
    # prefix caching: share page-aligned prompt heads across requests via
    # the radix tree (attention-only models; auto-disabled otherwise)
    prefix_cache: bool = True
    # admission: how far past a stuck queue head to scan for a request
    # that fits (1 = strict FCFS)
    admit_window: int = 4
    # paged decode attention: "fused" (default) runs the single-pass Pallas
    # flash-decode kernel over the page table — per-step work scales with
    # resident tokens; "einsum" is the escape hatch back to the reference
    # gather-and-dequantize path (also what wide bf16 pools fall back to)
    decode_kernel: str = "fused"
    # speculative decoding (greedy only): draft num_draft_tokens per
    # sequence per step and verify them all in one batched multi-token
    # pass over the paged MX cache — token-identical to non-speculative
    # decode for ANY drafter; a good drafter only raises tokens/step.
    # ``drafter`` is "ngram" (prompt-lookup, no second model needed) or a
    # spec_decode.Drafter instance.
    spec_decode: bool = False
    num_draft_tokens: int = 4
    drafter: object = "ngram"
    # prefill path: "chunked" (default) streams each prompt through
    # fixed-size page-aligned chunks straight against the MX page pool
    # (fused quantize-into-pages kernel, O(1) jitted traces, admission
    # interleaved with decode under a per-step token budget);
    # "monolithic" is the validated reference oracle — one dense prefill
    # per prompt + page install, retracing per prompt length. Models with
    # recurrent mixers fall back to monolithic automatically (their state
    # is per-slot, not paged — chunks have nothing to resume from).
    prefill_mode: str = "chunked"
    # chunk length in tokens; must be a multiple of page_size so chunk
    # starts stay page-aligned (no page ever blends two chunks)
    prefill_chunk: int = 64
    # max prefill tokens processed per engine step (Sarathi-style budget;
    # default = one chunk). The budget is spent round-robin across
    # admitted-but-prefilling sequences, so a short prompt's first token
    # never waits for a long neighbour's full prompt.
    prefill_token_budget: Optional[int] = None
    # LRU bound on the monolithic path's per-(length, prefix) jitted
    # prefill traces — a long-running server on the fallback path must
    # not grow trace memory without limit (the chunked path needs no
    # bound: its trace population is 1 by construction)
    prefill_trace_cache: int = 32


def _sample(logits, key, temperature: float):
    logits = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


class FixedSlotEngine:
    """Fixed batch of slots, one shared position (the golden reference)."""

    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, cfg, tokens=toks,
                                          max_seq=serve_cfg.max_seq))
        self._decode = jax.jit(
            lambda p, cache, tok, pos: model.decode_step(
                p, cfg, cache, tokens=tok, pos=pos))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        logits, cache = self._prefill(self.params, prompts)
        out = [prompts]
        tok = _sample(logits, key, self.serve_cfg.temperature)
        for i in range(max_new_tokens):
            out.append(tok[:, None])
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(s0 + i, jnp.int32)
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            tok = _sample(logits, sub, self.serve_cfg.temperature)
        return np.asarray(jnp.concatenate(out, axis=1))


class ContinuousBatchingEngine:
    """Continuous batching over a paged MX KV cache."""

    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        unsupported = {bd.mixer for bd in
                       (*cfg.prologue, *cfg.pattern, *cfg.epilogue)
                       } - _PAGED_MIXERS
        if unsupported:
            raise NotImplementedError(
                f"continuous batching does not support mixers {unsupported} "
                "— use FixedSlotEngine (launch/serve.py --engine fixed)")
        if cfg.num_codebooks > 1:
            raise NotImplementedError(
                "continuous batching with codebook heads is a follow-on")
        if serve_cfg.decode_kernel not in ("einsum", "fused"):
            raise ValueError(
                f"unknown decode_kernel {serve_cfg.decode_kernel!r} "
                "(expected 'fused' or 'einsum')")
        mixers = {bd.mixer for bd in (*cfg.prologue, *cfg.pattern,
                                      *cfg.epilogue)}
        self.spec_enabled = bool(serve_cfg.spec_decode)
        if self.spec_enabled:
            if serve_cfg.num_draft_tokens < 1:
                raise ValueError("spec_decode needs num_draft_tokens >= 1")
            if serve_cfg.temperature > 0:
                raise ValueError(
                    "speculative decoding currently requires greedy "
                    "sampling (temperature=0): acceptance compares greedy "
                    "argmaxes (typical-acceptance sampling is a ROADMAP "
                    "follow-on)")
            if mixers - {"attn"}:
                raise NotImplementedError(
                    f"speculative decoding requires attention-only models, "
                    f"got mixers {sorted(mixers - {'attn'})}: recurrent "
                    "state has no position axis to roll rejected drafts "
                    "back through")
            self.drafter = spec_decode.resolve_drafter(
                serve_cfg.drafter, cfg.vocab_size)
        self.params = params
        self.cfg = cfg
        # full-length (non-ring) prefill caches: slot == absolute position,
        # so a prompt cache reshapes exactly into its pages
        self.cfg_prefill = cfg.replace(serve_full_cache=True)
        # the decode step runs the fused flash-decode kernel by default;
        # ServeConfig.decode_kernel="einsum" is the escape hatch back to
        # the gather-and-dequantize reference path
        self.cfg_decode = cfg.replace(decode_kernel=serve_cfg.decode_kernel)
        self.serve_cfg = serve_cfg
        ps = serve_cfg.page_size
        pages_per_slot = kv_cache.pages_for(serve_cfg.max_seq, ps)
        self.num_pages = (serve_cfg.num_pages
                          or serve_cfg.max_slots * pages_per_slot)
        # prefix sharing needs every mixer to be attention: K/V pages are a
        # pure function of the token prefix, but recurrent state is not
        # paged (per-prefix snapshots are a follow-on — see ROADMAP)
        self.prefix_enabled = bool(serve_cfg.prefix_cache
                                   and mixers <= {"attn"})
        if serve_cfg.prefix_cache and not self.prefix_enabled:
            log.info("prefix cache disabled: mixers %s are not attention-only",
                     sorted(mixers - {"attn"}))
        if serve_cfg.prefill_mode not in ("chunked", "monolithic"):
            raise ValueError(
                f"unknown prefill_mode {serve_cfg.prefill_mode!r} "
                "(expected 'chunked' or 'monolithic')")
        # chunked prefill streams prompts through the paged attention
        # pools, so it needs every mixer paged — recurrent state is
        # per-slot and has no chunk to resume from; fall back like the
        # prefix cache does rather than failing the whole engine
        self.chunked = (serve_cfg.prefill_mode == "chunked"
                        and mixers <= {"attn"})
        if serve_cfg.prefill_mode == "chunked" and not self.chunked:
            log.info("chunked prefill disabled: mixers %s are not "
                     "attention-only; using monolithic prefill",
                     sorted(mixers - {"attn"}))
        if self.chunked:
            if serve_cfg.prefill_chunk <= 0:
                raise ValueError("prefill_chunk must be >= 1")
            budget = serve_cfg.prefill_token_budget
            if budget is not None and budget <= 0:
                raise ValueError("prefill_token_budget must be >= 1")
            # budget in whole chunks; anything below one chunk still
            # makes progress (one chunk per step)
            self._chunks_per_step = max(
                1, (budget or serve_cfg.prefill_chunk)
                // serve_cfg.prefill_chunk)
        if serve_cfg.prefill_trace_cache < 1:
            raise ValueError("prefill_trace_cache must be >= 1")
        self.scheduler = Scheduler(
            max_slots=serve_cfg.max_slots, num_pages=self.num_pages,
            page_size=ps, max_seq=serve_cfg.max_seq,
            prefix_cache=self.prefix_enabled,
            admit_window=serve_cfg.admit_window,
            num_draft_tokens=(serve_cfg.num_draft_tokens
                              if self.spec_enabled else 0),
            prefill_chunk=(serve_cfg.prefill_chunk if self.chunked else 0))
        self.cache = model.init_paged_cache(
            cfg, serve_cfg.max_slots, self.num_pages, ps)
        # donate the cache pytree: without donation every decode step /
        # install / restore copies the whole multi-layer page pool, which
        # would cancel the paged-cache footprint win. CPU has no donation
        # (it only warns), so gate on backend. _extract must NOT donate —
        # the cache lives on after a snapshot.
        cpu = jax.default_backend() == "cpu"
        self._decode = jax.jit(
            lambda p, c, tok, rows, pos: model.decode_step_paged(
                p, self.cfg_decode, c, tok, rows, pos),
            donate_argnums=() if cpu else (1,))
        self._verify = jax.jit(
            lambda p, c, tok, rows, pos: model.verify_step_paged(
                p, self.cfg_decode, c, tok, rows, pos),
            donate_argnums=() if cpu else (1,))
        self._install = jax.jit(
            lambda c, pf, slot, ids: kv_cache.install_prefill(
                c, pf, slot, ids, ps),
            donate_argnums=() if cpu else (0, 1))
        self._extract = jax.jit(kv_cache.extract_seq)
        self._restore = jax.jit(kv_cache.restore_seq,
                                donate_argnums=() if cpu else (0, 1))
        self._copy_page = jax.jit(kv_cache.copy_page,
                                  donate_argnums=() if cpu else (0,))
        # monolithic-path trace caches, LRU-bounded (satellite of the
        # chunked-prefill work: a long-running server on the fallback
        # path must not grow trace memory with every novel length)
        self._prefill_fns = OrderedDict()  # prompt length -> jitted
        self._prefill_tail_fns = OrderedDict()  # (tail, prefix pages) ->
        # the chunked path's ONE jitted trace: fixed (1, C) tokens, full
        # page-table row, dynamic scalars — every prompt length and
        # prefix hit reuses it
        self._prefill_chunk = jax.jit(
            lambda p, c, toks, rows, pos, nv, idx: model.prefill_chunk_paged(
                p, self.cfg_decode, c, toks, rows, pos, nv, idx),
            donate_argnums=() if cpu else (1,))
        self._key = jax.random.PRNGKey(0)
        self.steps = 0
        self.prompt_tokens = 0  # total prompt tokens admitted
        self.prefill_tokens = 0  # prompt tokens actually computed
        self.prefill_chunks = 0  # chunked-prefill kernel invocations
        self._rr_clock = 0  # cross-step round-robin cursor over prefills
        # admission latency: wall seconds from submit() to the request's
        # first sampled token (the serving-side tail-latency metric
        # chunked prefill exists to improve). Bounded sliding window so a
        # long-running server's stats stay O(1) memory — the same
        # unbounded-growth class the LRU trace cap closes.
        self._submit_time: Dict[int, float] = {}
        self.admission_latencies: deque = deque(maxlen=4096)
        # speculative decoding stats
        self.spec_steps = 0  # verify steps run
        self.spec_seq_steps = 0  # (sequence, verify step) participations
        self.drafted_tokens = 0  # k per active sequence per verify step
        self.accepted_tokens = 0  # drafts that matched the greedy target
        self.emitted_tokens = 0  # tokens recorded by verify steps

    # -- internals ----------------------------------------------------------

    def _lru_trace(self, store: OrderedDict, key, build):
        """Fetch-or-build a jitted trace with LRU eviction at the cap.

        The monolithic path traces per prompt length (and per
        (tail, prefix) pair), so an unbounded dict grows with every novel
        length a long-running server sees; evicting the LRU entry drops
        the jit wrapper and its compiled executables with it.
        """
        fn = store.get(key)
        if fn is None:
            fn = build()
            store[key] = fn
        else:
            store.move_to_end(key)
        while len(store) > self.serve_cfg.prefill_trace_cache:
            store.popitem(last=False)
        return fn

    def _prefill_for(self, length: int):
        """Jitted single-request prefill, LRU-cached per prompt length.

        max_seq rounds up to the page boundary so the cache T dim factors
        into whole pages. No padding of the tokens themselves: prefill
        numerics stay exactly those of the fixed-slot batch prefill.
        """
        ps = self.serve_cfg.page_size
        max_seq = kv_cache.pages_for(length, ps) * ps
        return self._lru_trace(
            self._prefill_fns, length,
            lambda: jax.jit(lambda p, toks: model.prefill(
                p, self.cfg_prefill, tokens=toks, max_seq=max_seq)))

    def _prefill_tail_for(self, tail_len: int, n_prefix: int):
        """Jitted tail prefill, LRU-cached per (tail length, prefix pages).

        Reads the shared prefix pages out of the live paged cache and
        prefills only the uncached tail at absolute positions — the
        prefix-cache fast path of the monolithic mode.
        """
        ps = self.serve_cfg.page_size
        max_seq = kv_cache.pages_for(tail_len, ps) * ps
        return self._lru_trace(
            self._prefill_tail_fns, (tail_len, n_prefix),
            lambda: jax.jit(lambda p, c, toks, rows: model.prefill_with_prefix(
                p, self.cfg_prefill, c, toks, rows, n_prefix * ps,
                max_seq=max_seq)))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _record_first_token(self, req_id: int) -> None:
        """Admission-latency sample: submit() -> first sampled token."""
        t0 = self._submit_time.pop(req_id, None)
        if t0 is not None:
            self.admission_latencies.append(time.perf_counter() - t0)

    def _admit(self):
        sched = self.scheduler
        while True:
            seq = sched.admit_next()
            if seq is None:
                return
            if seq.req.swap is not None:
                # swapped-out sequence: restore the exact bytes of the
                # pages it exclusively owned into their fresh replacements
                # (shared prefix pages stayed resident under other refs);
                # its pending token decodes — or its prefill resumes —
                # next step
                snapshot, owned_idx, *_ = seq.req.swap
                seq.req.swap = None
                if owned_idx:
                    self.cache = self._restore(
                        self.cache, snapshot,
                        jnp.asarray(seq.slot, jnp.int32),
                        jnp.asarray([seq.pages[i] for i in owned_idx],
                                    jnp.int32))
                continue
            prompt = seq.req.prompt
            self.prompt_tokens += len(prompt)
            if seq.prefill_pos is not None:
                # chunked mode: admission only binds the slot and pages;
                # the prompt streams through _run_prefill_chunks under
                # the per-step token budget
                continue
            cached = seq.cached_tokens
            if cached:
                # prefix hit: prefill only the uncached tail against the
                # shared pages already resident in the pool
                n_prefix = cached // self.serve_cfg.page_size
                tail = prompt[cached:]
                logits, pfcache = self._prefill_tail_for(
                    len(tail), n_prefix)(
                        self.params, self.cache,
                        jnp.asarray(tail, jnp.int32)[None],
                        jnp.asarray(seq.pages[:n_prefix], jnp.int32))
                install_pages = seq.pages[n_prefix:]
                self.prefill_tokens += len(tail)
            else:
                logits, pfcache = self._prefill_for(len(prompt))(
                    self.params, jnp.asarray(prompt, jnp.int32)[None])
                install_pages = seq.pages
                self.prefill_tokens += len(prompt)
            self.cache = self._install(
                self.cache, pfcache, jnp.asarray(seq.slot, jnp.int32),
                jnp.asarray(install_pages, jnp.int32))
            sched.register_prefix(seq)
            tok = int(_sample(logits, self._next_key(),
                              self.serve_cfg.temperature)[0])
            self._record_first_token(seq.req.id)
            sched.record_token(seq, tok, eos_id=self.serve_cfg.eos_id)

    def _run_prefill_chunks(self) -> None:
        """Advance chunked prefills by up to the per-step token budget.

        The budget is spent round-robin across prefilling sequences, with
        the rotation carried *across* steps (``_rr_clock``): a short
        prompt admitted behind a long one gets its first token after its
        own few chunks, not after the long prompt completes — the
        processor-sharing schedule that moves the admission-latency tail
        (a per-step restart from the oldest sequence would let a long
        prompt hog every one-chunk budget). Each chunk is one call of
        the single jitted trace; the final chunk of a prompt samples the
        request's first token and flips the sequence to decoding.
        """
        if not self.chunked:
            return
        sched = self.scheduler
        budget = self._chunks_per_step
        while budget > 0:
            pref = sched.prefilling()
            if not pref:
                return
            self._prefill_one_chunk(pref[self._rr_clock % len(pref)])
            self._rr_clock += 1
            budget -= 1

    def _prefill_one_chunk(self, seq) -> None:
        """Run one fixed-size chunk of ``seq``'s prompt through the paged
        prefill step; on the final chunk, sample the first token."""
        sched = self.scheduler
        c = self.serve_cfg.prefill_chunk
        prompt = seq.req.prompt
        start = seq.prefill_pos
        real = min(c, len(prompt) - start)
        tokens = np.zeros((1, c), np.int32)
        tokens[0, :real] = prompt[start:start + real]
        rows = np.full((1, sched.pages_per_slot), -1, np.int32)
        rows[0, : len(seq.pages)] = seq.pages
        final = start + real >= len(prompt)
        logits, self.cache = self._prefill_chunk(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(rows), jnp.asarray([start], jnp.int32),
            jnp.asarray([real], jnp.int32),
            jnp.asarray([real - 1], jnp.int32))
        self.prefill_tokens += real
        self.prefill_chunks += 1
        seq.pos = start + real
        seq.prefill_pos = start + c
        if final:
            seq.prefill_pos = None
            sched.register_prefix(seq)
            tok = int(_sample(logits, self._next_key(),
                              self.serve_cfg.temperature)[0])
            self._record_first_token(seq.req.id)
            sched.record_token(seq, tok, eos_id=self.serve_cfg.eos_id)

    def _swap_out(self, victim) -> None:
        """Preempt ``victim``: snapshot + free only the pages it
        exclusively owns; shared pages keep their other references."""
        sched = self.scheduler
        owned_idx, owned_ids = sched.exclusive_pages(victim)
        snapshot = None
        if owned_ids:
            snapshot = self._extract(
                self.cache, jnp.asarray(victim.slot, jnp.int32),
                jnp.asarray(owned_ids, jnp.int32))
        sched.preempt(victim, snapshot, owned_idx)

    def _reclaim_swapped_refs(self) -> bool:
        """Last-resort pool reclamation: queued swapped-out requests still
        retain references on shared pages (normally the cheap choice — the
        pages stay resident under the tree's reference too). When those
        pins would starve a live sequence, extract the shared pages' exact
        bytes into the swap snapshots and drop the references, turning the
        pages evictable/freeable. Restore then treats them like any other
        owned page, so generation stays bit-identical. Returns True if any
        reference was dropped.
        """
        sched = self.scheduler
        released = False
        for req in sched.queue:
            if req.swap is None:
                continue
            snapshot, owned_idx, pages, pos, cached, prefill_pos = req.swap
            owned = set(owned_idx)
            shared_idx = [i for i in range(len(pages)) if i not in owned]
            if not shared_idx:
                continue
            extra = self._extract(
                self.cache, jnp.asarray(0, jnp.int32),
                jnp.asarray([pages[i] for i in shared_idx], jnp.int32))
            req.swap = (kv_cache.merge_snapshots(snapshot, extra),
                        owned_idx + shared_idx, pages, pos, cached,
                        prefill_pos)
            sched.pool.free([pages[i] for i in shared_idx])
            released = True
        return released

    def _relieve_pressure(self, seq) -> bool:
        """One escalation step when ``seq`` can't get a page (tree LRU
        eviction already ran inside ``_alloc_with_evict``): swap out the
        youngest other sequence, else reclaim swapped requests' pinned
        shared refs. False means the pool is genuinely exhausted. Single
        source of the escalation order for the grow and COW paths."""
        victim = self.scheduler.pick_victim(exclude=seq)
        if victim is not None:
            self._swap_out(victim)
            return True
        return self._reclaim_swapped_refs()

    def _alloc_one(self, seq) -> Optional[int]:
        """One fresh page for ``seq``, evicting / preempting as needed."""
        while True:
            ids = self.scheduler._alloc_with_evict(1)
            if ids is not None:
                return ids[0]
            if not self._relieve_pressure(seq):
                return None

    def _ensure_pages(self, num_tokens: int = 1):
        """Grow each active sequence's page list for this step's write
        window (``num_tokens`` rows at ``seq.pos..`` — 1 for decode,
        1 + K for a speculative verify chunk), swapping out the youngest
        sequences when the pool runs dry, and give it exclusive ownership
        of *every* page in the window (copy-on-write: shared pages are
        never scribbled on — which is also what makes speculative
        rollback safe: a rejected draft's write only ever landed in a
        page this sequence owns alone)."""
        sched = self.scheduler
        ps = self.serve_cfg.page_size
        for seq in list(sched.decode_ready()):
            if sched.slots[seq.slot] is not seq:
                continue  # already preempted by an elder this pass
            while not sched.try_grow(seq, num_tokens):
                if not self._relieve_pressure(seq):
                    raise RuntimeError(
                        "page pool exhausted for a lone sequence")
            last = seq.pos + num_tokens - 1
            for wp in range(seq.pos // ps, last // ps + 1):
                pid = seq.pages[wp]
                if sched.pool.ref(pid) > 1:
                    # copy-on-write: this step writes into a page other
                    # holders reference — copy it to a fresh page and
                    # repoint
                    new = self._alloc_one(seq)
                    if new is None:
                        raise RuntimeError(
                            "page pool exhausted for a lone sequence")
                    self.cache = self._copy_page(
                        self.cache, jnp.asarray(pid, jnp.int32),
                        jnp.asarray(new, jnp.int32))
                    sched.pool.free([pid])
                    seq.pages[wp] = new
                    sched.cow_copies += 1

    def step(self) -> bool:
        """Admit what fits, advance prefill chunks under the token
        budget, run one decode (or speculative verify) step over the
        decode-ready slots. Returns True if any work remains afterwards."""
        sched = self.scheduler
        self._admit()
        if not sched.active():
            if sched.queue and self._reclaim_swapped_refs():
                self._admit()  # pinned shared pages were the blocker
            if not sched.active():
                if sched.queue:
                    raise RuntimeError("scheduler stalled with queued work")
                return sched.has_work
        self._run_prefill_chunks()
        if not sched.decode_ready():
            # every active sequence is still streaming its prompt; the
            # chunk(s) above were this step's progress
            return sched.has_work
        if self.spec_enabled:
            self._spec_step()
            return sched.has_work
        self._ensure_pages()
        tokens, pos, page_rows, act = sched.assemble()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(page_rows), jnp.asarray(pos))
        toks = np.asarray(_sample(logits, self._next_key(),
                                  self.serve_cfg.temperature))
        self.steps += 1
        for seq in act:
            sched.advance(seq)
            sched.record_token(seq, int(toks[seq.slot]),
                               eos_id=self.serve_cfg.eos_id)
        return sched.has_work

    def _spec_step(self) -> None:
        """One speculative draft + batched verify + rollback step.

        Each active slot feeds its pending token plus K drafter
        proposals; one ``verify_step_paged`` call writes all K + 1
        tokens' K/V into the slot's (exclusively owned — see
        ``_ensure_pages``) pages and returns per-position logits under
        causal intra-chunk masking. Greedy acceptance keeps the longest
        draft prefix matching the model's own argmaxes plus one bonus
        token, so each sequence emits 1..K+1 tokens that are
        token-identical to non-speculative decode regardless of the
        drafter. Rejected drafts are rolled back page-exactly by simply
        not advancing ``seq.pos`` past the accepted point: their rows are
        dead by position masking and the next write there overwrites them
        (nothing zeroed, nothing copied, shared pages never touched).
        """
        sched = self.scheduler
        k = self.serve_cfg.num_draft_tokens
        self._ensure_pages(1 + k)
        tokens, pos, page_rows, act = sched.assemble(extra_tokens=k)
        for seq in act:
            history = np.concatenate(
                [seq.req.prompt,
                 np.asarray(seq.req.generated, np.int32)])
            drafts = np.asarray(self.drafter.propose(history, k), np.int32)
            if drafts.shape != (k,):
                raise ValueError(
                    f"drafter returned shape {drafts.shape}, wanted ({k},)")
            tokens[seq.slot, 1:] = drafts
        logits, self.cache = self._verify(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(page_rows), jnp.asarray(pos))
        # greedy targets at every position (temperature 0 is validated at
        # construction; _sample's argmax over the f32 cast, vectorized)
        targets = np.asarray(
            jnp.argmax(logits.astype(jnp.float32), axis=-1))
        self.steps += 1
        self.spec_steps += 1
        for seq in act:
            accepted, emitted = spec_decode.greedy_accept(
                tokens[seq.slot, 1:], targets[seq.slot])
            self.spec_seq_steps += 1
            self.drafted_tokens += k
            self.accepted_tokens += accepted
            for tok in emitted:
                # each emitted token validates one more written row
                # (advance) before it is recorded — the verify-time
                # mirror of the decode loop's advance/record pair; the
                # loop stopping early (EOS / max_new) is the rollback
                sched.advance(seq)
                self.emitted_tokens += 1
                if not sched.record_token(seq, int(tok),
                                          eos_id=self.serve_cfg.eos_id):
                    break

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Queue one request; returns its id. Use with :meth:`run`."""
        rid = self.scheduler.submit(prompt, max_new_tokens)
        self._submit_time[rid] = time.perf_counter()
        return rid

    def run(self) -> Dict[int, np.ndarray]:
        """Serve until drained. Returns {request_id: prompt + generated}."""
        while self.step():
            pass
        out = {}
        for req in self.scheduler.finished:
            out[req.id] = np.concatenate(
                [req.prompt, np.asarray(req.generated, np.int32)])
        self.scheduler.finished.clear()
        return out

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """Batch API, shape-compatible with ``FixedSlotEngine.generate``.

        Rows that hit EOS early are right-padded with ``eos_id``.
        """
        if key is not None:
            self._key = key
        prompts = np.asarray(prompts, np.int32)
        b, s0 = prompts.shape
        ids = [self.submit(prompts[i], max_new_tokens) for i in range(b)]
        results = self.run()
        pad = self.serve_cfg.eos_id if self.serve_cfg.eos_id is not None else 0
        out = np.full((b, s0 + max_new_tokens), pad, np.int32)
        for row, rid in enumerate(ids):
            toks = results[rid]
            out[row, : len(toks)] = toks
        return out

    def cache_stats(self) -> Dict[str, float]:
        """Allocation + peak-usage + prefix-sharing stats."""
        page_bytes = kv_cache.pool_page_nbytes(self.cache, self.num_pages)
        sched = self.scheduler
        stats = {
            "allocated_bytes": kv_cache.cache_nbytes(self.cache),
            "page_bytes": page_bytes,
            "state_bytes": kv_cache.state_nbytes(self.cache),
            "peak_pages": sched.peak_pages,
            "resident_tokens_at_peak": sched.resident_at_peak,
            "preemptions": sched.preemptions,
            "peak_paged_bytes": page_bytes * sched.peak_pages,
            "skipped_admissions": sched.skipped_admissions,
            "deferred_admissions": sched.deferred_admissions,
            "cow_copies": sched.cow_copies,
            "prompt_tokens": self.prompt_tokens,
            "prefill_tokens_computed": self.prefill_tokens,
            "prefix_hit_rate": (
                1.0 - self.prefill_tokens / self.prompt_tokens
                if self.prompt_tokens else 0.0),
            "prefill_chunks": self.prefill_chunks,
            # the monolithic fallback's live jitted-trace population
            # (LRU-bounded); the chunked path keeps exactly one trace
            "prefill_traces": (len(self._prefill_fns)
                               + len(self._prefill_tail_fns)),
        }
        if self.admission_latencies:
            lat = np.sort(np.asarray(self.admission_latencies))
            stats["admission_latency_p50"] = float(
                lat[int(0.50 * (len(lat) - 1))])
            stats["admission_latency_p95"] = float(
                lat[int(round(0.95 * (len(lat) - 1)))])
            stats["admission_latency_mean"] = float(lat.mean())
        if self.spec_enabled:
            stats.update({
                "spec_steps": self.spec_steps,
                "drafted_tokens": self.drafted_tokens,
                "accepted_tokens": self.accepted_tokens,
                "emitted_tokens": self.emitted_tokens,
                # the speculative payoff: tokens a sequence emits per
                # verify step it takes part in (1 = no better than plain
                # decode, K+1 = perfect drafts) — normalized per sequence
                # so continuous-batching parallelism doesn't inflate it
                "accepted_per_step": (
                    self.emitted_tokens / self.spec_seq_steps
                    if self.spec_seq_steps else 0.0),
                "draft_acceptance_rate": (
                    self.accepted_tokens / self.drafted_tokens
                    if self.drafted_tokens else 0.0),
            })
        if sched.prefix is not None:
            stats.update(sched.prefix.stats())
        return stats


# the default engine: continuous batching over the paged MX cache
ServeEngine = ContinuousBatchingEngine


def make_serve_step(cfg: ModelConfig):
    """The (cache, token, pos) -> (logits, cache) step used by the dry-run.

    This is what ``decode_*`` shapes lower: one new token against a KV cache
    of seq_len, global_batch requests in flight.
    """

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cfg, cache, tokens=tokens, pos=pos)

    return serve_step
