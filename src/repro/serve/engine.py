"""Serving engines: MX-compressed weights + (paged) MX KV cache.

Two engines share one numerics contract:

  * ``FixedSlotEngine`` — the original continuous-batching-lite loop: a
    fixed batch of slots, one shared position counter, ring-buffer caches
    sized batch x max_seq. Kept as the golden reference: its greedy
    outputs define correctness for the paged path.
  * ``ContinuousBatchingEngine`` (exported as ``ServeEngine``) — requests
    enter and leave mid-stream. Admission prefills one request into pages
    drawn from a global MX page pool (``kv_cache``), the jitted decode
    step runs at fixed shapes (max_slots rows, padding rows masked by
    dropped writes), and EOS/max_new recycles the slot and pages the same
    step (``scheduler``). Per-request greedy outputs are token-identical
    to the fixed-slot engine because every op on the path — projection,
    RoPE, cache quantize/dequantize, masked softmax — is batch-row
    independent and shared between the two paths.

Why this is the paper's serving payoff at production shape: the decode
step's HBM traffic is dominated by the KV cache; MX storage cuts it ~2x
(fp8+E8M0 vs bf16) and paging cuts the *allocated* footprint to what is
actually resident, so ragged, churning traffic stops paying for max_seq
rectangles. ``benchmarks/serve_throughput.py`` measures both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import model
from repro.nn.config import ModelConfig

from . import kv_cache
from .scheduler import Scheduler

_PAGED_MIXERS = {"attn", "rglru", "ssd"}


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0  # 0 => greedy
    eos_id: Optional[int] = None
    # continuous batching (ignored by FixedSlotEngine)
    max_slots: int = 8
    page_size: int = 16
    num_pages: Optional[int] = None  # default: max_slots * pages_per_slot


def _sample(logits, key, temperature: float):
    logits = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


class FixedSlotEngine:
    """Fixed batch of slots, one shared position (the golden reference)."""

    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, cfg, tokens=toks,
                                          max_seq=serve_cfg.max_seq))
        self._decode = jax.jit(
            lambda p, cache, tok, pos: model.decode_step(
                p, cfg, cache, tokens=tok, pos=pos))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        logits, cache = self._prefill(self.params, prompts)
        out = [prompts]
        tok = _sample(logits, key, self.serve_cfg.temperature)
        for i in range(max_new_tokens):
            out.append(tok[:, None])
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(s0 + i, jnp.int32)
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            tok = _sample(logits, sub, self.serve_cfg.temperature)
        return np.asarray(jnp.concatenate(out, axis=1))


class ContinuousBatchingEngine:
    """Continuous batching over a paged MX KV cache."""

    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        unsupported = {bd.mixer for bd in
                       (*cfg.prologue, *cfg.pattern, *cfg.epilogue)
                       } - _PAGED_MIXERS
        if unsupported:
            raise NotImplementedError(
                f"continuous batching does not support mixers {unsupported} "
                "— use FixedSlotEngine (launch/serve.py --engine fixed)")
        if cfg.num_codebooks > 1:
            raise NotImplementedError(
                "continuous batching with codebook heads is a follow-on")
        self.params = params
        self.cfg = cfg
        # full-length (non-ring) prefill caches: slot == absolute position,
        # so a prompt cache reshapes exactly into its pages
        self.cfg_prefill = cfg.replace(serve_full_cache=True)
        self.serve_cfg = serve_cfg
        ps = serve_cfg.page_size
        pages_per_slot = kv_cache.pages_for(serve_cfg.max_seq, ps)
        self.num_pages = (serve_cfg.num_pages
                          or serve_cfg.max_slots * pages_per_slot)
        self.scheduler = Scheduler(
            max_slots=serve_cfg.max_slots, num_pages=self.num_pages,
            page_size=ps, max_seq=serve_cfg.max_seq)
        self.cache = model.init_paged_cache(
            cfg, serve_cfg.max_slots, self.num_pages, ps)
        # donate the cache pytree: without donation every decode step /
        # install / restore copies the whole multi-layer page pool, which
        # would cancel the paged-cache footprint win. CPU has no donation
        # (it only warns), so gate on backend. _extract must NOT donate —
        # the cache lives on after a snapshot.
        cpu = jax.default_backend() == "cpu"
        self._decode = jax.jit(
            lambda p, c, tok, rows, pos: model.decode_step_paged(
                p, cfg, c, tok, rows, pos),
            donate_argnums=() if cpu else (1,))
        self._install = jax.jit(
            lambda c, pf, slot, ids: kv_cache.install_prefill(
                c, pf, slot, ids, ps),
            donate_argnums=() if cpu else (0, 1))
        self._extract = jax.jit(kv_cache.extract_seq)
        self._restore = jax.jit(kv_cache.restore_seq,
                                donate_argnums=() if cpu else (0, 1))
        self._prefill_fns = {}  # prompt length -> jitted prefill
        self._key = jax.random.PRNGKey(0)
        self.steps = 0

    # -- internals ----------------------------------------------------------

    def _prefill_for(self, length: int):
        """Jitted single-request prefill, cached per prompt length.

        max_seq rounds up to the page boundary so the cache T dim factors
        into whole pages. No padding of the tokens themselves: prefill
        numerics stay exactly those of the fixed-slot batch prefill.
        """
        fn = self._prefill_fns.get(length)
        if fn is None:
            ps = self.serve_cfg.page_size
            max_seq = kv_cache.pages_for(length, ps) * ps
            fn = jax.jit(lambda p, toks: model.prefill(
                p, self.cfg_prefill, tokens=toks, max_seq=max_seq))
            self._prefill_fns[length] = fn
        return fn

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _admit(self):
        while True:
            seq = self.scheduler.admit_next()
            if seq is None:
                return
            if seq.req.swap is not None:
                # swapped-out sequence: restore its exact cache bytes into
                # the fresh pages/slot; its pending token decodes next step
                snapshot, _, _ = seq.req.swap
                seq.req.swap = None
                self.cache = self._restore(
                    self.cache, snapshot, jnp.asarray(seq.slot, jnp.int32),
                    jnp.asarray(seq.pages, jnp.int32))
                continue
            prompt = seq.req.prompt
            logits, pfcache = self._prefill_for(len(prompt))(
                self.params, jnp.asarray(prompt, jnp.int32)[None])
            self.cache = self._install(
                self.cache, pfcache, jnp.asarray(seq.slot, jnp.int32),
                jnp.asarray(seq.pages, jnp.int32))
            tok = int(_sample(logits, self._next_key(),
                              self.serve_cfg.temperature)[0])
            self.scheduler.record_token(seq, tok,
                                        eos_id=self.serve_cfg.eos_id)

    def _ensure_pages(self):
        """Grow each active sequence's page list for this step's write,
        swapping out the youngest sequences when the pool runs dry."""
        sched = self.scheduler
        for seq in list(sched.active()):
            if sched.slots[seq.slot] is not seq:
                continue  # already preempted by an elder this pass
            while not sched.try_grow(seq):
                victim = sched.pick_victim(exclude=seq)
                if victim is None:
                    raise RuntimeError(
                        "page pool exhausted for a lone sequence")
                snapshot = self._extract(
                    self.cache, jnp.asarray(victim.slot, jnp.int32),
                    jnp.asarray(victim.pages, jnp.int32))
                sched.preempt(victim, snapshot)

    def step(self) -> bool:
        """Admit what fits, run one decode step. Returns True if any work
        remains afterwards."""
        sched = self.scheduler
        self._admit()
        if not sched.active():
            if sched.queue:
                raise RuntimeError("scheduler stalled with queued work")
            return sched.has_work
        self._ensure_pages()
        tokens, pos, page_rows, act = sched.assemble()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(page_rows), jnp.asarray(pos))
        toks = np.asarray(_sample(logits, self._next_key(),
                                  self.serve_cfg.temperature))
        self.steps += 1
        for seq in act:
            sched.advance(seq)
            sched.record_token(seq, int(toks[seq.slot]),
                               eos_id=self.serve_cfg.eos_id)
        return sched.has_work

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Queue one request; returns its id. Use with :meth:`run`."""
        return self.scheduler.submit(prompt, max_new_tokens)

    def run(self) -> Dict[int, np.ndarray]:
        """Serve until drained. Returns {request_id: prompt + generated}."""
        while self.step():
            pass
        out = {}
        for req in self.scheduler.finished:
            out[req.id] = np.concatenate(
                [req.prompt, np.asarray(req.generated, np.int32)])
        self.scheduler.finished.clear()
        return out

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """Batch API, shape-compatible with ``FixedSlotEngine.generate``.

        Rows that hit EOS early are right-padded with ``eos_id``.
        """
        if key is not None:
            self._key = key
        prompts = np.asarray(prompts, np.int32)
        b, s0 = prompts.shape
        ids = [self.submit(prompts[i], max_new_tokens) for i in range(b)]
        results = self.run()
        pad = self.serve_cfg.eos_id if self.serve_cfg.eos_id is not None else 0
        out = np.full((b, s0 + max_new_tokens), pad, np.int32)
        for row, rid in enumerate(ids):
            toks = results[rid]
            out[row, : len(toks)] = toks
        return out

    def cache_stats(self) -> Dict[str, float]:
        """Allocation + peak-usage stats for the benchmark."""
        page_bytes = kv_cache.pool_page_nbytes(self.cache, self.num_pages)
        sched = self.scheduler
        return {
            "allocated_bytes": kv_cache.cache_nbytes(self.cache),
            "page_bytes": page_bytes,
            "state_bytes": kv_cache.state_nbytes(self.cache),
            "peak_pages": sched.peak_pages,
            "resident_tokens_at_peak": sched.resident_at_peak,
            "preemptions": sched.preemptions,
            "peak_paged_bytes": page_bytes * sched.peak_pages,
        }


# the default engine: continuous batching over the paged MX cache
ServeEngine = ContinuousBatchingEngine


def make_serve_step(cfg: ModelConfig):
    """The (cache, token, pos) -> (logits, cache) step used by the dry-run.

    This is what ``decode_*`` shapes lower: one new token against a KV cache
    of seq_len, global_batch requests in flight.
    """

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cfg, cache, tokens=tokens, pos=pos)

    return serve_step
