"""Serving engine: MX-compressed weights, batched prefill + decode loop.

The inference-side payoff of the paper's technique: weights (and optionally
the KV cache) live in MX format — decode is bandwidth-bound, so compact
weights translate directly into step-time via the roofline memory term.

``ServeEngine`` keeps a fixed batch of slots (continuous-batching-lite):
``generate`` runs prefill once and a jitted decode loop; sampling is greedy
or temperature-based with a per-call PRNG key.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import model
from repro.nn.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0  # 0 => greedy
    eos_id: Optional[int] = None


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, cfg, tokens=toks,
                                          max_seq=serve_cfg.max_seq))
        self._decode = jax.jit(
            lambda p, cache, tok, pos: model.decode_step(
                p, cfg, cache, tokens=tok, pos=pos))

    def _sample(self, logits, key):
        logits = logits[:, -1].astype(jnp.float32)
        if self.serve_cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.serve_cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        logits, cache = self._prefill(self.params, prompts)
        out = [prompts]
        tok = self._sample(logits, key)
        for i in range(max_new_tokens):
            out.append(tok[:, None])
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(s0 + i, jnp.int32)
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            tok = self._sample(logits, sub)
        return np.asarray(jnp.concatenate(out, axis=1))


def make_serve_step(cfg: ModelConfig):
    """The (cache, token, pos) -> (logits, cache) step used by the dry-run.

    This is what ``decode_*`` shapes lower: one new token against a KV cache
    of seq_len, global_batch requests in flight.
    """

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cfg, cache, tokens=tokens, pos=pos)

    return serve_step
