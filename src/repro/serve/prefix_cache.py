"""Prefix cache: a token-radix tree of shared, ref-counted MX cache pages.

The paper's serving argument is that decode is HBM-bandwidth-bound on the
KV cache, so every byte of MX-compressed cache we avoid recomputing or
duplicating multiplies the win of MXFP8/MXFP4 storage. Pages are already
content-addressable units: the K/V rows a page holds are a pure function
of the token prefix up to the end of that page (causal attention), so two
requests whose prompts share a page-aligned head can share the *physical*
pages of that head.

Structure: a radix tree whose edges are full pages of prompt tokens. Each
node owns exactly one page — its key is the ``page_size``-token tuple of
that page's slice of the prompt, and its path from the root spells the
whole prefix. This is the classic block-level radix structure (vLLM-style
hash-block prefix caching; see also SGLang's RadixAttention), specialised
to whole pages so a hit plugs straight into the engine's page tables.

Ownership protocol (all accounting lives in :class:`~.kv_cache.PagePool`):

  * the tree holds **one** reference per node's page for as long as the
    node exists — a cached prefix stays resident after its sequences
    finish, which is the whole point;
  * :meth:`acquire` retains one reference per matched page on behalf of
    the requesting sequence; the scheduler releases it with the rest of
    the sequence's page table (``pool.free``) at EOS/preemption;
  * :meth:`evict` drops least-recently-used leaves whose page nobody else
    references (``pool.ref == 1``) — pinned prefixes are never evicted,
    so a page a live (or swapped-out) sequence maps is never recycled
    under it.

Exactness: a hit is only usable if attending over the cached pages gives
bit-identical results to recomputing them. The cache stores either bf16
K/V verbatim, or MX elements+scales whose dequantization is deterministic;
the prefill path attends over exactly that representation (see
``attention.cache_kv_view``), so tail prefill over cached pages reproduces
full prefill token-for-token.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .kv_cache import PagePool


class _Node:
    """One full page of cached prompt tokens."""

    __slots__ = ("key", "page", "children", "parent", "last_use", "partial")

    def __init__(self, key: Tuple[int, ...], page: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page  # physical page id (None only for the root)
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_use = 0
        # partial-page entries hanging off this prefix: tail-token tuple
        # (0 < len < page_size) -> [page_id, last_use]. The page's first
        # len(key) rows hold the tail's K/V; the rest is garbage, masked
        # by position on every read path. Opt-in (see insert(partial=)).
        self.partial: Dict[Tuple[int, ...], List[int]] = {}


class PrefixCache:
    """Radix tree of page-granular prompt prefixes over a shared pool."""

    def __init__(self, pool: PagePool, page_size: int):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.pool = pool
        self.page_size = page_size
        self._root = _Node((), None, None)
        self._clock = 0
        # stats (surfaced by engine.cache_stats / benchmarks)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.evictions = 0
        self.dedupes = 0  # insert repointed a hit-cap duplicate page
        self.partial_inserts = 0  # partial-tail entries registered

    # -- introspection -------------------------------------------------------

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._iter_nodes())

    @property
    def pages_held(self) -> List[int]:
        held = [n.page for n in self._iter_nodes()]
        for node in self._nodes_with_root():
            held.extend(ent[0] for ent in node.partial.values())
        return held

    def _nodes_with_root(self):
        yield self._root
        yield from self._iter_nodes()

    @property
    def num_partial_entries(self) -> int:
        return sum(len(n.partial) for n in self._nodes_with_root())

    def _chunks(self, prompt, n: int):
        ps = self.page_size
        for i in range(n):
            yield i, tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])

    # -- the three operations ------------------------------------------------

    def acquire(self, prompt: np.ndarray,
                full_only: bool = False) -> Tuple[List[int], int]:
        """Longest prefix hit for ``prompt`` (full pages + a partial tail).

        Returns (page_ids, cached_tokens); one pool reference per returned
        page is retained for the caller. The hit is capped at
        ``len(prompt) - 1`` tokens: at least one prompt token must be
        prefilled to produce the logits the first sampled token needs.

        After the full-page walk, the longest matching *partial* entry at
        the stopping node (see :meth:`insert`) extends the hit mid-page:
        the returned ``cached_tokens`` is then not a page multiple, and
        the caller owns the bugfix contract — it must COW the partial
        page before writing the remaining rows in place, and mask the
        page's garbage rows past ``cached_tokens`` on every attend.
        ``full_only=True`` skips partial entries (the chunked-prefill
        path, whose page-aligned chunk dispatches can't start mid-page).

        Stat-free: an admission attempt can fail after the lookup (no
        pages for the tail) and be retried every step, so the scheduler
        reports the hit via :meth:`record_lookup` only once the request
        is actually admitted.
        """
        cap = (len(prompt) - 1) // self.page_size
        node, pages = self._root, []
        for _, key in self._chunks(prompt, cap):
            child = node.children.get(key)
            if child is None:
                break
            self.pool.retain([child.page])
            self._clock += 1
            child.last_use = self._clock
            pages.append(child.page)
            node = child
        cached = len(pages) * self.page_size
        if not full_only and node.partial:
            budget = (len(prompt) - 1) - cached
            best = None
            for key in node.partial:
                if (len(key) <= budget and (best is None or
                                            len(key) > len(best)) and
                        key == tuple(int(t) for t in
                                     prompt[cached:cached + len(key)])):
                    best = key
            if best is not None:
                ent = node.partial[best]
                self.pool.retain([ent[0]])
                self._clock += 1
                ent[1] = self._clock
                pages.append(ent[0])
                cached += len(best)
        return pages, cached

    def record_lookup(self, cached_tokens: int) -> None:
        """Count one admitted request's lookup outcome in the stats."""
        self.lookups += 1
        if cached_tokens:
            self.hits += 1
            self.hit_tokens += cached_tokens

    def insert(self, prompt: np.ndarray, pages: List[int],
               partial: bool = False) -> int:
        """Register a freshly prefilled prompt's full pages in the tree.

        ``pages`` is the sequence's page table; entry ``i`` must hold the
        installed K/V of prompt tokens ``[i*ps, (i+1)*ps)``. Existing nodes
        are kept (first writer wins — the contents are identical by the
        exactness contract); each new node retains one pool reference that
        outlives the inserting sequence. Returns the node count added.

        Dedupe-on-insert: when an existing node covers chunk ``i`` but the
        sequence arrived with a *different* page there, the sequence holds
        a redundant private copy of bytes already resident. The reachable
        case is the :meth:`acquire` hit cap — a prompt of exactly N full
        pages can only match N - 1 (one token must be prefilled for the
        first logits), so a repeat admission of the same prompt prefills
        its last page into a fresh private page that duplicates the
        tree's. The table entry is repointed to the tree's page (the
        caller's live list is mutated in place — the engine's next
        assemble reads the shared id) and the duplicate is released,
        which both frees a page *now* and makes the sequence's last page
        preemption-shared (never extracted into swap snapshots). Safe by
        the exactness contract: both pages hold bit-identical K/V.

        ``partial=True`` additionally registers the prompt's non-aligned
        tail (``len(prompt) % page_size`` tokens) as a partial entry on
        the last full-page node, retaining one tree reference on the
        sequence's last page. The tree's reference makes the owner's next
        write into that page COW first (the engine's guard sees ref > 1),
        so the cached rows survive the owner's decode — the classic
        lost-partial-hit bug was freeing or overwriting those rows.
        First writer wins; an existing entry for the same tail is left
        alone (the caller keeps its private copy — repointing would just
        trade the duplicate for an immediate COW on its next decode).
        """
        node, created = self._root, 0
        n_full = len(prompt) // self.page_size
        for i, key in self._chunks(prompt, n_full):
            child = node.children.get(key)
            if child is None:
                self.pool.retain([pages[i]])
                child = _Node(key, pages[i], node)
                node.children[key] = child
                self._clock += 1
                child.last_use = self._clock
                created += 1
            elif pages[i] != child.page:
                # the hit-cap duplicate: swap the sequence's reference
                # from its private copy to the tree's identical page
                self.pool.retain([child.page])
                self.pool.free([pages[i]])
                pages[i] = child.page
                self.dedupes += 1
            node = child
        tail = tuple(int(t) for t in prompt[n_full * self.page_size:])
        if partial and tail and n_full < len(pages) and \
                tail not in node.partial:
            self.pool.retain([pages[n_full]])
            self._clock += 1
            node.partial[tail] = [pages[n_full], self._clock]
            self.partial_inserts += 1
        return created

    def release_partial(self, page_id: int) -> bool:
        """Drop the partial-tail entry holding ``page_id``, if any.

        The COW guard's pool-exhaustion fallback: when a writer needs
        exclusive ownership of a page whose only other holder is a
        partial entry and no page can be found for the copy, un-pinning
        the entry lets the writer proceed in place. Loses a future hit
        opportunity, never cached data another holder still reads.
        """
        for nd in self._nodes_with_root():
            for key, ent in nd.partial.items():
                if ent[0] == page_id:
                    del nd.partial[key]
                    self.pool.free([page_id])
                    self.evictions += 1
                    return True
        return False

    def evictable_count(self) -> int:
        """Pages evict() could free right now: nodes whose whole subtree
        is unpinned (a node can only fall after all its descendants).
        Partial entries count like leaves — each unpinned one is a page,
        and a node can only fall after its partials do."""

        def walk(node):
            total, all_ev = 0, True
            for child in node.children.values():
                c_total, c_ev = walk(child)
                total += c_total
                all_ev = all_ev and c_ev
            for page, _ in node.partial.values():
                if self.pool.ref(page) == 1:
                    total += 1
                else:
                    all_ev = False
            if node is self._root:
                return total, False
            ev = all_ev and self.pool.ref(node.page) == 1
            return total + (1 if ev else 0), ev

        return walk(self._root)[0]

    def evict(self, need: int) -> int:
        """Free up to ``need`` pages by dropping LRU unreferenced leaves.

        Only leaves whose page has no holder besides the tree itself
        (``pool.ref == 1``) are candidates; evicting a leaf can expose its
        parent as the next candidate (pushed into the same LRU heap, so
        global LRU order is preserved). One tree walk + O(log n) per
        eviction — this sits on the per-step allocation path.
        Returns the number of pages freed.
        """
        def candidate(nd):
            return (not nd.children and not nd.partial and
                    self.pool.ref(nd.page) == 1)

        tick = iter(range(1 << 30))  # heap tiebreak (nodes don't compare)
        heap = [(nd.last_use, next(tick), nd, None)
                for nd in self._iter_nodes() if candidate(nd)]
        # partial entries are leaves in their own right: evictable
        # whenever nobody but the tree holds their page
        for nd in self._nodes_with_root():
            for key, ent in nd.partial.items():
                if self.pool.ref(ent[0]) == 1:
                    heap.append((ent[1], next(tick), nd, key))
        heapq.heapify(heap)
        freed = 0
        while freed < need and heap:
            _, _, nd, key = heapq.heappop(heap)
            if key is not None:
                ent = nd.partial.pop(key)
                self.pool.free([ent[0]])
                self.evictions += 1
                freed += 1
                if nd is not self._root and candidate(nd):
                    heapq.heappush(heap, (nd.last_use, next(tick), nd, None))
                continue
            del nd.parent.children[nd.key]
            self.pool.free([nd.page])
            self.evictions += 1
            freed += 1
            parent = nd.parent
            if parent is not self._root and candidate(parent):
                heapq.heappush(heap, (parent.last_use, next(tick),
                                      parent, None))
        return freed

    # -- persistence ---------------------------------------------------------

    def export_state(self) -> Dict:
        """Portable structural snapshot of the tree (no page bytes).

        Nodes are emitted in BFS order with a parent index (-1 = root),
        so children always follow their parents and import can rebuild
        in one pass. Page ids are *physical* ids in this engine's pool —
        the engine pairs this with the pages' extracted bytes and remaps
        ids on import (``page_map``). ``last_use`` clocks ride along so
        LRU eviction order survives a restart.
        """
        nodes, partials = [], []
        index = {id(self._root): -1}
        bfs = list(self._root.children.values())
        while bfs:
            node = bfs.pop(0)
            index[id(node)] = len(nodes)
            nodes.append({"parent": index[id(node.parent)],
                          "key": list(node.key), "page": int(node.page),
                          "last_use": int(node.last_use)})
            bfs.extend(node.children.values())
        for nd in self._nodes_with_root():
            for tail, (page, last_use) in nd.partial.items():
                partials.append({"node": index[id(nd)],
                                 "tail": list(tail), "page": int(page),
                                 "last_use": int(last_use)})
        return {"page_size": self.page_size, "nodes": nodes,
                "partials": partials}

    def import_state(self, state: Dict, page_map: Dict[int, int]) -> int:
        """Rebuild the tree from :meth:`export_state` output.

        ``page_map`` maps exported physical page ids to the freshly
        allocated pages whose bytes the engine already restored. The
        caller hands over exactly one pool reference per page (the
        ``alloc`` reference) — that becomes the tree's reference, so the
        ownership protocol after import is identical to a tree grown by
        ``insert``. Must be called on an empty tree. Returns the node
        count (full-page nodes + partial entries) imported.
        """
        if self._root.children or self._root.partial:
            raise RuntimeError("import_state requires an empty prefix cache")
        if state["page_size"] != self.page_size:
            raise ValueError(
                f"snapshot page_size {state['page_size']} != "
                f"engine page_size {self.page_size}")
        by_index = {-1: self._root}
        for i, nd in enumerate(state["nodes"]):
            parent = by_index[nd["parent"]]
            key = tuple(int(t) for t in nd["key"])
            node = _Node(key, page_map[int(nd["page"])], parent)
            node.last_use = int(nd["last_use"])
            parent.children[key] = node
            by_index[i] = node
        count = len(state["nodes"])
        for ent in state["partials"]:
            node = by_index[int(ent["node"])]
            tail = tuple(int(t) for t in ent["tail"])
            node.partial[tail] = [page_map[int(ent["page"])],
                                  int(ent["last_use"])]
            self.partial_inserts += 1
            count += 1
        clocks = [nd["last_use"] for nd in state["nodes"]] + \
            [ent["last_use"] for ent in state["partials"]]
        self._clock = max([self._clock, *clocks]) if clocks else self._clock
        return count

    def stats(self) -> Dict[str, int]:
        return {
            "prefix_lookups": self.lookups,
            "prefix_hits": self.hits,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_evictions": self.evictions,
            "prefix_dedupes": self.dedupes,
            "prefix_nodes": self.num_nodes,
            "prefix_partial_entries": self.num_partial_entries,
            "prefix_partial_inserts": self.partial_inserts,
        }
