"""Serving: MX weights + paged MX KV cache, continuous batching."""
from .engine import (ContinuousBatchingEngine, FixedSlotEngine, ServeConfig,
                     ServeEngine, make_serve_step)
from .kv_cache import PagePool, pages_for
from .scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "FixedSlotEngine", "PagePool",
           "Request", "Scheduler", "ServeConfig", "ServeEngine",
           "make_serve_step", "pages_for"]
