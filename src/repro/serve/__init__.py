"""Serving: MX weights + paged MX KV cache, continuous batching,
radix-tree prefix caching over ref-counted copy-on-write pages."""
from .engine import (ContinuousBatchingEngine, FixedSlotEngine, ServeConfig,
                     ServeEngine, make_serve_step)
from .kv_cache import PagePool, pages_for
from .prefix_cache import PrefixCache
from .scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "FixedSlotEngine", "PagePool",
           "PrefixCache", "Request", "Scheduler", "ServeConfig",
           "ServeEngine", "make_serve_step", "pages_for"]
