"""Serving: MX weights + paged MX KV cache, continuous batching,
radix-tree prefix caching over ref-counted copy-on-write pages,
lossless speculative decoding with batched multi-token verify (greedy
prefix matching at temperature 0, rejection sampling above), stochastic
sampling with per-request counter-based RNG, SLO-aware overload control,
and an asyncio HTTP/SSE front end."""
from .engine import (ContinuousBatchingEngine, FixedSlotEngine, ServeConfig,
                     ServeEngine, TierPolicy, make_serve_step)
from .kv_cache import PagePool, pages_for, pages_spanned
from .overload import OverloadConfig, OverloadController, ShedError
from .prefix_cache import PrefixCache
from .sampling import SamplingParams
from .scheduler import Request, Scheduler
from .server import AsyncServeEngine, DrainingError, ServeHTTPServer
from .spec_decode import (Drafter, NgramDrafter, ScriptedDrafter,
                          greedy_accept)

__all__ = ["AsyncServeEngine", "ContinuousBatchingEngine", "Drafter",
           "DrainingError", "FixedSlotEngine", "NgramDrafter",
           "OverloadConfig", "OverloadController", "PagePool",
           "PrefixCache", "Request", "SamplingParams", "Scheduler",
           "ScriptedDrafter", "ServeConfig", "ServeEngine",
           "ServeHTTPServer", "ShedError", "TierPolicy", "greedy_accept",
           "make_serve_step", "pages_for", "pages_spanned"]
