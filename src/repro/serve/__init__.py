"""Serving: MX weights + paged MX KV cache, continuous batching,
radix-tree prefix caching over ref-counted copy-on-write pages, and
greedy speculative decoding with batched multi-token verify."""
from .engine import (ContinuousBatchingEngine, FixedSlotEngine, ServeConfig,
                     ServeEngine, TierPolicy, make_serve_step)
from .kv_cache import PagePool, pages_for, pages_spanned
from .prefix_cache import PrefixCache
from .scheduler import Request, Scheduler
from .spec_decode import (Drafter, NgramDrafter, ScriptedDrafter,
                          greedy_accept)

__all__ = ["ContinuousBatchingEngine", "Drafter", "FixedSlotEngine",
           "NgramDrafter", "PagePool", "PrefixCache", "Request",
           "Scheduler", "ScriptedDrafter", "ServeConfig", "ServeEngine",
           "TierPolicy", "greedy_accept", "make_serve_step", "pages_for",
           "pages_spanned"]
