"""Speculative decoding: draft proposals + greedy batched verification.

One-token-per-step decode leaves the fused MX flash-decode kernel badly
underfed: every step pays a full page-table walk, per-page DMA, and
in-register dequant to attend *one* query token. Speculative decoding
drafts K cheap candidate tokens per sequence and verifies all of them —
plus the pending sampled token — in a single batched pass
(``model.verify_step_paged``), so one walk over the compact MX pages
feeds K+1 tokens of attention. That is the serving analogue of the
paper's thesis that block-scaled compute only pays off when the
mixed-precision dataflow stays dense and regular: the OCP Microscaling
report and MXDOTP amortize scale handling across a dot-product block;
we amortize the page walk and dequant across a verify chunk.

Losslessness (greedy): the verify pass computes, for every fed token, the
model's greedy next token under *per-row causal masking* — row ``i``
attends exactly the keys a one-token decode at that position would. The
engine accepts the longest draft prefix that matches those greedy
targets and always emits one extra model token (the "bonus" token: the
model's own prediction at the first mismatch, or after the last accepted
draft). Emitted tokens are therefore **token-identical to non-speculative
greedy decode for any drafter** — a good drafter only changes how many
tokens each step emits (1 .. K+1), never which tokens.

Rollback is page-exact and free: rejected drafts' K/V rows were written
into pages the sequence exclusively owns (the engine COWs the whole
write window first), and rejection simply does not advance the
sequence's position past the accepted point. The stale rows are dead by
position masking and are overwritten by the next write at that position
— nothing is zeroed, copied, or reallocated, and shared prefix pages
are never perturbed.

Drafters are pluggable (``Drafter.propose``); the default needs no
second model:

  * :class:`NgramDrafter` — prompt-lookup decoding (Saxena-style n-gram
    matching): find the most recent earlier occurrence of the current
    tail n-gram in the sequence's own history and propose the tokens
    that followed it. Free, and strong exactly where speculation wins —
    repetitive spans (code, extraction, self-repeating generations).
  * :class:`ScriptedDrafter` — deterministic pseudo-random proposals from
    a seed; exists for tests: *any* drafts must leave the output token
    stream unchanged, so adversarially bad drafts are the best probe of
    the rollback machinery.

A draft-model drafter (a small LM proposing tokens) and non-greedy
acceptance (typical-acceptance / rejection sampling for temperature > 0)
are ROADMAP follow-ons; the interface already carries them.
"""
from __future__ import annotations

import numpy as np


class Drafter:
    """Interface: propose ``k`` draft tokens continuing ``history``."""

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        """history: (S,) int32 prompt + generated tokens so far (the last
        entry is the pending token the verify step feeds first). Returns
        (k,) int32 draft tokens. Must be deterministic per (history, k):
        the engine may be replayed against a reference run."""
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt-lookup drafting: continue the most recent n-gram match.

    Scans for the latest earlier occurrence of the history's tail
    ``n``-gram (longest ``n`` first, ``max_ngram`` down to
    ``min_ngram``) and proposes the ``k`` tokens that followed that
    occurrence; repetitive histories make these near-perfect drafts. No
    match (or a match at the very end with nothing following) falls back
    to repeating the last token — acceptance then just degrades, never
    correctness.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32)
        out = np.full((k,), h[-1], np.int32)  # fallback: repeat last
        for n in range(min(self.max_ngram, len(h) - 1), self.min_ngram - 1,
                       -1):
            # all candidate windows at once (one vectorized pass — this
            # runs on the host every verify step, so O(S) python loops
            # would grow drafting latency with generation length)
            wins = np.lib.stride_tricks.sliding_window_view(h[:-1], n)
            hits = np.nonzero((wins == h[-n:]).all(axis=1))[0]
            if len(hits):
                start = int(hits[-1])  # most recent earlier occurrence
                cont = h[start + n:start + n + k]
                out[:len(cont)] = cont
                if 0 < len(cont) < k:
                    out[len(cont):] = cont[-1]
                return out
        return out


class ScriptedDrafter(Drafter):
    """Deterministic pseudo-random drafts — the adversarial test drafter.

    Proposals depend only on (seed, history, k), so a run can be replayed
    exactly. Mostly-wrong drafts exercise the rollback path every step;
    occasional accidental hits (small ``vocab``) exercise partial
    acceptance.
    """

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = int(vocab)
        self.seed = int(seed)

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int64)
        mix = int((h.sum() * 2654435761 + len(h) * 97 + self.seed)
                  % (2 ** 31))
        rng = np.random.default_rng(mix)
        return rng.integers(0, self.vocab, size=(k,)).astype(np.int32)


def resolve_drafter(spec, vocab_size: int) -> Drafter:
    """ServeConfig.drafter -> Drafter instance ("ngram" | instance)."""
    if isinstance(spec, Drafter):
        return spec
    if spec == "ngram":
        return NgramDrafter()
    raise ValueError(f"unknown drafter {spec!r} (expected 'ngram' or a "
                     "Drafter instance)")


def greedy_accept(drafts: np.ndarray, targets: np.ndarray):
    """Longest accepted draft prefix + the tokens to emit.

    ``targets[j]`` is the model's greedy next token after fed token ``j``
    (j = 0 is the pending token, j >= 1 the drafts). Draft ``i`` is
    accepted iff every earlier draft was and ``drafts[i] == targets[i]``
    — i.e. the draft matches what greedy decode would have produced at
    that position. Returns ``(accepted, emitted)`` where ``emitted =
    targets[:accepted + 1]``: the accepted drafts *are* those targets,
    and the final entry is the bonus token the model predicts after them
    (so every verify step emits >= 1 token and the stream equals
    non-speculative greedy decode exactly).
    """
    drafts = np.asarray(drafts)
    targets = np.asarray(targets)
    k = len(drafts)
    a = 0
    while a < k and drafts[a] == targets[a]:
        a += 1
    return a, targets[:a + 1]
