"""Stochastic sampling: per-slot counter-based RNG, temperature/top-p/
top-k filtering, and lossless rejection-sampling speculative verification.

Everything here is jit-friendly and batch-row independent, which is the
whole design: the serving engine threads per-slot parameter vectors —
temperature, top-p, top-k, seed, counter — through its *already jitted*
decode/verify steps, so a batch mixing greedy and stochastic requests at
different temperatures still samples in the same single device dispatch
that computed its logits.

RNG contract (the property the determinism tests pin down): every sampled
token is a pure function of ``(seed, counter)`` where ``counter`` is the
token's index in its own request's generated stream. Keys are derived
counter-style — ``fold_in(fold_in(PRNGKey(seed), counter), salt)`` — never
split from a shared stream, so a request's tokens do not depend on which
slot it occupies, which neighbours share the batch, or how often it was
preempted and restored. Same seed in, same stream out, under any churn.

Filtering semantics (matching the common serving convention):

  * ``temperature`` scales logits (``<= 0`` means greedy argmax — exact,
    not a low-temperature limit);
  * ``top_k`` keeps the k highest logits (0 disables); ties are broken by
    stable sort order, so the kept set is deterministic;
  * ``top_p`` keeps the smallest set of top-k survivors whose cumulative
    probability reaches ``p`` (nucleus sampling), evaluated on the
    temperature-scaled, top-k-masked distribution.

The *filtered* distribution is the target distribution: speculative
verification below is lossless with respect to it, i.e. speculative
decoding at temperature > 0 emits tokens with exactly the probabilities
plain filtered sampling would (see :func:`verify_rejection`).

Speculative verification: the drafters in ``spec_decode`` are
deterministic proposal functions, so each draft is a point-mass proposal
q = delta(draft). Standard speculative rejection sampling (Leviathan et
al.; Chen et al.) accepts a draft x with probability
``min(1, p(x)/q(x))`` and on rejection resamples from the residual
``norm(max(p - q, 0))``. With a point-mass q this reduces to: accept x
with probability ``p(x)``; on rejection sample from ``p`` with x removed
and renormalized. Summing the two branches gives back exactly ``p`` —
the acceptance test and the residual correction cancel — which is the
losslessness guarantee, and at temperature 0 (one-hot p) it degenerates
to exact greedy prefix matching, bit-identical to the greedy-only
verification this module replaces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# fold_in salts separating the independent uses of one (seed, counter)
# position: the acceptance uniform and the residual/bonus resample must
# not reuse the same bits
_SALT_SAMPLE = 0x1
_SALT_ACCEPT = 0x2
_SALT_RESIDUAL = 0x3


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    ``temperature <= 0`` selects exact greedy decoding (top_p/top_k are
    then irrelevant). ``seed=None`` asks the engine to derive a
    per-request seed from its base seed and the request id — distinct
    requests then draw distinct streams; pass an explicit seed to make a
    request's stream reproducible across engines and restarts.
    """

    temperature: float = 0.0
    top_p: float = 1.0
    top_k: int = 0  # 0 = disabled
    seed: Optional[int] = None

    def validate(self) -> "SamplingParams":
        if not np.isfinite(self.temperature) or self.temperature < 0:
            raise ValueError(
                f"temperature must be finite and >= 0, got {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.seed is not None and not isinstance(
                self.seed, (int, np.integer)):
            raise ValueError(f"seed must be an int, got {type(self.seed)}")
        return self


def resolve_seed(params: SamplingParams, base_seed: int,
                 request_id: int) -> int:
    """The uint32 seed a request actually samples with.

    An explicit per-request seed is used verbatim (reproducible streams);
    otherwise one is derived from the engine's base seed and the request
    id with a Weyl/Knuth mix so concurrent requests draw independent
    streams by default.
    """
    if params.seed is not None:
        return int(params.seed) & 0xFFFFFFFF
    return (int(base_seed) * 0x9E3779B1 + int(request_id) * 0x85EBCA77
            + 0x165667B1) & 0xFFFFFFFF


def _base_keys(seeds, counters):
    """(N,) seeds x (N,) counters -> (N,) counter-derived PRNG keys."""
    def one(seed, ctr):
        return jax.random.fold_in(
            jax.random.PRNGKey(seed.astype(jnp.uint32)), ctr)
    return jax.vmap(one)(seeds, counters)


def filter_logits(logits, temps, top_ps, top_ks):
    """Temperature + top-k + top-p filtering, batch-row independent.

    logits (N, V) any float dtype; temps/top_ps (N,) f32, top_ks (N,)
    i32. Returns (N, V) f32 logits with everything outside the kept set
    at -inf. Greedy rows (temp <= 0) get temperature 1 applied — their
    filtered row is computed but callers must (and do) argmax the raw
    logits instead.
    """
    x = logits.astype(jnp.float32)
    safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
    x = x / safe_t
    # stable double-argsort ranks: rank 0 = largest logit; ties resolve
    # by index order, so the kept set is deterministic
    order = jnp.argsort(-x, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    keep_k = (top_ks[:, None] <= 0) | (ranks < top_ks[:, None])
    x = jnp.where(keep_k, x, -jnp.inf)
    # nucleus over the top-k survivors: keep while the *exclusive* prefix
    # mass is still below p (always keeps the top-1 token)
    probs = jax.nn.softmax(x, axis=-1)
    sorted_probs = jnp.take_along_axis(probs, order, axis=-1)
    excl = jnp.cumsum(sorted_probs, axis=-1) - sorted_probs
    keep_sorted = excl < top_ps[:, None]
    keep_p = jnp.take_along_axis(keep_sorted, ranks, axis=-1)
    return jnp.where(keep_k & keep_p, x, -jnp.inf)


def sample(logits, temps, top_ps, top_ks, seeds, counters):
    """One token per batch row, one dispatch, mixed greedy/stochastic.

    logits (N, V); per-row parameter vectors as in :func:`filter_logits`
    plus seeds (N,) uint32-ish and counters (N,) i32 (the row's token
    index within its own request stream). Greedy rows (temp <= 0) return
    the exact f32 argmax — bit-identical to the pre-sampling engine.
    """
    lf32 = logits.astype(jnp.float32)
    greedy = temps <= 0
    filtered = filter_logits(lf32, temps, top_ps, top_ks)
    keys = _base_keys(seeds, counters)
    sample_keys = jax.vmap(lambda k: jax.random.fold_in(k, _SALT_SAMPLE))(
        keys)
    drawn = jax.vmap(jax.random.categorical)(sample_keys, filtered)
    argmaxes = jnp.argmax(lf32, axis=-1)
    return jnp.where(greedy, argmaxes, drawn).astype(jnp.int32)


def _remove_and_renorm(probs, token, remove):
    """Residual distribution: zero ``token``'s mass (when ``remove``) and
    renormalize; degenerate rows fall back to their argmax one-hot."""
    v = probs.shape[-1]
    hot = jax.nn.one_hot(token, v, dtype=probs.dtype)
    resid = jnp.where(remove[:, None], probs * (1.0 - hot), probs)
    total = resid.sum(axis=-1, keepdims=True)
    # p(draft) ~ 1.0 yet rejected by float roundoff: residual mass ~ 0;
    # fall back to the row argmax of the unmodified distribution
    fallback = jax.nn.one_hot(jnp.argmax(probs, axis=-1), v,
                              dtype=probs.dtype)
    return jnp.where(total > 0, resid / jnp.maximum(total, 1e-38), fallback)


def verify_rejection(logits, drafts, temps, top_ps, top_ks, seeds,
                     counters):
    """Speculative acceptance for one batched verify step, in-dispatch.

    logits (N, K+1, V) — position j's logits are the model's next-token
    distribution after feeding token j (j = 0 is the pending sampled
    token, j >= 1 the drafts). drafts (N, K). Per-row sampling parameter
    vectors as in :func:`sample`; ``counters`` is each row's stream index
    of the *first* token this step may emit.

    Returns ``(num_emitted (N,), emitted (N, K+1))`` int32: row n emits
    ``emitted[n, :num_emitted[n]]`` (1 <= num_emitted <= K+1; entries past
    the count are garbage).

    Greedy rows (temp <= 0) use exact argmax prefix matching — identical
    to ``spec_decode.greedy_accept`` and therefore to plain greedy
    decode. Stochastic rows run point-mass rejection sampling against
    the filtered target distribution p̃ at each position: accept draft
    ``x_j`` with probability ``p̃_j(x_j)`` (uniform drawn from the
    (seed, counter + j) key); at the first rejection, emit a sample from
    p̃_j with ``x_j`` removed and renormalized; if all K drafts are
    accepted, emit a bonus sample from p̃_K. Each emitted position
    consumes the (seed, counter + j) key exactly once per salt, so the
    emitted stream is deterministic per (seed, counter) like plain
    sampling — and marginally, every emitted token is distributed
    exactly as plain filtered sampling at that stream position
    (losslessness; see the module docstring for the algebra).
    """
    n, t, v = logits.shape
    k = t - 1
    lf32 = logits.astype(jnp.float32)
    targets = jnp.argmax(lf32, axis=-1)  # (N, T) greedy targets
    greedy = temps <= 0

    rep = lambda a: jnp.repeat(a, t)
    filtered = filter_logits(
        lf32.reshape(n * t, v), rep(temps), rep(top_ps),
        rep(top_ks)).reshape(n, t, v)
    probs = jax.nn.softmax(filtered, axis=-1)

    base = _base_keys(seeds, counters)  # (N,) keys at stream position 0

    # acceptance uniforms: u[n, j] from (seed_n, counter_n + j, ACCEPT)
    def accept_u(key, j):
        return jax.random.uniform(
            jax.random.fold_in(jax.random.fold_in(key, j), _SALT_ACCEPT))
    u = jax.vmap(lambda key: jax.vmap(lambda j: accept_u(key, j))(
        jnp.arange(k)))(base)  # (N, K)

    p_draft = jnp.take_along_axis(
        probs[:, :k], drafts[..., None], axis=-1)[..., 0]  # (N, K)
    accept_sto = u < p_draft
    accept_grd = drafts == targets[:, :k]
    accept = jnp.where(greedy[:, None], accept_grd, accept_sto)
    acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1),
                  axis=1)  # (N,) accepted prefix length in [0, K]

    # the final emitted token: residual sample at the first rejection,
    # bonus sample after K acceptances (no removal), argmax when greedy
    probs_a = jnp.take_along_axis(
        probs, acc[:, None, None].astype(jnp.int32), axis=1)[:, 0]  # (N, V)
    draft_a = jnp.take_along_axis(
        jnp.concatenate([drafts, jnp.zeros((n, 1), drafts.dtype)], axis=1),
        acc[:, None].astype(jnp.int32), axis=1)[:, 0]
    resid = _remove_and_renorm(probs_a, draft_a, acc < k)
    last_keys = jax.vmap(
        lambda key, j: jax.random.fold_in(jax.random.fold_in(key, j),
                                          _SALT_RESIDUAL))(base, acc)
    drawn = jax.vmap(jax.random.categorical)(
        last_keys, jnp.log(jnp.maximum(resid, 1e-38))
        + jnp.where(resid > 0, 0.0, -jnp.inf))
    target_a = jnp.take_along_axis(
        targets, acc[:, None].astype(jnp.int32), axis=1)[:, 0]
    final = jnp.where(greedy, target_a, drawn).astype(jnp.int32)

    cols = jnp.arange(t)[None, :]
    padded = jnp.concatenate(
        [drafts.astype(jnp.int32), jnp.zeros((n, 1), jnp.int32)], axis=1)
    # greedy rows emit the targets themselves (== drafts on the accepted
    # prefix, by construction); stochastic rows emit the accepted drafts
    emitted = jnp.where(cols < acc[:, None],
                        jnp.where(greedy[:, None], targets[:, :t].astype(
                            jnp.int32), padded),
                        0)
    emitted = emitted.at[jnp.arange(n), acc].set(final)
    return (acc + 1).astype(jnp.int32), emitted


def slot_arrays(max_slots: int):
    """Neutral per-slot parameter arrays (greedy, seed 0, counter 0).

    The engine fills in active slots' values and leaves padding rows
    greedy — their argmax output is computed and discarded, exactly like
    padding rows' logits.
    """
    return {
        "temps": np.zeros((max_slots,), np.float32),
        "top_ps": np.ones((max_slots,), np.float32),
        "top_ks": np.zeros((max_slots,), np.int32),
        "seeds": np.zeros((max_slots,), np.uint32),
        "counters": np.zeros((max_slots,), np.int32),
    }
