"""Continuous-batching scheduler: admission, prefix sharing, preemption.

Host-side control plane of the serving engine. The device sees only fixed
shapes — (max_slots, 1) token batches and a (max_slots, pages_per_slot)
page table — while requests enter and leave mid-stream:

  * **admission** — FCFS with a bounded skip-ahead window: the queue head
    is admitted as soon as a slot is free and its pages fit; when the head
    does *not* fit, up to ``admit_window - 1`` younger requests are
    scanned for one that does (head-of-line order is preserved otherwise,
    so the window bounds how far fairness can bend).
  * **prefix sharing** — with a :class:`~.prefix_cache.PrefixCache`
    attached, admission first takes the longest page-aligned prefix hit:
    matched pages are retained (ref-counted) into the request's page
    table and only the uncached tail is prefilled. Fresh full prompt
    pages are inserted back into the radix tree after install.
  * **chunked prefill** — with ``prefill_chunk`` set, admission only binds
    the slot and pages (the prompt's worth, exactly as monolithic) and
    marks the sequence ``prefill_pos = cached_tokens``; the engine then
    streams the prompt through fixed-size page-aligned chunks under a
    per-step token budget, interleaved with decode steps (Sarathi-style),
    so resident decoders never stall behind a long prompt and admission
    latency is O(chunk). ``assemble`` skips prefilling sequences — they
    have no pending token until the final chunk's logits are sampled.
    Preempting a mid-prefill sequence is legal: the swap tuple carries
    ``prefill_pos`` and re-admission resumes chunking where it stopped.
    Because admission is decoupled from prefill, a request sharing an
    unregistered page-aligned head with a still-prefilling sequence is
    *deferred* (``deferred_admissions``) until those pages register in
    the prefix tree — otherwise a shared-prefix burst would race past
    the tree and prefill private copies of the same pages.
  * **decode paging** — each step, a slot crossing a page boundary pulls a
    fresh page from the pool. A dry pool first evicts LRU unreferenced
    prefix-tree leaves; if still dry, the *youngest* other active request
    is preempted: the engine snapshots the exact bytes of the pages it
    exclusively owns (shared prefix pages are released by reference and
    never extracted — other holders keep them resident), its references
    are dropped, and it is requeued at the front; re-admission restores
    the snapshot verbatim into fresh pages and re-links the shared ones
    (swap-style preemption). Recompute-style preemption would NOT be
    token-identical here: a re-prefill of *generated* tokens would attend
    over unquantized K/V where the original decode attended over the MX
    cache.
  * **speculative verify windows** — with speculative decoding enabled
    the engine writes 1 + K tokens per step, so ``try_grow`` covers the
    whole window (possibly several fresh pages at once) and ``submit``
    rejects requests whose worst-case window would overflow the page
    table near max_seq (a silent clamp would drop speculated K/V writes
    mid-verify). Rollback of rejected drafts is position truncation
    only — ``advance`` is simply called once per *accepted* token.
  * **recycling** — EOS or max_new_tokens frees the slot and drops the
    sequence's page references in O(1); pages the prefix tree still
    references stay resident as cache, everything else returns to the
    free list, and the next queued request can be admitted the same step.

The scheduler never touches device memory: it hands the engine (slot,
request, page_ids) admission tuples and assembles per-step numpy batches.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional

import numpy as np

from .kv_cache import PagePool, pages_for, pages_spanned
from .prefix_cache import PrefixCache


def _common_pages(a: np.ndarray, b: np.ndarray, page_size: int) -> int:
    """Whole pages of identical leading tokens between two prompts."""
    n = min(len(a), len(b))
    diff = np.flatnonzero(a[:n] != b[:n])
    common = int(diff[0]) if len(diff) else n
    return common // page_size


@dataclasses.dataclass
class Request:
    """One generation request. ``generated`` and ``swap`` survive preemption."""

    id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    # per-request sampling: a serve.sampling.SamplingParams (None = the
    # engine's defaults) plus the resolved uint32 RNG seed — carried on
    # the request so its token stream survives preemption and swap
    # (sampling keys are (seed, token index), never slot or step)
    sampling: Optional[object] = None
    seed: int = 0
    # cancel(): the request was abandoned by its client; its pages and
    # slot are already released and it will never reach ``finished``
    cancelled: bool = False
    # preemption snapshot: (cache_snapshot, owned_idx, pages, resident
    # tokens, cached_tokens, prefill_pos). ``owned_idx`` are the
    # page-table positions that were exclusively owned (extracted +
    # freed); the remaining entries of ``pages`` stayed retained (shared)
    # across the swap. ``prefill_pos`` is the chunked-prefill resume
    # point (None once prefill completed). Restored verbatim on
    # re-admission so generation stays bit-identical.
    swap: Optional[tuple] = None
    # chunked admission deferred this request at least once (the stat
    # counts requests, not retries — admit_next re-tries every step)
    deferred: bool = False
    # how many admission attempts deferral has already cost this request;
    # bounded by Scheduler.max_deferrals so a preempted / stalled leader
    # can't starve it forever (it then prefills independently)
    defer_count: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    @property
    def done(self) -> bool:
        return self.remaining <= 0


@dataclasses.dataclass
class ActiveSeq:
    """A request bound to a decode slot."""

    req: Request
    slot: int
    pos: int  # next cache write position == tokens currently resident
    pages: List[int]
    order: int  # admission sequence number (preemption picks the youngest)
    cached_tokens: int = 0  # page-aligned prefix-cache hit at admission
    # chunked prefill: next chunk's start row (a multiple of the chunk
    # length past ``cached_tokens``); None once the prompt is fully
    # resident and the sequence decodes. While set, the sequence owns a
    # slot but is skipped by assemble() — it has no pending token yet.
    prefill_pos: Optional[int] = None


class Scheduler:
    def __init__(self, *, max_slots: int, num_pages: int, page_size: int,
                 max_seq: int, prefix_cache: bool = False,
                 admit_window: int = 4, num_draft_tokens: int = 0,
                 prefill_chunk: int = 0, max_deferrals: int = 8,
                 prefill_max_chunks: int = 1,
                 unit_budget: Optional[int] = None,
                 track_allocs: bool = False):
        self.max_slots = max_slots
        self.page_size = page_size
        self.max_seq = max_seq
        # chunked prefill (0 = monolithic): admission only binds the slot
        # and pages; the engine streams the prompt through fixed-size
        # chunks (page-aligned, so every chunk page is wholly owned by
        # one chunk) interleaved with decode steps
        if prefill_chunk and prefill_chunk % page_size != 0:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must be a multiple of "
                f"page_size={page_size}: chunk starts must stay "
                "page-aligned so no page blends two chunks")
        self.prefill_chunk = prefill_chunk
        # ragged-aware prefill budgeting: when decode rows undersubscribe
        # the batch (fewer active sequences than slots), a prefilling
        # sequence may take up to this many chunks in one step. Admission
        # bound all of the prompt's pages already, so a bigger bite needs
        # no allocation — only wider (still static) step rows.
        if prefill_max_chunks < 1:
            raise ValueError("prefill_max_chunks must be >= 1")
        self.prefill_max_chunks = prefill_max_chunks
        self.pages_per_slot = pages_for(max_seq, page_size)
        if num_pages < self.pages_per_slot:
            raise ValueError(
                f"num_pages={num_pages} cannot hold one max_seq={max_seq} "
                f"sequence (needs {self.pages_per_slot})")
        if admit_window < 1:
            raise ValueError("admit_window must be >= 1")
        if num_draft_tokens < 0:
            raise ValueError("num_draft_tokens must be >= 0")
        self.admit_window = admit_window
        # speculative decoding: every verify step writes 1 + K tokens, so
        # admission must guarantee the whole worst-case window fits inside
        # max_seq's page table (see submit)
        self.num_draft_tokens = num_draft_tokens
        if max_deferrals < 0:
            raise ValueError("max_deferrals must be >= 0")
        self.max_deferrals = max_deferrals
        self.pool = PagePool(num_pages, unit_budget=unit_budget,
                             track_allocs=track_allocs)
        self.prefix = (PrefixCache(self.pool, page_size)
                       if prefix_cache else None)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[ActiveSeq]] = [None] * max_slots
        self.finished: List[Request] = []
        self._order = 0
        self._next_id = 0
        # stats sampled at the peak-pages step (benchmark bytes/token)
        self.peak_pages = 0
        self.resident_at_peak = 0
        self.preemptions = 0
        self.skipped_admissions = 0
        self.cow_copies = 0
        self.deferred_admissions = 0  # chunked: waited for a prefix match
        self.deferral_fallbacks = 0  # deferral bound hit: went independent
        self.cancellations = 0
        # streaming hook: called as on_token(request, token, finished)
        # after every recorded token — the async server's per-token
        # delivery path (None = no streaming consumer)
        self.on_token: Optional[Callable] = None

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               sampling=None, seed: int = 0) -> int:
        """Queue one request. Invalid inputs fail here, with a clear
        ValueError, not steps later inside a jitted prefill."""
        prompt = np.asarray(prompt)
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"prompt must be integer token ids, got dtype {prompt.dtype}")
        prompt = prompt.astype(np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if not isinstance(max_new_tokens, (int, np.integer)):
            raise ValueError(
                f"max_new_tokens must be an int, got {type(max_new_tokens).__name__}")
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new_tokens}) "
                f"exceeds max_seq={self.max_seq}")
        if (self.num_draft_tokens
                and len(prompt) + max_new_tokens + self.num_draft_tokens
                > self.max_seq):
            # a silent clamp here would let a verify step write speculated
            # K/V past the last page of the table mid-stream — reject at
            # submission with the actual numbers instead
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new_tokens}) + "
                f"speculative draft window ({self.num_draft_tokens}) "
                f"exceeds max_seq={self.max_seq}: a verify step near the "
                f"end of this request would overflow its page table "
                f"(shrink num_draft_tokens or raise max_seq)")
        req = Request(self._next_id, prompt, int(max_new_tokens),
                      sampling=sampling, seed=int(seed))
        self._next_id += 1
        self.queue.append(req)
        return req.id

    def cancel(self, request_id: int) -> bool:
        """Abandon a request wherever it currently lives; True if found.

        Active (decoding, mid-prefill, or mid-verify — cancel runs on the
        host between steps, so a verify window is never half-landed):
        drop every page reference in one ``pool.free`` — prefix-cache
        retains and exclusively-owned pages alike; pages the radix tree
        still references stay resident as cache, the rest return to the
        free list — and release the slot the same step. Queued fresh:
        just dequeue (no resources bound yet). Queued swapped-out: the
        preemption already freed the exclusively-owned pages; free the
        *shared* references the swap tuple still pins and drop the
        snapshot. Finished/unknown ids return False (cancel raced
        completion — the tokens already streamed, nothing to release).
        """
        for seq in self.active():
            if seq.req.id == request_id:
                self.pool.free(seq.pages)
                self.slots[seq.slot] = None
                seq.req.cancelled = True
                self.cancellations += 1
                return True
        for qi, req in enumerate(self.queue):
            if req.id != request_id:
                continue
            if req.swap is not None:
                _snapshot, owned_idx, pages, *_ = req.swap
                owned = set(owned_idx)
                shared = [p for i, p in enumerate(pages) if i not in owned]
                if shared:
                    self.pool.free(shared)
                req.swap = None
            del self.queue[qi]
            req.cancelled = True
            self.cancellations += 1
            return True
        return False

    # -- admission / eviction ----------------------------------------------

    def active(self) -> List[ActiveSeq]:
        return [s for s in self.slots if s is not None]

    def prefilling(self) -> List[ActiveSeq]:
        """Active sequences still streaming prompt chunks, oldest first."""
        return sorted((s for s in self.active()
                       if s.prefill_pos is not None),
                      key=lambda s: s.order)

    def decode_ready(self) -> List[ActiveSeq]:
        """Active sequences with a pending token (prefill complete)."""
        return [s for s in self.active() if s.prefill_pos is None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def _alloc_with_evict(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages, evicting prefix-tree leaves if needed.

        Eviction only runs when it can actually cover the shortfall —
        a doomed allocation must not destroy cached prefixes for nothing
        (the caller will retry every step while the request waits).
        """
        if not self.pool.can_alloc(n) and self.prefix is not None:
            shortfall = n - self.pool.free_pages
            if self.prefix.evictable_count() >= shortfall:
                self.prefix.evict(shortfall)
        return self.pool.alloc(n)

    def _try_admit(self, req: Request, slot: int) -> Optional[ActiveSeq]:
        """Bind ``req`` to ``slot`` if its pages fit; None leaves no trace."""
        if req.swap is not None:
            snapshot, owned_idx, pages, pos0, cached, prefill_pos = req.swap
            ids = self._alloc_with_evict(len(owned_idx))
            if ids is None:
                return None
            pages = list(pages)
            for i, pid in zip(owned_idx, ids):
                pages[i] = pid
        else:
            # only fresh requests are prefilled; preempted ones re-enter
            # exclusively via their cache snapshot above (a re-prefill of
            # prompt+generated would not be token-identical: prefill
            # attends over unquantized K/V)
            assert not req.generated, "mid-stream request without snapshot"
            hit, cached = ([], 0)
            if self.prefix is not None:
                # chunked prefill streams page-aligned chunks, so it can
                # only consume page-aligned hits; monolithic admission
                # also takes a partial last-page hit (the engine COWs the
                # partial page and installs the tail rows in place)
                hit, cached = self.prefix.acquire(
                    req.prompt, full_only=bool(self.prefill_chunk))
            if (self.prefill_chunk and self.prefix is not None
                    and req.defer_count < self.max_deferrals):
                # chunked admission is decoupled from prefill, so a burst
                # of shared-prefix prompts could race past the radix tree
                # (monolithic admission registered each prompt's pages
                # before the next request's lookup, making the race
                # impossible). Defer a request whose prompt shares an
                # unregistered page-aligned head with a sequence still
                # streaming chunks: once that sequence registers, this
                # request re-admits with a real tree hit and shares the
                # pages instead of prefilling a private copy. Deferral is
                # bounded (max_deferrals attempts): a leader that stalls —
                # preempted mid-prefill, starved of chunk budget — must
                # not starve this request forever, so past the bound it
                # falls through and prefills independently (correct, just
                # without sharing; dedupe-on-insert may still reconcile
                # the duplicate pages later).
                cap = (len(req.prompt) - 1) // self.page_size
                for s in self.prefilling():
                    shared = min(
                        _common_pages(req.prompt, s.req.prompt,
                                      self.page_size), cap)
                    if shared * self.page_size > cached:
                        if hit:
                            self.pool.free(hit)
                        if not req.deferred:
                            req.deferred = True
                            self.deferred_admissions += 1
                        req.defer_count += 1
                        if req.defer_count == self.max_deferrals:
                            self.deferral_fallbacks += 1
                        return None
            prompt_len = len(req.prompt)
            ids = self._alloc_with_evict(pages_for(prompt_len, self.page_size)
                                         - len(hit))
            if ids is None:
                if hit:
                    self.pool.free(hit)  # drop the lookup's references
                return None
            pages = hit + ids
            if self.prefix is not None:
                self.prefix.record_lookup(cached)
            if self.prefill_chunk:
                # chunked: only the prefix hit is resident so far; the
                # engine streams the tail through fixed chunks, advancing
                # ``pos``/``prefill_pos`` as each chunk's rows land
                pos0, prefill_pos = cached, cached
            else:
                pos0, prefill_pos = prompt_len, None
        seq = ActiveSeq(req=req, slot=slot, pos=pos0, pages=pages,
                        order=self._order, cached_tokens=cached,
                        prefill_pos=prefill_pos)
        self._order += 1
        self.slots[slot] = seq
        return seq

    def admit_next(self) -> Optional[ActiveSeq]:
        """Admit the queue head, or — when it doesn't fit — the first of
        up to ``admit_window - 1`` younger requests that does (bounded
        skip-ahead; strict FCFS otherwise)."""
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        if not free_slots or not self.queue:
            return None
        for qi in range(min(self.admit_window, len(self.queue))):
            seq = self._try_admit(self.queue[qi], free_slots[0])
            if seq is not None:
                del self.queue[qi]
                if qi:
                    self.skipped_admissions += 1
                return seq
        return None

    def register_prefix(self, seq: ActiveSeq) -> None:
        """Insert ``seq``'s freshly installed full prompt pages into the
        radix tree (no-op without a prefix cache). Engine calls this after
        the device install, so a later hit always reads real bytes.
        Monolithic prefill also registers the prompt's partial last page
        (chunked can't serve partial hits, so it doesn't pin them)."""
        if self.prefix is not None:
            self.prefix.insert(seq.req.prompt, seq.pages,
                               partial=not self.prefill_chunk)

    def try_grow(self, seq: ActiveSeq, num_tokens: int = 1) -> bool:
        """Grow ``seq``'s page table to cover this step's write window.

        ``num_tokens`` is how many cache rows the step writes starting at
        ``seq.pos`` — 1 for plain decode, 1 + K for a speculative verify
        chunk (which may straddle a page boundary and need several fresh
        pages at once). All-or-nothing: a partial grow would leave the
        window half-backed and the verify write would drop rows silently.
        """
        need = pages_spanned(seq.pos, num_tokens, self.page_size) \
            - len(seq.pages)
        if need <= 0:
            return True
        ids = self._alloc_with_evict(need)
        if ids is None:
            return False
        seq.pages.extend(ids)
        return True

    def pick_victim(self, exclude: ActiveSeq) -> Optional[ActiveSeq]:
        """Youngest other active sequence (FCFS: elders keep their slots)."""
        victims = [s for s in self.active() if s is not exclude]
        return max(victims, key=lambda s: s.order) if victims else None

    def exclusive_pages(self, seq: ActiveSeq):
        """(table indices, page ids) of pages only ``seq`` references —
        the ones a preemption snapshot must extract. Shared pages (prefix
        tree / other sequences) stay resident and are never extracted."""
        idx = [i for i, p in enumerate(seq.pages) if self.pool.ref(p) == 1]
        return idx, [seq.pages[i] for i in idx]

    def preempt(self, victim: ActiveSeq, snapshot,
                owned_idx: Optional[List[int]] = None) -> None:
        """Swap out ``victim``: free its exclusive pages, requeue at front.

        The engine passes the device-side snapshot of the victim's
        exclusively owned pages + state row (``kv_cache.extract_seq``) and
        their table indices; shared pages keep the victim's reference
        across the swap (they cannot be evicted under it). Re-admission
        restores the snapshot verbatim, so preemption never perturbs the
        token stream.
        """
        if owned_idx is None:
            owned_idx = list(range(len(victim.pages)))
        self.pool.free([victim.pages[i] for i in owned_idx])
        self.slots[victim.slot] = None
        victim.req.swap = (snapshot, owned_idx, list(victim.pages),
                           victim.pos, victim.cached_tokens,
                           victim.prefill_pos)
        self.queue.appendleft(victim.req)
        self.preemptions += 1

    def advance(self, seq: ActiveSeq) -> None:
        """The decode step wrote ``seq``'s pending token at ``seq.pos``."""
        seq.pos += 1

    def record_token(self, seq: ActiveSeq, token: int, eos_id=None) -> bool:
        """Append a sampled token; finish + recycle on EOS/max_new.

        ``seq.pos`` is untouched: the token's KV lands in the cache only
        when the next decode step feeds it (see :meth:`advance`). Returns
        True if the sequence is still active.
        """
        seq.req.generated.append(int(token))
        finished = seq.req.done or (eos_id is not None
                                    and int(token) == eos_id)
        if finished:
            self.pool.free(seq.pages)
            self.slots[seq.slot] = None
            self.finished.append(seq.req)
        if self.on_token is not None:
            self.on_token(seq.req, int(token), finished)
        return not finished

    # -- per-step batch assembly -------------------------------------------

    def assemble(self, extra_tokens: int = 0):
        """Fixed-shape numpy batch for the jitted decode/verify step.

        Returns (tokens (NS, 1 + extra_tokens), pos (NS,), page_rows
        (NS, P), active) — inactive rows are token 0 / pos 0 / pages -1
        (their device writes are dropped and their logits ignored).
        Sequences still in chunked prefill are treated as inactive: they
        hold a slot but have no pending token until their final chunk's
        logits are sampled. Column 0 is each slot's pending token; the
        engine fills columns 1.. with its drafter's proposals
        (speculative verify). The shape is static per ``extra_tokens``,
        so the verify step jits once.
        """
        ns, pps = self.max_slots, self.pages_per_slot
        tokens = np.zeros((ns, 1 + extra_tokens), np.int32)
        pos = np.zeros((ns,), np.int32)
        page_rows = np.full((ns, pps), -1, np.int32)
        act = self.decode_ready()
        for seq in act:
            # every activation path records a pending token before the
            # first assemble (admission samples from prefill logits;
            # swapped requests carry theirs in ``generated``)
            assert seq.req.generated, "active sequence with no pending token"
            tokens[seq.slot, 0] = seq.req.generated[-1]
            pos[seq.slot] = seq.pos
            page_rows[seq.slot, : len(seq.pages)] = seq.pages
        # resident rows: decode-ready sequences are about to write their
        # pending token (+1); prefilling ones count what chunks landed
        resident = int(sum(s.pos + (1 if s.prefill_pos is None else 0)
                           for s in self.active()))
        # both stats sampled at the same step: a strict new peak resets the
        # resident count; ties keep the smaller resident (conservative —
        # reports the larger bytes/token)
        if self.pool.pages_in_use > self.peak_pages:
            self.peak_pages = self.pool.pages_in_use
            self.resident_at_peak = resident
        elif self.pool.pages_in_use == self.peak_pages:
            self.resident_at_peak = (resident if self.resident_at_peak == 0
                                     else min(self.resident_at_peak, resident))
        return tokens, pos, page_rows, act

    def prefill_allowed_chunks(self) -> int:
        """How many prefill chunks one sequence may take this step.

        Undersubscribed batches (fewer active sequences than slots —
        tokens the static row width would otherwise waste) let a
        prefilling sequence stream up to ``prefill_max_chunks`` at once;
        a full batch drops back to exactly one chunk, which is the
        starvation bound: decode rows are never displaced, and a
        prefilling sequence always advances >= 1 chunk per step.
        """
        if len(self.active()) < self.max_slots:
            return self.prefill_max_chunks
        return 1

    def planned_prefill_real(self, seq: "ActiveSeq", width: int) -> int:
        """Valid prompt tokens ``seq``'s next ragged chunk will carry.

        Single source of truth for the chunk-size formula: used by
        ``assemble_ragged`` to pack rows and by the tiered engine's
        write-marking pre-pass, which must mark exactly the pages the
        step is about to touch.
        """
        chunk = min(self.prefill_chunk, width) if self.prefill_chunk else 0
        bite = min(chunk * self.prefill_allowed_chunks(), width)
        return min(bite, len(seq.req.prompt) - seq.prefill_pos)

    def assemble_ragged(self, width: int, extra_tokens: int = 0):
        """One packed ragged row batch for the single-dispatch engine step.

        Every active slot becomes one row of a (NS, width) token batch:
        decode-ready sequences contribute their pending token (plus
        ``extra_tokens`` draft columns the engine fills for speculative
        verify), sequences mid chunked-prefill contribute their next
        prompt chunk. Returns (tokens (NS, W), row_start (NS,), seq_lens
        (NS,), logit_idx (NS,), page_rows (NS, P), modes (NS,), decode,
        prefill):

          * ``row_start[s]`` — cache position of row s's first new token
          * ``seq_lens[s]`` — ``row_start + n_new`` (1 for inactive rows,
            whose pages are all -1 so the kernel's write lands on the
            pool's reserved trash page)
          * ``logit_idx[s]`` — first new-token row whose logits the host
            reads (0 for decode/verify, the last real row for a
            prompt-final chunk)
          * ``modes[s]`` — 0 inactive, 1 decode/verify, 2 prefill chunk
          * ``decode`` — the decode-ready ActiveSeqs (slot order)
          * ``prefill`` — ``[(seq, start, real, final)]``, one chunk per
            prefilling sequence (oldest first): ``real`` valid prompt
            tokens from position ``start``; ``final`` marks the chunk
            whose last row's logits sample the first generated token

        Shapes are static per (width, extra_tokens), so ONE jitted trace
        of the ragged step covers every decode / verify / prefill batch
        composition the engine can assemble.
        """
        ns, pps = self.max_slots, self.pages_per_slot
        tokens = np.zeros((ns, width), np.int32)
        row_start = np.zeros((ns,), np.int32)
        seq_lens = np.ones((ns,), np.int32)
        logit_idx = np.zeros((ns,), np.int32)
        modes = np.zeros((ns,), np.int32)
        page_rows = np.full((ns, pps), -1, np.int32)
        decode = self.decode_ready()
        for seq in decode:
            assert seq.req.generated, "active sequence with no pending token"
            tokens[seq.slot, 0] = seq.req.generated[-1]
            row_start[seq.slot] = seq.pos
            seq_lens[seq.slot] = seq.pos + 1 + extra_tokens
            modes[seq.slot] = 1
            page_rows[seq.slot, : len(seq.pages)] = seq.pages
        prefill = []
        for seq in self.prefilling():
            st = seq.prefill_pos
            real = self.planned_prefill_real(seq, width)
            if real <= 0:
                continue
            tokens[seq.slot, :real] = seq.req.prompt[st:st + real]
            row_start[seq.slot] = st
            seq_lens[seq.slot] = st + real
            final = st + real == len(seq.req.prompt)
            logit_idx[seq.slot] = real - 1 if final else 0
            modes[seq.slot] = 2
            page_rows[seq.slot, : len(seq.pages)] = seq.pages
            prefill.append((seq, st, real, final))
        # mirror assemble()'s peak-step sampling so bytes/token stats stay
        # comparable across step modes
        resident = int(sum(s.pos + (1 if s.prefill_pos is None else 0)
                           for s in self.active()))
        if self.pool.pages_in_use > self.peak_pages:
            self.peak_pages = self.pool.pages_in_use
            self.resident_at_peak = resident
        elif self.pool.pages_in_use == self.peak_pages:
            self.resident_at_peak = (resident if self.resident_at_peak == 0
                                     else min(self.resident_at_peak, resident))
        return (tokens, row_start, seq_lens, logit_idx, page_rows, modes,
                decode, prefill)
