"""Continuous-batching scheduler: FCFS admission, preemption, slot recycling.

Host-side control plane of the serving engine. The device sees only fixed
shapes — (max_slots, 1) token batches and a (max_slots, pages_per_slot)
page table — while requests enter and leave mid-stream:

  * **admission** — strict FCFS: the queue head is admitted as soon as a
    slot is free and its prompt's pages fit the pool (head-of-line order is
    the fairness contract; skipping ahead is a follow-on).
  * **decode paging** — each step, a slot crossing a page boundary pulls a
    fresh page from the pool. If the pool is dry, the *youngest* other
    active request is preempted: the engine snapshots its exact cache
    bytes (pages + state row, ``kv_cache.extract_seq``), its pages are
    freed, and it is requeued at the front; re-admission restores the
    snapshot verbatim (swap-style preemption). Recompute-style preemption
    would NOT be token-identical here: a re-prefill attends over
    unquantized K/V where the original decode attended over the MX cache.
  * **recycling** — EOS or max_new_tokens frees the slot and all its pages
    in O(1); the next queued request can be admitted the same step.

The scheduler never touches device memory: it hands the engine (slot,
request, page_ids) admission tuples and assembles per-step numpy batches.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np

from .kv_cache import PagePool, pages_for


@dataclasses.dataclass
class Request:
    """One generation request. ``generated`` and ``swap`` survive preemption."""

    id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    # preemption snapshot: (cache_snapshot, n_pages, resident_tokens);
    # restored verbatim on re-admission so generation stays bit-identical
    swap: Optional[tuple] = None

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    @property
    def done(self) -> bool:
        return self.remaining <= 0


@dataclasses.dataclass
class ActiveSeq:
    """A request bound to a decode slot."""

    req: Request
    slot: int
    pos: int  # next cache write position == tokens currently resident
    pages: List[int]
    order: int  # admission sequence number (preemption picks the youngest)


class Scheduler:
    def __init__(self, *, max_slots: int, num_pages: int, page_size: int,
                 max_seq: int):
        self.max_slots = max_slots
        self.page_size = page_size
        self.max_seq = max_seq
        self.pages_per_slot = pages_for(max_seq, page_size)
        if num_pages < self.pages_per_slot:
            raise ValueError(
                f"num_pages={num_pages} cannot hold one max_seq={max_seq} "
                f"sequence (needs {self.pages_per_slot})")
        self.pool = PagePool(num_pages)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[ActiveSeq]] = [None] * max_slots
        self.finished: List[Request] = []
        self._order = 0
        self._next_id = 0
        # stats sampled at the peak-pages step (benchmark bytes/token)
        self.peak_pages = 0
        self.resident_at_peak = 0
        self.preemptions = 0

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new_tokens}) "
                f"exceeds max_seq={self.max_seq}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req = Request(self._next_id, prompt, max_new_tokens)
        self._next_id += 1
        self.queue.append(req)
        return req.id

    # -- admission / eviction ----------------------------------------------

    def active(self) -> List[ActiveSeq]:
        return [s for s in self.slots if s is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def admit_next(self) -> Optional[ActiveSeq]:
        """FCFS: admit the queue head if a slot and its pages are free.

        A preempted request re-enters with exactly the pages its snapshot
        holds; a fresh one with its prompt's pages.
        """
        if not self.queue:
            return None
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        if not free_slots:
            return None
        req = self.queue[0]
        if req.swap is not None:
            _, npages, pos0 = req.swap
        else:
            # only fresh requests are prefilled; preempted ones re-enter
            # exclusively via their cache snapshot above (a re-prefill of
            # prompt+generated would not be token-identical: prefill
            # attends over unquantized K/V)
            assert not req.generated, "mid-stream request without snapshot"
            pos0 = len(req.prompt)
            npages = pages_for(pos0, self.page_size)
        ids = self.pool.alloc(npages)
        if ids is None:
            return None
        self.queue.popleft()
        seq = ActiveSeq(req=req, slot=free_slots[0], pos=pos0, pages=ids,
                        order=self._order)
        self._order += 1
        self.slots[seq.slot] = seq
        return seq

    def try_grow(self, seq: ActiveSeq) -> bool:
        """Allocate the page for ``seq.pos`` if it crosses a boundary."""
        if seq.pos // self.page_size < len(seq.pages):
            return True
        ids = self.pool.alloc(1)
        if ids is None:
            return False
        seq.pages.extend(ids)
        return True

    def pick_victim(self, exclude: ActiveSeq) -> Optional[ActiveSeq]:
        """Youngest other active sequence (FCFS: elders keep their slots)."""
        victims = [s for s in self.active() if s is not exclude]
        return max(victims, key=lambda s: s.order) if victims else None

    def preempt(self, victim: ActiveSeq, snapshot) -> None:
        """Swap out ``victim``: free its pages/slot, requeue at the front.

        The engine passes the device-side snapshot of its pages + state
        row (``kv_cache.extract_seq``); re-admission restores it verbatim,
        so preemption never perturbs the token stream.
        """
        self.pool.free(victim.pages)
        self.slots[victim.slot] = None
        victim.req.swap = (snapshot, len(victim.pages), victim.pos)
        self.queue.appendleft(victim.req)
        self.preemptions += 1

    def advance(self, seq: ActiveSeq) -> None:
        """The decode step wrote ``seq``'s pending token at ``seq.pos``."""
        seq.pos += 1

    def record_token(self, seq: ActiveSeq, token: int, eos_id=None) -> bool:
        """Append a sampled token; finish + recycle on EOS/max_new.

        ``seq.pos`` is untouched: the token's KV lands in the cache only
        when the next decode step feeds it (see :meth:`advance`). Returns
        True if the sequence is still active.
        """
        seq.req.generated.append(int(token))
        if seq.req.done or (eos_id is not None and int(token) == eos_id):
            self.pool.free(seq.pages)
            self.slots[seq.slot] = None
            self.finished.append(seq.req)
            return False
        return True

    # -- per-step batch assembly -------------------------------------------

    def assemble(self):
        """Fixed-shape numpy batch for the jitted decode step.

        Returns (tokens (NS, 1), pos (NS,), page_rows (NS, P), active) —
        inactive rows are token 0 / pos 0 / pages -1 (their device writes
        are dropped and their logits ignored).
        """
        ns, pps = self.max_slots, self.pages_per_slot
        tokens = np.zeros((ns, 1), np.int32)
        pos = np.zeros((ns,), np.int32)
        page_rows = np.full((ns, pps), -1, np.int32)
        act = self.active()
        for seq in act:
            # every activation path records a pending token before the
            # first assemble (admission samples from prefill logits;
            # swapped requests carry theirs in ``generated``)
            assert seq.req.generated, "active sequence with no pending token"
            tokens[seq.slot, 0] = seq.req.generated[-1]
            pos[seq.slot] = seq.pos
            page_rows[seq.slot, : len(seq.pages)] = seq.pages
        resident = int(sum(s.pos + 1 for s in act))
        # both stats sampled at the same step: a strict new peak resets the
        # resident count; ties keep the smaller resident (conservative —
        # reports the larger bytes/token)
        if self.pool.pages_in_use > self.peak_pages:
            self.peak_pages = self.pool.pages_in_use
            self.resident_at_peak = resident
        elif self.pool.pages_in_use == self.peak_pages:
            self.resident_at_peak = (resident if self.resident_at_peak == 0
                                     else min(self.resident_at_peak, resident))
        return tokens, pos, page_rows, act
