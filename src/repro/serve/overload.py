"""SLO-aware overload control: admission gating and load shedding.

The engine's continuous-batching loop degrades gracefully under moderate
overload — the queue absorbs bursts, preemption absorbs page pressure —
but under sustained overload both degradations compound into the classic
serving failure mode: every request waits behind an unbounded queue, the
page pool thrashes through swap preemptions, and *nobody* meets the
latency target even though the engine is running at full throughput.
Goodput (requests served within their SLO) collapses while throughput
stays flat.

The controller here implements the standard fix: measure what the system
is actually delivering, predict what a new arrival would experience, and
**reject at the door** (a 429-equivalent ``ShedError``) once that
prediction misses the SLO. A shed request costs one exception; an
admitted-then-late request costs a slot, pages, prefill compute, and —
under page pressure — preemption work that slows every resident request.
Shedding before queuing is therefore also shedding before preemption
thrash, which ``benchmarks/serve_overload.py`` pins down directly.

Model: admission latency (submit -> first sampled token) is dominated by
queue wait once the engine saturates, and queue wait is depth times the
drain rate. The controller keeps an EWMA of the interval between
successive first tokens (the drain rate's inverse — measured, so it
automatically reflects prompt lengths, chunked-prefill budgets, spec
decode, tiering, everything) plus an EWMA of recent admission latency as
the zero-queue floor, and predicts::

    predicted(depth) = depth * ewma_first_token_interval + ewma_latency

A request is shed when ``predicted(queue_depth) > slo`` (with hysteresis:
shedding stops only once the prediction falls below
``hysteresis * slo``, so the gate doesn't flap at the boundary), or
unconditionally when the queue has reached ``max_queue``. Both knobs are
optional and independent; with neither set the controller admits
everything. An arrival that finds the queue **empty** is always admitted
— it waits behind nothing the model can price, and each admitted request
refreshes the estimates, so the gate can never latch shut on a stale
under-load latency floor while the engine drains idle.

The controller is pure host-side bookkeeping — no device work, O(1) per
event — and clock-injectable for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class ShedError(RuntimeError):
    """Request rejected by overload control (HTTP 429 equivalent).

    ``retry_after_s`` is the controller's estimate of when capacity may
    return (the predicted excess over the SLO); servers surface it as a
    ``Retry-After`` hint.
    """

    def __init__(self, message: str, retry_after_s: float = 0.0):
        super().__init__(message)
        self.retry_after_s = max(0.0, float(retry_after_s))


@dataclasses.dataclass
class OverloadConfig:
    """Knobs for :class:`OverloadController`.

    ``slo_ms`` — target admission latency (submit -> first token); None
    disables latency-model shedding. ``max_queue`` — hard queue-depth
    cap; None disables it. ``ewma_alpha`` — smoothing for the interval /
    latency estimates (higher = faster reaction). ``hysteresis`` — the
    fraction of the SLO the prediction must fall back under before
    shedding stops. ``min_retry_after_s`` — floor on every ShedError's
    ``retry_after_s``: the hard ``max_queue`` cap can fire before any
    first-token interval was ever observed (cold controller), and the
    latency model's excess can round to ~0 right at the SLO boundary —
    either way a literal ``Retry-After: 0`` makes well-behaved clients
    hot-loop against a full queue.
    """

    slo_ms: Optional[float] = None
    max_queue: Optional[int] = None
    ewma_alpha: float = 0.3
    hysteresis: float = 0.85
    min_retry_after_s: float = 0.05

    def validate(self) -> "OverloadConfig":
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be > 0, got {self.slo_ms}")
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError(
                f"max_queue must be >= 0, got {self.max_queue}")
        if not 0 < self.ewma_alpha <= 1:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if not 0 < self.hysteresis <= 1:
            raise ValueError("hysteresis must be in (0, 1]")
        if self.min_retry_after_s < 0:
            raise ValueError(
                f"min_retry_after_s must be >= 0, "
                f"got {self.min_retry_after_s}")
        return self


class OverloadController:
    """Admission gate: predicts a new arrival's first-token latency and
    sheds when the prediction (or a hard queue cap) says the SLO is
    already lost. See the module docstring for the model."""

    def __init__(self, cfg: OverloadConfig,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg.validate()
        self.clock = clock
        self.ewma_interval: Optional[float] = None  # s between first tokens
        self.ewma_latency: Optional[float] = None  # s submit -> first token
        self._last_first_token: Optional[float] = None
        self.shedding = False  # hysteresis state
        self.shed_count = 0
        self.admitted_count = 0

    # -- measurement --------------------------------------------------------

    def _ewma(self, prev: Optional[float], x: float) -> float:
        a = self.cfg.ewma_alpha
        return x if prev is None else (1 - a) * prev + a * x

    def observe_first_token(self, latency_s: float) -> None:
        """One request reached its first sampled token after
        ``latency_s`` of admission latency. Updates both estimates."""
        now = self.clock()
        if self._last_first_token is not None:
            self.ewma_interval = self._ewma(
                self.ewma_interval, now - self._last_first_token)
        self._last_first_token = now
        self.ewma_latency = self._ewma(self.ewma_latency, latency_s)

    # -- the gate -----------------------------------------------------------

    def predicted_latency(self, queue_depth: int) -> Optional[float]:
        """Predicted admission latency (s) for an arrival behind
        ``queue_depth`` queued requests; None until first measurements."""
        if self.ewma_latency is None:
            return None
        interval = self.ewma_interval or 0.0
        return queue_depth * interval + self.ewma_latency

    def admit(self, queue_depth: int) -> None:
        """Gate one submission: returns on admit, raises ShedError on
        shed. Called by the engine before the request is queued."""
        cfg = self.cfg
        if cfg.max_queue is not None and queue_depth >= cfg.max_queue:
            self.shed_count += 1
            # cold controller: the cap can trip before any first-token
            # interval exists, so the drain-rate estimate is 0 — floor it
            # (and every hint below) at min_retry_after_s so the client's
            # Retry-After is never a hot-loop-inducing 0
            interval = self.ewma_interval or 0.0
            raise ShedError(
                f"queue full ({queue_depth} >= max_queue={cfg.max_queue})",
                retry_after_s=max(interval, cfg.min_retry_after_s))
        # the latency model only gates arrivals that would actually wait
        # behind a queue: at depth 0 admission is imminent and the model
        # has nothing but its (possibly stale, measured-under-load) EWMA
        # floor to go on. Admitting unconditionally at depth 0 guarantees
        # liveness — each admitted request produces a fresh first-token
        # sample, so the estimates recover after a shed episode instead
        # of latching shed forever on a stale floor.
        if cfg.slo_ms is not None and queue_depth > 0:
            slo = cfg.slo_ms / 1e3
            predicted = self.predicted_latency(queue_depth)
            if predicted is not None:
                if self.shedding:
                    if predicted < cfg.hysteresis * slo:
                        self.shedding = False
                elif predicted > slo:
                    self.shedding = True
                if self.shedding:
                    self.shed_count += 1
                    raise ShedError(
                        f"predicted first-token latency "
                        f"{predicted * 1e3:.0f}ms exceeds SLO "
                        f"{cfg.slo_ms:.0f}ms at queue depth {queue_depth}",
                        retry_after_s=max(predicted - slo,
                                          cfg.min_retry_after_s))
        self.admitted_count += 1

    def stats(self) -> dict:
        return {
            "shed_count": self.shed_count,
            "admitted_count": self.admitted_count,
            "shedding": self.shedding,
            "ewma_first_token_interval_s": self.ewma_interval,
            "ewma_admission_latency_s": self.ewma_latency,
        }
