"""Paged MX KV cache: host-side page pool + device-side cache surgery.

The paper's serving argument is that decode is HBM-bandwidth-bound on the
KV cache, so the cache should be (a) MX-compressed and (b) allocated at the
granularity traffic actually arrives in. This module supplies (b): a global
pool of fixed-size pages (fp8/fp4 element pages + E8M0 scale pages, or
bf16 pages for the baseline), a free-list allocator, and the jit-able
transfer that installs a request's prefill cache into its pages.

Split of responsibilities:

  * ``PagePool`` — pure host bookkeeping (free list, peak-usage stats).
    Which physical page holds which (sequence, position) range is decided
    here; device arrays never carry ownership metadata.
  * ``install_prefill`` — device-side: scatter a single-sequence prefill
    cache (built by ``model.prefill`` with ``serve_full_cache=True``, so
    slot == absolute position and T is a page multiple) into the pools at
    the sequence's page ids, and recurrent state rows into its slot row.
  * byte accounting — the benchmark's cache-bytes/token numbers come from
    the same walk that does the install, so they can't drift from what is
    actually allocated.

The model-level cache pytree (``model.init_paged_cache``) interleaves two
kinds of per-block caches; they are told apart structurally:
  * page pools: dicts with "k"/"v" (wide) or "k_elems"/… (MX) leaves
    shaped (NP, PS, KVH, ·), with a leading num_groups axis inside
    ``cache["groups"]``;
  * recurrent state: any other dict; leaves have the slot axis first
    (again +1 leading group axis inside ``groups``).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp


def pages_for(num_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold ``num_tokens`` cache rows."""
    return -(-num_tokens // page_size)


def pages_spanned(pos0: int, num_tokens: int, page_size: int) -> int:
    """Pages a write of ``num_tokens`` rows at positions ``pos0..`` needs.

    The speculative-verify write window: a verify step writes the pending
    token plus K drafts at positions ``pos0 .. pos0 + num_tokens - 1``,
    so the sequence's page table must reach page
    ``(pos0 + num_tokens - 1) // page_size`` *before* the step runs (and
    the engine must own every page in the window exclusively — see the
    rollback note below). Returns that page count (table length), i.e.
    ``last_page + 1``.

    Rollback contract (page-exact): rejected drafts are rolled back by
    *truncation only* — the scheduler simply does not advance ``seq.pos``
    past the accepted point. The rejected rows stay in the pages as
    garbage; they are dead to every reader because all attention paths
    mask keys by position (``kpos <= pos``), and the next write at that
    position overwrites them in place. Nothing is zeroed, copied, or
    freed, which is what makes rollback O(1) and COW-safe: because the
    engine copy-on-writes the whole window before the speculative write,
    shared prefix pages (radix tree, other sequences, swapped-out
    holders) are never touched by a write that might be rolled back.
    """
    if num_tokens <= 0:
        raise ValueError("write window must cover at least one token")
    return (pos0 + num_tokens - 1) // page_size + 1


#: Unit cost of a full-width page, in quarter-page units. The tiered
#: mixed-format pool stores every page's elements in full-width uint8 rows
#: (narrower formats occupy a row *prefix*), so the *physical* array is
#: sized for fp8 — but the HBM-budget argument tiers make is about the
#: bytes a page's format actually needs: fp8 = 4/4, fp6 = 3/4, fp4 = 2/4
#: of a full page. ``PagePool`` can meter allocation against that logical
#: budget so repacking pages down the ladder genuinely frees capacity.
PAGE_UNITS_FULL = 4

#: Quarter-page unit cost per element format bit width.
UNITS_BY_BITS = {8: 4, 6: 3, 4: 2}


class PagePool:
    """Ref-counted free-list allocator over a fixed set of physical page ids.

    Any free page can serve any sequence (no fragmentation by design), so
    allocation is O(n) pops and ``alloc`` fails only when the pool is
    genuinely out of pages — the scheduler then evicts prefix-cache leaves
    or preempts.

    Sharing: a physical page can back many sequences' page tables (prompt
    prefix sharing) plus the prefix radix tree. ``alloc`` hands out pages
    with one reference; every additional holder calls :meth:`retain`, every
    holder releases with :meth:`free`, and the page returns to the free
    list only when its last reference drops. Writers must hold the only
    reference (copy-on-write is the engine's job; ``ref`` exposes the count
    so it can tell).

    Tiered budget metering: with ``unit_budget`` set (quarter-page units,
    see :data:`PAGE_UNITS_FULL`), every freshly allocated page is charged
    the full 4 units (new writes always land hot fp8), the tiering engine
    credits units back by calling :meth:`set_cost` when it repacks a page
    to a narrower format, and :meth:`can_alloc`/:meth:`alloc` admit only
    while both physical pages *and* units remain. The physical page count
    should then over-provision the fp8-equivalent budget (the engine uses
    2x) so the pool can hold more, narrower pages than an all-fp8 pool of
    the same byte budget. ``unit_budget=None`` keeps the legacy
    pages-only behavior.
    """

    def __init__(self, num_pages: int, unit_budget: Optional[int] = None,
                 track_allocs: bool = False):
        if num_pages <= 0:
            raise ValueError("num_pages must be positive")
        if unit_budget is not None and unit_budget <= 0:
            raise ValueError("unit_budget must be positive")
        self.num_pages = num_pages
        self.unit_budget = unit_budget
        self.track_allocs = track_allocs
        #: With ``track_allocs``: every page id handed out by :meth:`alloc`
        #: since the last drain. The tiering engine drains this each step to
        #: reset a recycled page's format id back to hot fp8 — a page that
        #: was repacked to fp4, freed, and re-allocated would otherwise keep
        #: its stale narrow format id while new writes land fp8 bytes.
        self.alloc_log: List[int] = []
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._free_set = set(self._free)  # O(1) double-free detection
        self._ref = [0] * num_pages
        self._cost = [PAGE_UNITS_FULL] * num_pages
        self.units_in_use = 0
        self.peak_in_use = 0
        self.peak_units = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def units_free(self) -> Optional[int]:
        """Remaining quarter-page units (None when not metering)."""
        if self.unit_budget is None:
            return None
        return self.unit_budget - self.units_in_use

    def ref(self, pid: int) -> int:
        """Current reference count of ``pid`` (0 = on the free list)."""
        if not 0 <= pid < self.num_pages:
            raise ValueError(f"unknown page {pid}")
        return self._ref[pid]

    def cost(self, pid: int) -> int:
        """Current unit cost of allocated page ``pid``."""
        if not 0 <= pid < self.num_pages:
            raise ValueError(f"unknown page {pid}")
        return self._cost[pid]

    def set_cost(self, pid: int, units: int) -> None:
        """Re-meter an allocated page after a format change (repack).

        The tiering engine calls this when a page's element format flips:
        repack down the ladder credits units back to the budget; promoting
        back to hot (rewrite) charges them again. Refcounts are untouched
        — cost is a property of the physical page, shared by all holders.
        """
        if not 0 <= pid < self.num_pages:
            raise ValueError(f"unknown page {pid}")
        if self._ref[pid] == 0:
            raise ValueError(f"set_cost of free page {pid}")
        if not 1 <= units <= PAGE_UNITS_FULL:
            raise ValueError(f"bad page cost {units}")
        self.units_in_use += units - self._cost[pid]
        self._cost[pid] = units
        self.peak_units = max(self.peak_units, self.units_in_use)

    def can_alloc(self, n: int) -> bool:
        if n > len(self._free):
            return False
        return (self.unit_budget is None or
                self.units_in_use + n * PAGE_UNITS_FULL <= self.unit_budget)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` page ids (refcount 1, full cost), or None (no change)."""
        if n < 0:
            raise ValueError("alloc of negative page count")
        if not self.can_alloc(n):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(ids)
        for pid in ids:
            self._ref[pid] = 1
            self._cost[pid] = PAGE_UNITS_FULL
        if self.track_allocs:
            self.alloc_log.extend(ids)
        self.units_in_use += n * PAGE_UNITS_FULL
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        self.peak_units = max(self.peak_units, self.units_in_use)
        return ids

    def retain(self, ids) -> None:
        """Add one reference to each allocated page in ``ids``."""
        for pid in ids:
            if not 0 <= pid < self.num_pages:
                raise ValueError(f"retain of unknown page {pid}")
            if self._ref[pid] == 0:
                raise ValueError(f"retain of free page {pid}")
            self._ref[pid] += 1

    def free(self, ids) -> None:
        """Drop one reference per page; last reference frees the page."""
        for pid in ids:
            if not 0 <= pid < self.num_pages:
                raise ValueError(f"free of unknown page {pid}")
            if pid in self._free_set or self._ref[pid] == 0:
                raise ValueError(f"double free of page {pid}")
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self.units_in_use -= self._cost[pid]
                self._free.append(pid)
                self._free_set.add(pid)


# ---------------------------------------------------------------------------
# structural walk over the model cache pytree
# ---------------------------------------------------------------------------

_POOL_KEYS = ({"k", "v"}, {"k_elems", "k_scales", "v_elems", "v_scales"})


def _is_pool(block_cache) -> bool:
    return isinstance(block_cache, dict) and set(block_cache) in _POOL_KEYS


def _iter_blocks(cache):
    """Yield (key_path, block_cache, grouped) for every block's cache."""
    for key, val in cache.items():
        if key == "groups":
            for i, blk in enumerate(val):
                yield (key, i), blk, True
        else:
            yield (key,), val, False


def _set_block(cache, path, new_blk):
    cache = dict(cache)
    if path[0] == "groups":
        groups = list(cache["groups"])
        groups[path[1]] = new_blk
        cache["groups"] = tuple(groups)
    else:
        cache[path[0]] = new_blk
    return cache


def _install_pool(pool, contig, page_ids, page_size, grouped):
    """Scatter a (1, T, ·) contiguous cache into pool pages ``page_ids``."""
    n = page_ids.shape[0]
    new = {}
    for key in pool:
        src = contig[key]
        if grouped:
            g = src.shape[0]
            pages = src.reshape(g, n, page_size, *src.shape[3:])
            new[key] = pool[key].at[:, page_ids].set(pages)
        else:
            pages = src.reshape(n, page_size, *src.shape[2:])
            new[key] = pool[key].at[page_ids].set(pages)
    return new


def _install_state(state, contig, slot, grouped):
    """Write a batch-1 recurrent state into the pool's ``slot`` row."""
    if grouped:
        return jax.tree_util.tree_map(
            lambda pool, src: pool.at[:, slot].set(src[:, 0]), state, contig)
    return jax.tree_util.tree_map(
        lambda pool, src: pool.at[slot].set(src[0]), state, contig)


def install_prefill(cache, prefill_cache, slot, page_ids, page_size: int):
    """Install one request's prefill cache into the paged model cache.

    ``prefill_cache`` comes from ``model.prefill`` on a batch of 1 with
    ``serve_full_cache=True`` and ``max_seq == len(page_ids) * page_size``
    (so its T dim factors exactly into the allocated pages). ``slot`` is
    the request's decode-batch row; recurrent state lands there. Returns
    the updated cache pytree (jit-able; retraces per page count).
    """
    for path, blk, grouped in _iter_blocks(cache):
        src = prefill_cache[path[0]] if len(path) == 1 else \
            prefill_cache["groups"][path[1]]
        if _is_pool(blk):
            src = {key: src[key] for key in blk}  # drop kpos
            blk = _install_pool(blk, src, page_ids, page_size, grouped)
        else:
            blk = _install_state(blk, src, slot, grouped)
        cache = _set_block(cache, path, blk)
    return cache


def install_prefill_offset(cache, prefill_cache, slot, page_ids,
                           page_size: int, offset: int, num_rows: int):
    """Install a prefill *tail* starting at a non-page-aligned position.

    The partial-page prefix-hit path: a prefix-cache hit may end mid-page
    (``offset = cached % page_size != 0``), so the freshly prefillled tail
    rows land at row ``offset`` of the first page in its write window
    rather than at a page boundary. ``prefill_cache`` covers the tail only
    (row r is absolute position ``offset + r`` within ``page_ids``'
    span); only the first ``num_rows`` rows are live, the rest padding.
    The engine must own every written page exclusively (COW first) — the
    partial hit page keeps its cached prefix rows and receives the tail
    rows in place. Recurrent state rows install whole, as in
    :func:`install_prefill` (sharing implies attention-only models, so
    state blocks are empty on this path anyway). jit-able; retraces per
    (pages, offset, num_rows).
    """
    rows = jnp.arange(num_rows, dtype=jnp.int32) + offset
    pidx = page_ids[rows // page_size]
    sidx = rows % page_size
    for path, blk, grouped in _iter_blocks(cache):
        src = prefill_cache[path[0]] if len(path) == 1 else \
            prefill_cache["groups"][path[1]]
        if _is_pool(blk):
            if grouped:
                blk = {key: blk[key].at[:, pidx, sidx].set(
                    src[key][:, 0, :num_rows]) for key in blk}
            else:
                blk = {key: blk[key].at[pidx, sidx].set(
                    src[key][0, :num_rows]) for key in blk}
        else:
            blk = _install_state(blk, src, slot, grouped)
        cache = _set_block(cache, path, blk)
    return cache


def copy_page(cache, src, dst):
    """Copy one physical page's contents ``src`` -> ``dst`` in every pool.

    The device half of copy-on-write: when a sequence must write into a
    page other holders reference, the engine allocates a fresh page, copies
    the shared page's bytes here, and repoints the sequence's page table
    before the write. Recurrent state blocks are untouched (they are
    per-slot, never shared). jit-able; ``src``/``dst`` are scalar int32.
    """
    for path, blk, grouped in _iter_blocks(cache):
        if not _is_pool(blk):
            continue
        blk = {key: (leaf.at[:, dst].set(leaf[:, src]) if grouped
                     else leaf.at[dst].set(leaf[src]))
               for key, leaf in blk.items()}
        cache = _set_block(cache, path, blk)
    return cache


# ---------------------------------------------------------------------------
# swap-out / swap-in (exact preemption)
# ---------------------------------------------------------------------------


def extract_seq(cache, slot, page_ids):
    """Snapshot one sequence's cache: its pool pages + its state row.

    Used on preemption: unlike recompute-style preemption, restoring the
    exact cache bytes keeps generation bit-identical — a re-*prefill*
    would attend over unquantized K/V where the original decode attended
    over the MX cache, and the token stream could diverge.

    Returns a pytree mirroring ``cache`` with pool leaves gathered to
    (n_pages, PS, ·) (grouped: (G, n_pages, PS, ·)) and state leaves
    sliced to the slot row.
    """
    out = {}
    for path, blk, grouped in _iter_blocks(cache):
        if _is_pool(blk):
            snap = {key: (leaf[:, page_ids] if grouped else leaf[page_ids])
                    for key, leaf in blk.items()}
        else:
            snap = jax.tree_util.tree_map(
                lambda leaf: leaf[:, slot] if grouped else leaf[slot], blk)
        if path[0] == "groups":
            out.setdefault("groups", {})[path[1]] = snap
        else:
            out[path[0]] = snap
    if "groups" in out:
        out["groups"] = tuple(out["groups"][i]
                              for i in range(len(out["groups"])))
    return out


def merge_snapshots(a, b):
    """Concatenate two :func:`extract_seq` snapshots along the page axis.

    Used when a swapped-out request's retained *shared* pages must be
    reclaimed (last-resort pool pressure): their bytes are extracted into
    a second snapshot and appended to the swap's original one, in the
    same order the page indices are appended to its owned list. Only pool
    leaves are merged; state rows keep ``a``'s (sharing implies an
    attention-only model, so state blocks are empty anyway). ``a`` may be
    None (a swap that owned no pages exclusively).
    """
    if a is None:
        return b
    merged = a
    for path, blk, grouped in _iter_blocks(a):
        if not _is_pool(blk):
            continue
        other = b[path[0]] if len(path) == 1 else b["groups"][path[1]]
        blk = {key: jnp.concatenate([leaf, other[key]],
                                    axis=1 if grouped else 0)
               for key, leaf in blk.items()}
        merged = _set_block(merged, path, blk)
    return merged


def restore_seq(cache, snapshot, slot, page_ids):
    """Inverse of :func:`extract_seq` onto freshly allocated pages/slot."""
    for path, blk, grouped in _iter_blocks(cache):
        snap = snapshot[path[0]] if len(path) == 1 else \
            snapshot["groups"][path[1]]
        if _is_pool(blk):
            blk = {key: (leaf.at[:, page_ids].set(snap[key]) if grouped
                         else leaf.at[page_ids].set(snap[key]))
                   for key, leaf in blk.items()}
        else:
            blk = jax.tree_util.tree_map(
                lambda leaf, src: (leaf.at[:, slot].set(src) if grouped
                                   else leaf.at[slot].set(src)), blk, snap)
        cache = _set_block(cache, path, blk)
    return cache


# ---------------------------------------------------------------------------
# sharded pools (KV-head-parallel serve step)
# ---------------------------------------------------------------------------


def pool_specs(cache, axis: str):
    """PartitionSpec pytree sharding every pool leaf's KV-head axis.

    The sharded serve engine partitions each attention layer's page pool
    along its KV-head dimension — layout ``(NP, PS, KVH, ·)``, grouped
    ``(G, NP, PS, KVH, ·)``, so the KV-head axis is always ``ndim - 2``.
    The megakernel's stacked-layer pool (``model.init_megakernel_cache``)
    is the grouped layout with ``G == num_layers``, so these specs — and
    every other structural walk in this module (copy_page,
    extract/restore, repack) — apply to it unchanged; that layout
    coincidence is load-bearing (see ``blocks.megakernel_reject_reason``)
    and is what the sharded-megakernel ROADMAP rung builds on.
    The page axis stays unsharded: every device holds pages
    ``0..NP`` for *its* head slice, so the host page table is replicated
    metadata and extract/restore/copy_page stay shard-local gathers
    under GSPMD. Recurrent state blocks (and anything else that is not a
    pool) are replicated. Returns a tree with the same structure as
    ``cache`` whose leaves are ``PartitionSpec``s — usable both as
    ``shard_map`` in/out specs and (through ``NamedSharding``) as
    ``device_put`` targets.
    """
    from jax.sharding import PartitionSpec as P

    specs = cache
    for path, blk, _grouped in _iter_blocks(cache):
        if _is_pool(blk):
            # no trailing None past the sharded axis: jit hashes the
            # canonical (trimmed) form the step's outputs come back
            # with, and a P(..., axis, None) _shard_put placement would
            # make the first call a second trace
            new = {key: P(*([None] * (leaf.ndim - 2)), axis)
                   for key, leaf in blk.items()}
        else:
            new = jax.tree_util.tree_map(lambda leaf: P(), blk)
        specs = _set_block(specs, path, new)
    return specs


# ---------------------------------------------------------------------------
# byte accounting (benchmark: cache bytes per resident token)
# ---------------------------------------------------------------------------


def cache_nbytes(cache) -> int:
    """Total bytes of every cache leaf (pools + recurrent state)."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(cache))


def pool_page_nbytes(cache, num_pages: int) -> int:
    """Bytes one page costs across all attention layers (incl. groups)."""
    total = 0
    for _, blk, _ in _iter_blocks(cache):
        if _is_pool(blk):
            total += sum(leaf.nbytes for leaf in blk.values())
    if total % num_pages:
        raise ValueError("pool bytes not divisible by page count")
    return total // num_pages


def state_nbytes(cache) -> int:
    """Bytes of per-slot recurrent state (not paged)."""
    total = 0
    for _, blk, _ in _iter_blocks(cache):
        if not _is_pool(blk):
            total += sum(leaf.nbytes
                         for leaf in jax.tree_util.tree_leaves(blk))
    return total
