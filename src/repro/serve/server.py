"""Asyncio serving front end: HTTP/SSE token streaming over the engine.

Two layers, both stdlib-only (no aiohttp — the CI image has none):

  * :class:`AsyncServeEngine` — drives ``ContinuousBatchingEngine.step()``
    as a cooperative asyncio task and turns the scheduler's ``on_token``
    hook into per-request ``asyncio.Queue`` deliveries, so any number of
    concurrent coroutines each ``async for`` their own request's tokens
    the moment the step that sampled them finishes. Submission applies
    the engine's overload gate (:class:`~.overload.ShedError` propagates
    to the caller — the HTTP layer maps it to 429) and a draining server
    rejects new work while resident requests run to completion.
  * :class:`ServeHTTPServer` — a minimal HTTP/1.1 server on
    ``asyncio.start_server`` exposing

      - ``POST /v1/generate`` — body ``{"prompt": [ids...],
        "max_new_tokens": n, "temperature": t, "top_p": p, "top_k": k,
        "seed": s}`` (sampling fields optional → engine defaults);
        responds with an SSE stream: one ``data: {"token": id,
        "index": i}`` event per token as it is sampled, then a final
        ``data: {"done": true, ...}`` event. 429 + Retry-After when the
        overload controller sheds, 503 while draining.
      - ``POST /v1/cancel`` — body ``{"request_id": id}``; releases the
        request's slot/pages/prefix retains mid-flight.
      - ``GET /v1/health`` — engine + overload stats as JSON.
      - ``POST /v1/drain`` — stop admitting, wait for resident requests
        to finish, then respond (graceful-shutdown hook).

    Client disconnects are detected two ways — the socket reaching EOF
    while the stream waits for its next token, and a failed SSE write —
    and both route to ``engine.cancel``: an abandoned request frees its
    pages and prefix-cache retains the same engine step instead of
    decoding to max_new_tokens for nobody.

The engine step is synchronous device compute, so the step loop runs it
inline and yields to the event loop between steps: token delivery,
admission, and disconnect handling all interleave at step granularity.
That is the right trade for a single-device engine — a thread pool would
add latency jitter without adding parallelism (steps serialize on the
device anyway).
"""
from __future__ import annotations

import asyncio
import json
import logging
from typing import Dict, Optional

import numpy as np

from .overload import ShedError
from .sampling import SamplingParams

log = logging.getLogger("repro.serve.server")

#: sentinel queue item: the request was cancelled, end the stream
_CANCELLED = object()


class DrainingError(RuntimeError):
    """Submission rejected because the server is draining (HTTP 503)."""


class AsyncServeEngine:
    """Async facade over ``ContinuousBatchingEngine`` for many clients.

    One instance owns the engine: all submissions, cancels, and steps go
    through it, on one event loop. ``submit`` returns a request id whose
    tokens arrive on :meth:`stream`; the internal step task starts on
    first submission and parks when the engine drains idle.
    """

    def __init__(self, engine):
        self.engine = engine
        engine.scheduler.on_token = self._on_token
        self._queues: Dict[int, asyncio.Queue] = {}
        self._step_task: Optional[asyncio.Task] = None
        self.draining = False
        self._idle = asyncio.Event()
        self._idle.set()

    # -- engine-side callbacks (sync, inside step()) ------------------------

    def _on_token(self, req, token: int, finished: bool) -> None:
        q = self._queues.get(req.id)
        if q is not None:
            q.put_nowait((token, finished))

    # -- submission / delivery ----------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               sampling_params: Optional[SamplingParams] = None) -> int:
        """Queue one request; returns its id (tokens via :meth:`stream`).

        Raises :class:`DrainingError` while draining and propagates the
        engine's :class:`~.overload.ShedError` under overload.
        """
        if self.draining:
            raise DrainingError("server is draining, not accepting work")
        rid = self.engine.submit(np.asarray(prompt, np.int32),
                                 max_new_tokens,
                                 sampling_params=sampling_params)
        self._queues[rid] = asyncio.Queue()
        self._kick()
        return rid

    async def stream(self, request_id: int):
        """Async-iterate ``(index, token, finished)`` for one request.

        Ends after the ``finished`` token, or immediately (no further
        items) if the request is cancelled mid-stream.
        """
        q = self._queues.get(request_id)
        if q is None:
            raise KeyError(f"unknown request id {request_id}")
        index = 0
        try:
            while True:
                item = await q.get()
                if item is _CANCELLED:
                    return
                token, finished = item
                yield index, token, finished
                index += 1
                if finished:
                    return
        finally:
            self._queues.pop(request_id, None)

    def cancel(self, request_id: int) -> bool:
        """Release a request's slot/pages/prefix retains mid-flight and
        terminate its stream. True if it was still live."""
        found = self.engine.cancel(request_id)
        # pop the map entry now (a disconnected client's stream may never
        # resume to clean up); a live stream still holds the queue object
        # and sees the sentinel
        q = self._queues.pop(request_id, None)
        if q is not None:
            q.put_nowait(_CANCELLED)
        return found

    async def drain(self) -> None:
        """Stop admitting new requests, then wait until every resident
        request has run to completion (graceful shutdown)."""
        self.draining = True
        await self._idle.wait()

    # -- the step loop -------------------------------------------------------

    def _kick(self) -> None:
        if self._step_task is None or self._step_task.done():
            self._idle.clear()
            self._step_task = asyncio.get_running_loop().create_task(
                self._run_steps())

    async def _run_steps(self) -> None:
        engine = self.engine
        try:
            while engine.scheduler.has_work:
                engine.step()
                # streamed requests' results live in their queues; don't
                # let the batch-API result list grow without bound
                engine.scheduler.finished.clear()
                # one cooperative yield per step: token writes, new
                # submissions, cancels, and disconnects interleave here
                await asyncio.sleep(0)
        finally:
            self._idle.set()


# -- the HTTP/SSE layer ------------------------------------------------------

_SSE_HEADERS = (b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n"
                b"Connection: close\r\n\r\n")


def _json_response(status: str, payload: dict,
                   extra_headers: str = "") -> bytes:
    body = json.dumps(payload).encode()
    return (f"HTTP/1.1 {status}\r\nContent-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n{extra_headers}"
            f"Connection: close\r\n\r\n").encode() + body


def _sse_event(payload: dict) -> bytes:
    return b"data: " + json.dumps(payload).encode() + b"\n\n"


def _parse_sampling(body: dict) -> Optional[SamplingParams]:
    keys = ("temperature", "top_p", "top_k", "seed")
    if not any(k in body for k in keys):
        return None
    return SamplingParams(
        temperature=float(body.get("temperature", 0.0)),
        top_p=float(body.get("top_p", 1.0)),
        top_k=int(body.get("top_k", 0)),
        seed=(int(body["seed"]) if body.get("seed") is not None
              else None)).validate()


class ServeHTTPServer:
    """Minimal stdlib HTTP/1.1 + SSE front end over AsyncServeEngine."""

    def __init__(self, async_engine: AsyncServeEngine, host: str =
                 "127.0.0.1", port: int = 8000):
        self.engine = async_engine
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        # port 0 resolves to an ephemeral port at bind time
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    # -- request handling ---------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, body = await self._read_request(reader)
            if method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            elif method == "POST" and path == "/v1/cancel":
                found = self.engine.cancel(int(body["request_id"]))
                writer.write(_json_response(
                    "200 OK", {"cancelled": bool(found)}))
            elif method == "GET" and path == "/v1/health":
                stats = dict(self.engine.engine.overload.stats())
                stats["draining"] = self.engine.draining
                stats["queue_depth"] = len(
                    self.engine.engine.scheduler.queue)
                writer.write(_json_response("200 OK", stats))
            elif method == "POST" and path == "/v1/drain":
                await self.engine.drain()
                writer.write(_json_response("200 OK", {"drained": True}))
            else:
                writer.write(_json_response(
                    "404 Not Found", {"error": f"no route {method} {path}"}))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError, asyncio.IncompleteReadError):
            pass
        except Exception as e:  # malformed request: answer, don't crash
            try:
                writer.write(_json_response("400 Bad Request",
                                            {"error": str(e)}))
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        request_line = (await reader.readline()).decode()
        if not request_line.strip():
            raise ValueError("empty request")
        method, path, _ = request_line.split(" ", 2)
        content_length = 0
        while True:
            line = (await reader.readline()).decode()
            if line in ("\r\n", "\n", ""):
                break
            name, _, value = line.partition(":")
            if name.strip().lower() == "content-length":
                content_length = int(value.strip())
        body = {}
        if content_length:
            body = json.loads(await reader.readexactly(content_length))
        return method, path.strip(), body

    async def _generate(self, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter, body: dict) -> None:
        try:
            rid = self.engine.submit(
                body["prompt"], int(body.get("max_new_tokens", 16)),
                sampling_params=_parse_sampling(body))
        except DrainingError as e:
            writer.write(_json_response("503 Service Unavailable",
                                        {"error": str(e)}))
            return
        except ShedError as e:
            writer.write(_json_response(
                "429 Too Many Requests", {"error": str(e)},
                extra_headers=f"Retry-After: {e.retry_after_s:.3f}\r\n"))
            return
        except (ValueError, KeyError) as e:
            writer.write(_json_response("400 Bad Request",
                                        {"error": str(e)}))
            return
        writer.write(_SSE_HEADERS)
        writer.write(_sse_event({"request_id": rid}))
        await writer.drain()
        # half-open detection: the POST body is fully consumed, so any
        # EOF from here on means the client hung up — reap the request
        # instead of decoding to max_new_tokens for nobody
        eof_task = asyncio.ensure_future(reader.read(1))
        tokens = []
        cancelled = False
        try:
            stream = self.engine.stream(rid)
            stream_iter = stream.__aiter__()
            while True:
                next_task = asyncio.ensure_future(stream_iter.__anext__())
                done, _ = await asyncio.wait(
                    {eof_task, next_task},
                    return_when=asyncio.FIRST_COMPLETED)
                if eof_task in done:
                    next_task.cancel()
                    self.engine.cancel(rid)
                    cancelled = True
                    log.info("client disconnected, cancelled request %d",
                             rid)
                    return
                try:
                    index, token, finished = next_task.result()
                except StopAsyncIteration:
                    cancelled = True  # cancelled via /v1/cancel
                    break
                tokens.append(int(token))
                try:
                    writer.write(_sse_event(
                        {"token": int(token), "index": index}))
                    await writer.drain()
                except (ConnectionResetError, BrokenPipeError):
                    self.engine.cancel(rid)
                    cancelled = True
                    return
                if finished:
                    break
            if not cancelled:
                writer.write(_sse_event(
                    {"done": True, "request_id": rid, "tokens": tokens}))
            else:
                writer.write(_sse_event(
                    {"done": True, "request_id": rid, "cancelled": True}))
            await writer.drain()
        finally:
            eof_task.cancel()


async def sse_generate(host: str, port: int, payload: dict):
    """Minimal stdlib SSE client: POST /v1/generate, yield parsed events.

    The benchmark's and tests' closed-loop clients use this — it speaks
    exactly the wire format ``ServeHTTPServer`` emits. Raises
    ``RuntimeError`` carrying the status line on non-200 responses (429
    sheds land here).
    """
    body = json.dumps(payload).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(
            (f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
             f"Content-Type: application/json\r\n"
             f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        status = (await reader.readline()).decode()
        if "200" not in status:
            rest = await reader.read()
            raise RuntimeError(f"{status.strip()} {rest.decode()!r}")
        while True:  # skip response headers
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
        while True:
            line = await reader.readline()
            if not line:
                return
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            event = json.loads(line[len(b"data: "):])
            yield event
            if event.get("done"):
                return
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
