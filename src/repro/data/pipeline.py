"""Deterministic, host-sharded synthetic LM data pipeline.

Production posture: every host generates exactly its shard of the global
batch from a counter-based PRNG (hash of (seed, step, host)) — no data
server, no cross-host coordination, bit-reproducible, and restart-safe
(pipeline state is just the step counter, stored in each checkpoint).
The "markov" mode produces learnable structure so integration tests can
assert loss decreases; "uniform" is for pure throughput work.

A byte-level corpus reader (``CorpusDataset``) covers the
train-on-real-text example: documents -> byte tokens -> packed sequences
with -1 padding labels at document boundaries.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "markov"  # "markov" | "uniform"
    num_codebooks: int = 1
    process_index: int = 0
    process_count: int = 1


class SyntheticLMDataset:
    """Counter-based deterministic batches (per-host shard)."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.process_count:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.process_count
        # fixed random markov transition table (shared across hosts)
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 512)
        self._v = v
        probs = rng.dirichlet(np.ones(8), size=v)
        nexts = rng.integers(0, v, size=(v, 8))
        self._probs = probs
        self._nexts = nexts

    def _rng_for(self, step: int) -> np.random.Generator:
        h = hashlib.sha256(
            f"{self.cfg.seed}:{step}:{self.cfg.process_index}".encode()
        ).digest()
        return np.random.default_rng(int.from_bytes(h[:8], "little"))

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for ``step`` (resume == replay)."""
        cfg = self.cfg
        rng = self._rng_for(step)
        shape = (self.local_batch, cfg.seq_len)
        if cfg.num_codebooks > 1:
            shape = (*shape, cfg.num_codebooks)
        if cfg.mode == "uniform":
            tokens = rng.integers(0, cfg.vocab_size, size=shape, dtype=np.int32)
        else:
            tokens = self._markov(rng, shape)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1  # no target for the last position
        return {"tokens": tokens, "labels": labels.astype(np.int32)}

    def _markov(self, rng, shape):
        b, s = shape[0], shape[1]
        flatshape = (b, s) if len(shape) == 2 else shape
        out = np.zeros((b, s), np.int32)
        state = rng.integers(0, self._v, size=b)
        # vectorized markov walk over the (small) synthetic vocabulary
        for t in range(s):
            out[:, t] = state
            u = rng.random(b)
            cum = np.cumsum(self._probs[state], axis=1)
            choice = (u[:, None] < cum).argmax(axis=1)
            state = self._nexts[state, choice]
        if len(shape) == 3:
            out = np.broadcast_to(out[..., None], shape).copy()
            out = (out + np.arange(shape[-1])) % self.cfg.vocab_size
        return out % self.cfg.vocab_size

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class CorpusDataset:
    """Byte-level corpus with sequence packing (real-text example path)."""

    def __init__(self, text: str, cfg: DataConfig):
        self.cfg = cfg
        data = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(
            np.int32)
        self.data = data
        self.local_batch = cfg.global_batch // cfg.process_count

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.process_index, 7919))
        n = len(self.data) - cfg.seq_len - 1
        starts = rng.integers(0, max(n, 1), size=self.local_batch)
        tokens = np.stack([self.data[s:s + cfg.seq_len] for s in starts])
        labels = np.stack([self.data[s + 1:s + cfg.seq_len + 1] for s in starts])
        return {"tokens": tokens, "labels": labels.astype(np.int32)}
