"""Data pipelines: deterministic synthetic + byte-level corpus."""
from .pipeline import CorpusDataset, DataConfig, SyntheticLMDataset

__all__ = ["CorpusDataset", "DataConfig", "SyntheticLMDataset"]
