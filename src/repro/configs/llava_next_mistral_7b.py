"""llava-next-mistral-7b [vlm]: mistral-7b backbone, anyres vision stub.

32L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. The vision frontend
(anyres tiling -> patch embeddings) is a STUB per the assignment:
``input_specs()`` supplies precomputed (B, S, d_model) embeddings.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        d_model=4096, vocab_size=32000,
        pattern=(BlockDef("attn"),), num_groups=32,
        num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, ffn_kind="swiglu",
        rope_theta=1e6, tied_embeddings=False,
        quant=MXFP8,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=2,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16),
    )
