"""Architecture registry: the 10 assigned configs + the paper's own bench.

``get_config(name)`` returns the full ModelConfig; ``get_reduced(name)`` a
CPU-smoke-sized config of the same family; ``--arch <id>`` in the launchers
resolves through :data:`ARCHS`.
"""
from __future__ import annotations

import importlib

ARCHS = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "gemma2-2b": "gemma2_2b",
    "gemma2-9b": "gemma2_9b",
    "phi4-mini-3.8b": "phi4_mini",
    "granite-8b": "granite_8b",
    "mamba2-780m": "mamba2_780m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "musicgen-medium": "musicgen_medium",
}

from .shapes import SHAPES, ShapeSpec, shape_applicable  # noqa: E402


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[name]}")


def get_config(name: str):
    return _module(name).config()


def get_reduced(name: str):
    return _module(name).reduced()


def list_archs():
    return sorted(ARCHS)
