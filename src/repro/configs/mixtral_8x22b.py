"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff_expert=16384 vocab=32768
[arXiv:2401.04088; hf]. Assignment sheet specifies SWA (window 4096) ->
sub-quadratic, eligible for long_500k with a ring-buffer cache.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig

WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        d_model=6144, vocab_size=32768,
        pattern=(BlockDef("attn", window=WINDOW, ffn="moe"),),
        num_groups=56,
        num_heads=48, num_kv_heads=8, head_dim=128,
        num_experts=8, top_k=2, d_ff_expert=16384,
        rope_theta=1e6, tied_embeddings=False,
        quant=MXFP8,
        train_microbatches=1,
        source="arXiv:2401.04088; hf",
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=2,
        num_heads=4, num_kv_heads=2, head_dim=16,
        num_experts=4, top_k=2, d_ff_expert=64,
        pattern=(BlockDef("attn", window=8, ffn="moe"),),
        quant=MXFP8.replace(block_size=16),
    )
