"""Assigned input shapes (per the architecture sheet): seq_len x global_batch.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len); ``train_*`` lowers ``train_step``; ``prefill_*`` lowers
the prompt-processing step.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch_cfg, shape: ShapeSpec) -> bool:
    """long_500k requires sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k":
        return arch_cfg.sub_quadratic
    return True
