"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1536 (d_inner=3072, headdim 64 -> 48 heads, d_state=128)
vocab=50280 [arXiv:2405.21060; unverified]. No FFN blocks (mamba stacks
mixer-only layers). Attention-free -> long_500k eligible.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        d_model=1536, vocab_size=50280,
        pattern=(BlockDef("ssd", ffn="none"),), num_groups=48,
        d_inner=3072, headdim=64, d_state=128, ngroups=1,
        conv_width=4, ssd_chunk=256,
        quant=MXFP8,
        source="arXiv:2405.21060; unverified",
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=2,
        d_inner=128, headdim=16, d_state=32, ssd_chunk=8,
        quant=MXFP8.replace(block_size=16),
    )
