"""musicgen-medium [audio]: decoder-only over EnCodec tokens (4 codebooks).

48L d_model=1536 24H (MHA kv=24, head_dim 64) d_ff=6144 (GELU) vocab=2048
[arXiv:2306.05284; hf]. The EnCodec frontend is a STUB per the assignment:
inputs are 4-codebook token frames (delay pattern handled upstream); the
backbone sums codebook embeddings and predicts 4 codebook heads.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        d_model=1536, vocab_size=2048,
        pattern=(BlockDef("attn"),), num_groups=48,
        num_heads=24, num_kv_heads=24, head_dim=64,
        d_ff=6144, ffn_kind="gelu",
        num_codebooks=4,
        quant=MXFP8,
        source="arXiv:2306.05284; hf",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=128, num_groups=2,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16),
    )
