"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 attn:recurrent.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 (GeGLU) vocab=256000
[arXiv:2402.19427; hf]. Pattern (rec, rec, local-attn) x 8 groups + 2
trailing recurrent layers (26 = 3*8 + 2). Local window 2048. Sub-quadratic
-> eligible for long_500k.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig

WINDOW = 2048


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        d_model=2560, vocab_size=256000,
        pattern=(BlockDef("rglru"), BlockDef("rglru"),
                 BlockDef("attn", window=WINDOW)),
        num_groups=8,
        epilogue=(BlockDef("rglru"), BlockDef("rglru")),
        num_heads=10, num_kv_heads=1, head_dim=256,
        d_ff=7680, ffn_kind="geglu",
        rnn_width=2560, conv_width=4,
        scale_embeds_by_sqrt_dim=True,
        quant=MXFP8,
        source="arXiv:2402.19427; hf",
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=1, epilogue=(),
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128, rnn_width=64,
        pattern=(BlockDef("rglru"), BlockDef("rglru"),
                 BlockDef("attn", window=8)),
        quant=MXFP8.replace(block_size=16),
    )
