"""granite-8b [dense]: llama-arch code model.

36L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=14336 vocab=49152
[arXiv:2405.04324; hf].
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        d_model=4096, vocab_size=49152,
        pattern=(BlockDef("attn"),), num_groups=36,
        num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, ffn_kind="swiglu",
        rope_theta=1e7, tied_embeddings=False,
        quant=MXFP8,
        source="arXiv:2405.04324; hf",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=2,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16),
    )
