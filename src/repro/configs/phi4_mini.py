"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA decoder.

32L d_model=3072 24H (GQA kv=8, head_dim 128) d_ff=8192 vocab=200064
[arXiv:2412.08905; hf].
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", family="dense",
        d_model=3072, vocab_size=200064,
        pattern=(BlockDef("attn"),), num_groups=32,
        num_heads=24, num_kv_heads=8, head_dim=128,
        d_ff=8192, ffn_kind="swiglu",
        quant=MXFP8,
        source="arXiv:2412.08905; hf",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=2,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        quant=MXFP8.replace(block_size=16),
    )
