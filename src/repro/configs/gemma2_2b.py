"""gemma2-2b [dense]: local/global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim 256) d_ff=9216 (GeGLU) vocab=256000
[arXiv:2408.00118; hf]. Local window 4096; attn softcap 50, final logit
softcap 30; pre+post sandwich norms. Global layers are full attention ->
not eligible for long_500k.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        d_model=2304, vocab_size=256000,
        pattern=(BlockDef("attn", window=4096), BlockDef("attn")),
        num_groups=13,
        num_heads=8, num_kv_heads=4, head_dim=256,
        d_ff=9216, ffn_kind="geglu",
        attn_softcap=50.0, logit_softcap=30.0, post_norms=True,
        scale_embeds_by_sqrt_dim=True,
        quant=MXFP8,
        source="arXiv:2408.00118; hf",
        sub_quadratic=False,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=1,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        pattern=(BlockDef("attn", window=8), BlockDef("attn")),
        quant=MXFP8.replace(block_size=16),
    )
