"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + fine-grained MoE.

27L d_model=2048 16H d_ff_expert=1408 vocab=102400, 64 routed experts top-6
+ 2 shared [arXiv:2405.04434; hf]. First layer uses a dense FFN (d_ff=10944),
the remaining 26 are MoE — expressed as prologue + scanned pattern.

Note: the assignment line lists both "MoE 64e top-6" and "2 shared+160
routed"; 160 routed is the full V2 — we implement the real V2-Lite
(64 routed + 2 shared, top-6). See DESIGN.md §4.
"""
from repro.core import MXFP8
from repro.nn import BlockDef, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        d_model=2048, vocab_size=102400,
        prologue=(BlockDef("mla", ffn="dense"),),
        pattern=(BlockDef("mla", ffn="moe"),),
        num_groups=26,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10944,  # dense first layer
        num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408,
        kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        quant=MXFP8,
        train_microbatches=1,
        source="arXiv:2405.04434; hf",
        sub_quadratic=False,  # MLA is full attention over latents
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, vocab_size=512, num_groups=2,
        num_heads=4, d_ff=128,
        num_experts=4, top_k=2, num_shared=1, d_ff_expert=64,
        kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        quant=MXFP8.replace(block_size=16),
    )
