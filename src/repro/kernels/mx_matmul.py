"""Pallas TPU kernel for fused MX matmul — the VMXDOTP analogue.

The paper's VMXDOTP instruction computes, per accumulator element,
``vd[i] += X(A) * X(B) * sum_j A[j] * B[ki+j]`` with scales applied in
hardware and no wide intermediate leaving the datapath. The TPU-native
reading (DESIGN.md §2) is a tiled matmul kernel where:

  * MX elements and E8M0 scales stream HBM -> VMEM in *compact* form
    (fp8 bytes, fp4 packed nibbles, uint8 scales) — this is the bandwidth
    win; no dequantized tensor ever exists in HBM;
  * decode + scale application happen in-register (VREG) on VMEM tiles:
    scales are folded into the operand tiles per MX block (exact — scales
    are powers of two), which is the kernel form of the paper's insight
    that an MX dot decomposes into sub-dot-products reusing block scales;
  * the MXU then runs a full-depth (bk >= 128) contraction at full systolic
    utilization — unlike a literal port of the 8-wide RVV instruction,
    which would starve a 128x128 systolic array (see DESIGN.md assumption
    deltas);
  * accumulation is f32 (spec) or bf16 (compact option) in the output tile,
    revisited across the K grid dimension.

Layouts (blocked/contraction axis last — the paper's column-major B):
  a_elems (M, K) fp8 | (M, K//2) packed fp4      a_scales (M, K/k) uint8
  b_elems (N, K) fp8 | (N, K//2) packed fp4      b_scales (N, K/k) uint8
  out     (M, N) acc_dtype

Software-defined block size: any k with k | bk (bk = K-tile). Validated
against ``ref.py`` in interpret mode; targets TPU MXU when compiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

from repro.core import formats as F

# ---------------------------------------------------------------------------
# In-kernel decode helpers (pure jnp: lower on TPU and in interpret mode)
# ---------------------------------------------------------------------------


def _decode_e8m0(e: jnp.ndarray) -> jnp.ndarray:
    """E8M0 -> f32 scale via exponent-field bitcast (paper's shift trick)."""
    e32 = e.astype(jnp.uint32)
    bits = jnp.where(e32 > 0, e32 << 23, jnp.uint32(0x00400000))
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _decode_fp4_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """Arithmetic E2M1 decode of 4-bit codes (no gather/table lookup)."""
    c = codes.astype(jnp.int32)
    sign = jnp.where((c & 0x8) != 0, -1.0, 1.0).astype(jnp.float32)
    e = (c >> 1) & 0x3
    m = (c & 0x1).astype(jnp.float32)
    pow2 = jnp.left_shift(1, jnp.maximum(e - 1, 0)).astype(jnp.float32)
    mag = jnp.where(e == 0, 0.5 * m, pow2 * (1.0 + 0.5 * m))
    return sign * mag


def _unpack_fp4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., n) packed bytes -> (..., 2n) f32 values (low nibble first)."""
    lo = _decode_fp4_codes(packed & 0xF)
    hi = _decode_fp4_codes((packed >> 4) & 0xF)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def _decode_fp6_codes(codes: jnp.ndarray, fmt_name: str) -> jnp.ndarray:
    """Arithmetic FP6 E3M2/E2M3 decode of 6-bit codes (no gather/table).

    Subnormals (exponent field 0) decode as m * 2^(1 - bias - mant); the
    normal-path power of two is built by integer shift, exact and
    Pallas-safe like :func:`_decode_fp4_codes`.
    """
    mant = 2 if fmt_name == "fp6_e3m2" else 3
    ebits = 3 if fmt_name == "fp6_e3m2" else 2
    bias = 2 ** (ebits - 1) - 1
    eps = 2.0 ** -mant
    min_sub = 2.0 ** (1 - bias - mant)
    c = codes.astype(jnp.int32)
    sign = jnp.where((c & 0x20) != 0, -1.0, 1.0).astype(jnp.float32)
    e = (c >> mant) & ((1 << ebits) - 1)
    m = (c & ((1 << mant) - 1)).astype(jnp.float32)
    # 2^(e - bias) for normals: shift against the worst negative exponent
    # (e3m2 min normal exp is -2) so the shift count stays non-negative
    pow2 = jnp.left_shift(1, jnp.maximum(e - 1, 0)).astype(jnp.float32) * (
        2.0 ** (1 - bias))
    mag = jnp.where(e == 0, min_sub * m, pow2 * (1.0 + eps * m))
    return sign * mag


def _unpack_fp6(packed: jnp.ndarray, fmt_name: str) -> jnp.ndarray:
    """(..., 3n) packed bytes -> (..., 4n) f32 values (low bits first)."""
    b = packed.astype(jnp.int32).reshape(*packed.shape[:-1], -1, 3)
    b0, b1, b2 = b[..., 0], b[..., 1], b[..., 2]
    c0 = b0 & 0x3F
    c1 = ((b0 >> 6) | (b1 << 2)) & 0x3F
    c2 = ((b1 >> 4) | (b2 << 4)) & 0x3F
    c3 = (b2 >> 2) & 0x3F
    codes = jnp.stack([c0, c1, c2, c3], axis=-1)
    vals = _decode_fp6_codes(codes, fmt_name)
    return vals.reshape(*packed.shape[:-1], -1)


def _decode_tile(tile: jnp.ndarray, fmt_name: str) -> jnp.ndarray:
    """Decode a VMEM tile of stored elements to f32 (in-register upcast)."""
    if fmt_name == "fp4_e2m1":
        return _unpack_fp4(tile)
    if fmt_name in ("fp6_e3m2", "fp6_e2m3"):
        return _unpack_fp6(tile, fmt_name)
    return tile.astype(jnp.float32)


def _fold_scales(vals: jnp.ndarray, scales_e8m0: jnp.ndarray, block_size: int):
    """Fold per-block power-of-two scales into decoded element rows (exact)."""
    r, bk = vals.shape
    nb = bk // block_size
    s = _decode_e8m0(scales_e8m0)  # (r, nb)
    return (vals.reshape(r, nb, block_size) * s[:, :, None]).reshape(r, bk)


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------


def _mx_matmul_kernel(
    a_ref, as_ref, b_ref, bs_ref, o_ref, *, fmt_name: str, block_size: int
):
    """Vector-vector variant: both operands MX (paper Eq. (2))."""
    kk = pl.program_id(2)
    a = _fold_scales(_decode_tile(a_ref[...], fmt_name), as_ref[...], block_size)
    b = _fold_scales(_decode_tile(b_ref[...], fmt_name), bs_ref[...], block_size)
    partial = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial.astype(o_ref.dtype)


def _mx_matmul_wo_kernel(
    a_ref, b_ref, bs_ref, o_ref, *, fmt_name: str, block_size: int
):
    """Vector-scalar variant (`vmxdotp.*f`): wide A x MX B (weight-only)."""
    kk = pl.program_id(2)
    a = a_ref[...].astype(jnp.float32)
    b = _fold_scales(_decode_tile(b_ref[...], fmt_name), bs_ref[...], block_size)
    partial = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------


def _elem_tile(bk: int, fmt_name: str) -> int:
    return bk // 2 if fmt_name == "fp4_e2m1" else bk


def mx_matmul_vv(
    a_elems,
    a_scales,
    b_elems,
    b_scales,
    *,
    fmt_name: str = "fp8_e4m3",
    block_size: int = 32,
    acc_dtype=jnp.float32,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
):
    """Tiled MX x MX matmul. Shapes per module docstring; returns (M, N)."""
    m = a_scales.shape[0]
    n = b_scales.shape[0]
    kb = a_scales.shape[1]
    k = kb * block_size
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk or bk % block_size:
        raise ValueError(f"tiling mismatch: {(m, n, k)} vs {(bm, bn, bk)}/{block_size}")
    ebk = _elem_tile(bk, fmt_name)
    nb = bk // block_size
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(
        _mx_matmul_kernel, fmt_name=fmt_name, block_size=block_size
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, ebk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, nb), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, ebk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, nb), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_elems, a_scales, b_elems, b_scales)


def mx_matmul_wo(
    a,
    b_elems,
    b_scales,
    *,
    fmt_name: str = "fp8_e4m3",
    block_size: int = 32,
    acc_dtype=jnp.float32,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
):
    """Tiled wide-A x MX-B matmul (weight-only). Returns (M, N)."""
    m, k = a.shape
    n = b_scales.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk or bk % block_size:
        raise ValueError(f"tiling mismatch: {(m, n, k)} vs {(bm, bn, bk)}/{block_size}")
    ebk = _elem_tile(bk, fmt_name)
    nb = bk // block_size
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(
        _mx_matmul_wo_kernel, fmt_name=fmt_name, block_size=block_size
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, ebk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, nb), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b_elems, b_scales)


# ---------------------------------------------------------------------------
# dgrad: dx = dy @ W^T with MX weights (training backward, weight-only path)
# ---------------------------------------------------------------------------


def _mx_dgrad_kernel(dy_ref, b_ref, bs_ref, o_ref, *, fmt_name: str,
                     block_size: int):
    """dx tile = dy (bm, bn) @ dequant(stored (bn, bk)). Accumulate over n."""
    nn = pl.program_id(2)
    dy = dy_ref[...].astype(jnp.float32)
    s = _fold_scales(_decode_tile(b_ref[...], fmt_name), bs_ref[...],
                     block_size)  # (bn, bk) dequantized W^T tile
    partial = jax.lax.dot_general(
        dy, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(nn == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial.astype(o_ref.dtype)


def mx_matmul_dgrad(
    dy,
    b_elems,
    b_scales,
    *,
    fmt_name: str = "fp8_e4m3",
    block_size: int = 32,
    out_dtype=jnp.float32,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
):
    """dx (M, K) = dy (M, N) @ dequant(W)^T for W stored (N, K) MX-blocked
    along K (the forward weight layout — no transposition needed: the
    stored layout IS W^T)."""
    m, n = dy.shape
    kb = b_scales.shape[1]
    k = kb * block_size
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk or bk % block_size:
        raise ValueError(f"tiling mismatch: {(m, n, k)} vs {(bm, bn, bk)}")
    ebk = _elem_tile(bk, fmt_name)
    nb = bk // block_size
    grid = (m // bm, k // bk, n // bn)
    kernel = functools.partial(_mx_dgrad_kernel, fmt_name=fmt_name,
                               block_size=block_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, nn: (i, nn)),
            pl.BlockSpec((bn, ebk), lambda i, j, nn: (nn, j)),
            pl.BlockSpec((bn, nb), lambda i, j, nn: (nn, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, nn: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dy, b_elems, b_scales)
