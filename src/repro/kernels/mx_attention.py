"""Pallas decode-attention kernels over an MX-quantized KV cache.

The serving-side application of VMXDOTP's insight: decode attention is
HBM-bandwidth-bound on the KV cache, so the cache is stored block-scaled
(fp8 elements + E8M0 scales along head_dim) and decoded **in-register** —
the wide K/V never exist in HBM. This is the vector-scalar instruction
family (`vmxdotp.*f`): one wide query operand against compact MX operands.

Three entry points, two cache layouts:

  * **contiguous** (`mx_attention_decode`): one (T, D) tile per (batch,
    kv-head), the fixed-slot serving layout. ``kpos``/``pos`` may be shared
    across the batch or per-sequence (continuous batching decodes requests
    at different positions in the same step).
  * **paged, two-pass** (`mx_attention_decode_paged`): the cache lives in a
    global page pool (num_pages, page_size, KVH, D) and each sequence owns
    a list of pages (its page-table row). `gather_kv_pages` is a Pallas
    kernel whose BlockSpec index maps read the scalar-prefetched page
    table — the DMA engine walks the page list directly, and the gathered
    operands stay **compact** (fp8/fp4 + E8M0). Decode then reuses the
    contiguous kernel bit-for-bit, which is what makes paged-vs-contiguous
    equivalence exact rather than approximate. Kept as the bit-exactness
    oracle; the engine no longer runs it.
  * **paged, single-pass fused** (`mx_attention_decode_fused` /
    `mx_attention_verify_fused`): the serve engine's hot path. One
    kernel, grid (B, KVH, num_kv_pages) with the page dimension
    innermost: the BlockSpec index maps read the scalar-prefetched page
    table, so each grid step DMAs one *compact* pool page tile straight
    into VMEM, dequantizes it in-register, and folds it into a
    flash-style online softmax (running max / rescaled partial sums in
    VMEM scratch). The gathered cache never exists — not wide, not even
    compact — and ``pl.when`` skips every page tile past
    ``ceil(seq_len / page_size)`` (the index map also re-points skipped
    steps at the last valid page, so the pipeline's DMA is elided by the
    revisit rule). Per-step work is proportional to *resident* tokens,
    not the padded table width. The verify variant runs Tq > 1 query
    tokens (speculative decoding's batched multi-token verify) through
    the *same* page walk with per-row causal intra-chunk masking — one
    tile DMA + dequant now feeds K+1 tokens of attention, the serving
    analogue of the paper's keep-the-MX-dataflow-dense argument; decode
    is its Tq == 1 case.

Per grid cell (batch b, kv-head h): load the query group (G, D) wide, the
K/V cache tiles compact, fold scales in VREGs, run the (G, ·) logits
matmul + masked f32 softmax + (G, D) output matmul.

Layouts:
  q        (B, KVH, G, D)    bf16/f32 (G = query heads per kv head)
  k_elems  (B, KVH, T, D)    fp8   k_scales (B, KVH, T, D//k) u8
  v_elems  (B, KVH, T, D)    fp8   v_scales (B, KVH, T, D//k) u8
  kpos     (T,) or (B, T)    i32 (absolute positions; -1 = empty slot)
  pos      scalar or (B,)    i32 (last valid position per sequence)
  out      (B, KVH, G, D)    f32
Paged pools: (NP, PS, KVH, D[/2]) elems, (NP, PS, KVH, D//k) scales,
page_table (B, P) i32 (entries < 0 = unallocated; rows are masked out via
seq_lens so garbage pages never contribute).

Element formats are threaded explicitly (``fmt_name``, as ``mx_matmul``
does) — fp4 packs two nibbles per stored byte, so the storage dtype alone
cannot name the format once more than one byte-backed format exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F

from .compat import CompilerParams
from .mx_matmul import _decode_e8m0, _decode_tile

NEG_INF = -2.0e38


def _check_fmt(elems, fmt_name: str, mixed: bool = False):
    """Fail loudly when ``fmt_name`` contradicts the storage dtype.

    fp4/fp6 pack sub-byte codes into uint8 bytes, so decoding them as fp8
    (or vice versa) produces shape garbage deep inside the kernel; catching
    the mismatch at the wrapper names the actual mistake. Mixed-format
    (tiered) pools are always raw uint8 bytes regardless of ``fmt_name``
    (which then names the hot/write format).
    """
    if mixed:
        if elems.dtype != jnp.uint8:
            raise ValueError(
                "mixed-format (tiered) pools must store raw uint8 bytes, "
                f"got {elems.dtype}")
        return
    stored_u8 = elems.dtype == jnp.uint8
    if stored_u8 != F.get_format(fmt_name).sub_byte:
        raise ValueError(
            f"fmt_name {fmt_name!r} does not match the cache storage dtype "
            f"{elems.dtype} (packed fp4/fp6 pools need a sub-byte fmt_name, "
            "fp8 pools an fp8 format)")


def _dequant_rows(elems, scales, fmt_name: str, block_size: int):
    """(T, D) stored elements + (T, D//k) scales -> (T, D) f32.

    ``fmt_name`` is threaded explicitly from the caller (never sniffed from
    the storage dtype): fp8 variants share decode-by-astype but fp4 stores
    two packed nibbles per byte, and any future byte-backed format would
    make dtype sniffing silently wrong.
    """
    t = elems.shape[0]
    vals = _decode_tile(elems, fmt_name)
    d = vals.shape[-1]
    nb = d // block_size
    s = _decode_e8m0(scales)  # (T, nb)
    return (vals.reshape(t, nb, block_size) * s[:, :, None]).reshape(t, d)


# ---------------------------------------------------------------------------
# mixed-format (tiered) pools: full-width uint8 rows, per-page format id
# ---------------------------------------------------------------------------

# the repack ladder (hot -> cold); also the default candidate set the mixed
# kernels compile decode branches for
MIXED_FMTS_DEFAULT = ("fp8_e4m3", "fp6_e3m2", "fp4_e2m1")


def _decode_u8_codes(codes, ebits: int, mant: int) -> jnp.ndarray:
    """Arithmetic decode of byte-stored fp8 codes (sign/exp/mant fields).

    Used only on mixed pools, where fp8 elements live as raw bytes rather
    than an fp8 dtype. Exact: the normal-path power of two comes from an
    f32 exponent-field bitcast and ``(1 + m * 2^-mant)`` is exact in f32,
    so the result is bit-identical to ``astype(f32)`` on the fp8 view
    (our encoders never emit inf/NaN codes — saturating RNE).
    """
    bias = 2 ** (ebits - 1) - 1
    c = codes.astype(jnp.int32)
    sign = jnp.where((c & 0x80) != 0, -1.0, 1.0).astype(jnp.float32)
    e = (c >> mant) & ((1 << ebits) - 1)
    m = (c & ((1 << mant) - 1)).astype(jnp.float32)
    eps = 2.0 ** -mant
    min_sub = 2.0 ** (1 - bias - mant)
    scale_bits = ((e - bias + 127) << 23).astype(jnp.uint32)
    scale = jax.lax.bitcast_convert_type(scale_bits, jnp.float32)
    mag = jnp.where(e == 0, min_sub * m, scale * (1.0 + eps * m))
    return sign * mag


def _decode_bytes_as(bytes_tile, fmt_name: str) -> jnp.ndarray:
    """Decode a (T, D) full-width uint8 row tile as ``fmt_name``.

    Tiered pool rows are D bytes wide regardless of element format; a
    narrower format's codes occupy the row *prefix* (fp8 = D bytes,
    fp6 = 3D/4, fp4 = D/2) and the tail bytes are dead. Always returns
    (T, D) f32 — one decoded value per logical element.
    """
    fmt = F.get_format(fmt_name)
    d = bytes_tile.shape[-1]
    w = fmt.storage_len(d)
    prefix = bytes_tile[..., :w]
    if fmt.name == "fp4_e2m1":
        from .mx_matmul import _unpack_fp4
        return _unpack_fp4(prefix)
    if fmt.bits == 6:
        from .mx_matmul import _unpack_fp6
        return _unpack_fp6(prefix, fmt.name)
    return _decode_u8_codes(prefix, fmt.exp_bits, fmt.mantissa_bits)


def _dequant_rows_mixed(bytes_tile, scales, fmt_id, mixed_fmts,
                        block_size: int):
    """(T, D) uint8 rows + scales + scalar page format id -> (T, D) f32.

    ``fmt_id`` is a traced scalar (the page's entry in the prefetched
    per-page format array); ``mixed_fmts`` is the *static* tuple of
    formats this kernel was compiled for. Every candidate decode runs and
    a scalar-predicate select picks the live one — branchless, the same
    shape every grid step, which is what keeps the page walk a single
    trace. The E8M0 scale fold is format-independent (scales are
    recomputed at repack time because emax differs per format).
    """
    t, d = bytes_tile.shape
    out = None
    for name in mixed_fmts:
        vals = _decode_bytes_as(bytes_tile, name)
        sel = fmt_id == F.FORMAT_IDS[name]
        out = vals if out is None else jnp.where(sel, vals, out)
    nb = d // block_size
    s = _decode_e8m0(scales)  # (T, nb)
    return (out.reshape(t, nb, block_size) * s[:, :, None]).reshape(t, d)


def _mx_attn_kernel(q_ref, ke_ref, ks_ref, ve_ref, vs_ref, kpos_ref,
                    pos_ref, o_ref, *, fmt_name: str, block_size: int,
                    softcap):
    """One (batch, kv_head) cell: full-T attention with masked f32 softmax."""
    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = _dequant_rows(ke_ref[0, 0], ks_ref[0, 0], fmt_name, block_size)
    v = _dequant_rows(ve_ref[0, 0], vs_ref[0, 0], fmt_name, block_size)
    d = q.shape[-1]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (d ** -0.5)  # (G, T)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    kpos = kpos_ref[0]
    pos = pos_ref[0]
    mask = (kpos <= pos) & (kpos >= 0)
    logits = jnp.where(mask[None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[0, 0] = (out / denom).astype(o_ref.dtype)


def mx_attention_decode(q, k_elems, k_scales, v_elems, v_scales, kpos, pos,
                        *, fmt_name: str = "fp8_e4m3", block_size: int = 32,
                        softcap=None, interpret: bool | None = None):
    """Decode attention against an MX-quantized cache. Returns (B,KVH,G,D).

    ``kpos`` may be (T,) shared or (B, T) per-sequence; ``pos`` a scalar or
    (B,) per-sequence — the ragged-batch form continuous batching needs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    _check_fmt(k_elems, fmt_name)
    b, kvh, g, d = q.shape
    t = k_elems.shape[2]
    nb = k_scales.shape[-1]
    kpos = jnp.asarray(kpos, jnp.int32)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos[None], (b, t))
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos[None], (b,))
    kernel = functools.partial(_mx_attn_kernel, fmt_name=fmt_name,
                               block_size=block_size, softcap=softcap)
    ed = k_elems.shape[-1]
    return pl.pallas_call(
        kernel,
        grid=(b, kvh),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k_elems, k_scales, v_elems, v_scales, kpos, pos)


# ---------------------------------------------------------------------------
# paged cache: page-table gather kernel + decode wrapper
# ---------------------------------------------------------------------------


def _gather_pages_kernel(pt_ref, ke_ref, ks_ref, ve_ref, vs_ref,
                         oke_ref, oks_ref, ove_ref, ovs_ref):
    """Copy one pool page tile into its contiguous slot (pure DMA shuffle).

    The interesting part is outside the body: the *input* BlockSpec index
    maps read the scalar-prefetched page table, so block (b, h, p) is DMA'd
    straight from pool page ``page_table[b, p]`` — the kernel never touches
    a wide value and never materializes an indirection on the compute units.
    """
    oke_ref[0, 0] = ke_ref[0, :, 0, :]
    oks_ref[0, 0] = ks_ref[0, :, 0, :]
    ove_ref[0, 0] = ve_ref[0, :, 0, :]
    ovs_ref[0, 0] = vs_ref[0, :, 0, :]


def gather_kv_pages(ke_pool, ks_pool, ve_pool, vs_pool, page_table,
                    *, interpret: bool | None = None):
    """Gather per-sequence K/V pages into contiguous compact caches.

    Pools: (NP, PS, KVH, ED) elems + (NP, PS, KVH, NB) scales.
    page_table: (B, P) int32, entries < 0 = unallocated (clamped to page 0;
    callers mask those rows via seq_lens).
    Returns (k_elems, k_scales, v_elems, v_scales) shaped (B, KVH, P*PS, ·).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    npages, ps, kvh, ed = ke_pool.shape
    nb = ks_pool.shape[-1]
    b, pmax = page_table.shape
    t = pmax * ps
    table = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, npages - 1)

    def pool_spec(width):
        return pl.BlockSpec((1, ps, 1, width),
                            lambda i, j, p, pt: (pt[i, p], 0, j, 0))

    def out_spec(width):
        return pl.BlockSpec((1, 1, ps, width),
                            lambda i, j, p, pt: (i, j, p, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, pmax),
        in_specs=[pool_spec(ed), pool_spec(nb), pool_spec(ed), pool_spec(nb)],
        out_specs=[out_spec(ed), out_spec(nb), out_spec(ed), out_spec(nb)],
    )
    return pl.pallas_call(
        _gather_pages_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, t, ed), ke_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, nb), ks_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, ed), ve_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, nb), vs_pool.dtype),
        ],
        interpret=interpret,
    )(table, ke_pool, ks_pool, ve_pool, vs_pool)


def mx_attention_decode_paged(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              interpret: bool | None = None):
    """Two-pass decode attention through a page table over an MX page pool.

    q: (B, KVH, G, D); pools per :func:`gather_kv_pages`; seq_lens (B,) =
    number of valid cache rows per sequence (query sits at seq_len - 1).
    Returns (B, KVH, G, D) f32, bit-identical to `mx_attention_decode` on
    the equivalent contiguous cache (same gather order, same kernel).

    This materializes the gathered *compact* cache (pass 1) before
    attending over the full padded table (pass 2) — kept as the exactness
    oracle for :func:`mx_attention_decode_fused`, which does both in one
    kernel and never materializes the gather.
    """
    ke, ks, ve, vs = gather_kv_pages(ke_pool, ks_pool, ve_pool, vs_pool,
                                     page_table, interpret=interpret)
    t = ke.shape[2]
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                            (q.shape[0], t))
    return mx_attention_decode(q, ke, ks, ve, vs, kpos, seq_lens - 1,
                               fmt_name=fmt_name, block_size=block_size,
                               softcap=softcap, interpret=interpret)


# ---------------------------------------------------------------------------
# single-pass fused paged decode: page-table walk + dequant + online softmax
# ---------------------------------------------------------------------------


def _quantize_rows(x, fmt_name: str, block_size: int):
    """(T, D) f32 -> (elements (T, ED) storage, scales (T, D//k) uint8).

    The exact math of ``core.quantize`` (f32 work dtype) inlined for the
    kernel: block amax -> E8M0 shared exponent (exponent-field floor-log2,
    no transcendentals and no lookup tables — Pallas rejects captured
    constant arrays) -> RNE saturating element cast. Bit-identical to the
    host cache-write path (``attention._quantize_kv_token``), which is
    what lets the fused prefill kernel's in-kernel page writes substitute
    for the host ``jnp.at[].set`` install without perturbing a single
    cache byte. Shares the arithmetic encoders with ``mx_quantize``'s
    kernel, the repo's other in-kernel quantizer.
    """
    from .mx_quantize import (_encode_fp4_codes, _encode_fp6_codes,
                              _floor_log2, _pack_fp4, _pack_fp6)

    fmt = F.get_format(fmt_name)
    t, d = x.shape
    nb = d // block_size
    blocked = x.reshape(t, nb, block_size)
    amax = jnp.max(jnp.abs(blocked), axis=-1)  # (t, nb)
    e_unb = _floor_log2(amax) - fmt.emax + F.E8M0_BIAS
    e_biased = jnp.clip(jnp.where(amax > 0, e_unb, 0), 0,
                        254).astype(jnp.uint8)
    scale = _decode_e8m0(e_biased)[..., None]
    ratio = jnp.where(scale > 0, blocked / scale, 0.0)
    ratio = jnp.clip(ratio, -fmt.max, fmt.max).reshape(t, d)
    if fmt.name == "fp4_e2m1":
        return _pack_fp4(_encode_fp4_codes(ratio)), e_biased
    if fmt.bits == 6:
        return _pack_fp6(_encode_fp6_codes(ratio, fmt)), e_biased
    return F.snap_to_fp8_grid(ratio, fmt).astype(fmt.storage_dtype), e_biased


#: row-tile budget for one flash-update step, in f32 elements of the
#: (rows, D) partial-output slab. Verify windows and prefill/ragged
#: chunks put ``num_q * G`` query rows in one cell; at large G*D (e.g.
#: head_dim 128 x G 8 x a multi-token window) the full (rows, D) slab
#: outgrows a comfortable VREG/VMEM working set, so the update walks
#: static row tiles instead. Tiling is exact: the online-softmax state
#: (m, l, acc) is per *query row*, so splitting rows changes no
#: accumulation order within any row.
_FLASH_ROW_TILE_ELEMS = 4096


def _flash_update(m_ref, l_ref, acc_ref, q, k, v, mask, softcap):
    """One online-softmax accumulation step over a (PS, D) key/value tile.

    Shared by the decode/verify, prefill, and ragged kernels so the
    accumulation order (and therefore the f32 rounding) of every fused
    path is identical by construction. ``q`` (R, D) f32, ``mask``
    (R, PS) bool. When R * D exceeds :data:`_FLASH_ROW_TILE_ELEMS` the
    update runs over static row tiles (see there) — bit-identical to the
    untiled form because every row's state is independent.
    """
    rows, d = q.shape
    tile = max(1, _FLASH_ROW_TILE_ELEMS // max(d, 1))
    for lo in range(0, rows, tile):
        sl = slice(lo, min(lo + tile, rows))
        s = jax.lax.dot_general(
            q[sl], k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * (d ** -0.5)  # (r, PS)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mrows = mask[sl]
        s = jnp.where(mrows, s, NEG_INF)
        m_prev = m_ref[sl]  # (r, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # the explicit mask (not just exp(NEG_INF - m)) guards the
        # all-masked tile: there m_new == NEG_INF and the difference is 0
        probs = jnp.where(mrows, jnp.exp(s - m_new), 0.0)  # (r, PS)
        l_ref[sl] = l_ref[sl] * alpha + jnp.sum(probs, axis=-1,
                                                keepdims=True)
        acc_ref[sl] = acc_ref[sl] * alpha + jax.lax.dot_general(
            probs, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[sl] = m_new


def _first_window_page(qpos_min, window, page_size: int):
    """Index of the first page any query can see under a sliding window.

    The earliest key row any of the chunk's queries attends is
    ``qpos_min - window + 1`` (the *oldest* query bounds it); pages wholly
    below that hold only masked keys, so both the kernel body and the
    BlockSpec index maps can skip them — the head-page analogue of the
    past-``seq_len`` tail skip. ``window is None`` disables the clamp.
    """
    if window is None:
        return 0
    return jnp.maximum((qpos_min - window + 1) // page_size, 0)


def _mx_attn_fused_kernel(*refs, page_size: int, fmt_name: str,
                          block_size: int, softcap, window, num_q: int,
                          group: int, mixed_fmts=None):
    """One page tile of one (batch, kv-head) cell, flash-style.

    Grid is (B, KVH, P) with P innermost ("arbitrary"), so the VMEM
    scratch — running max ``m``, running denominator ``l``, rescaled
    partial output ``acc`` — persists across the page walk of a cell and
    is re-initialized at page 0. ``pl.when`` skips tiles past
    ``ceil(seq_len / page_size)`` entirely: masked-out pages cost neither
    dequant nor MXU work, and their DMA is elided because the index map
    re-points them at the last valid page (unchanged block index = no
    refetch). The wide K/V tile exists only in VREGs.

    ``num_q`` query tokens per sequence share the page walk (speculative
    verify): the query tile holds ``num_q * group`` rows, rows
    ``[i*group, (i+1)*group)`` belonging to the query at absolute
    position ``seq_len - num_q + i``, and the causal mask is per-row —
    query ``i`` sees keys ``kpos <= seq_len - num_q + i`` (intra-chunk
    causality), so drafted tokens never attend to their own successors.
    ``num_q == 1`` is exactly the decode kernel this generalizes.

    Sliding-window head skip: pages wholly below the oldest query's
    window (``p < _first_window_page``) are skipped exactly like tail
    pages past ``seq_len`` — their keys are fully masked, so the body is
    predicated away (``visits`` counts only pages actually inside the
    window) and the index maps re-point them at the first in-window page
    so their DMA is elided by the revisit rule.

    Mixed-format (tiered) pools: when ``mixed_fmts`` is set, a third
    scalar-prefetch operand carries one format id per *pool page*, and
    the page's id — read through the same page-table walk the BlockSpec
    index maps use (``fmts[tbl[i, p]]``) — selects the dequant path for
    that grid step (branchless select over the static candidate set, so
    the walk stays one trace).
    """
    if mixed_fmts is None:
        (tbl_ref, lens_ref, q_ref, ke_ref, ks_ref, ve_ref, vs_ref,
         o_ref, visits_ref, m_ref, l_ref, acc_ref) = refs
        fmts_ref = None
    else:
        (tbl_ref, lens_ref, fmts_ref, q_ref, ke_ref, ks_ref, ve_ref, vs_ref,
         o_ref, visits_ref, m_ref, l_ref, acc_ref) = refs
    i = pl.program_id(0)
    p = pl.program_id(2)
    last = pl.num_programs(2) - 1

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        visits_ref[0, 0, 0] = 0

    seq_len = lens_ref[i]  # wrapper-clamped to >= num_q
    valid_pages = pl.cdiv(seq_len, page_size)
    first_page = _first_window_page(seq_len - num_q, window, page_size)

    @pl.when((p >= first_page) & (p < valid_pages))
    def _page():
        # the skip predicate's audit trail: counts page bodies actually
        # executed, so tests/benchmarks can assert work == resident pages
        # inside the window
        visits_ref[0, 0, 0] += 1
        q = q_ref[0, 0].astype(jnp.float32)  # (num_q * G, D)
        if mixed_fmts is None:
            k = _dequant_rows(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                              fmt_name, block_size)  # (PS, D)
            v = _dequant_rows(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                              fmt_name, block_size)
        else:
            fid = fmts_ref[tbl_ref[i, p]]
            k = _dequant_rows_mixed(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                                    fid, mixed_fmts, block_size)
            v = _dequant_rows_mixed(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                                    fid, mixed_fmts, block_size)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        rows = num_q * group
        # row r belongs to query index r // group; query i sits at
        # absolute position seq_len - num_q + i
        qpos = seq_len - num_q + jax.lax.broadcasted_iota(
            jnp.int32, (rows, 1), 0) // group
        mask = kpos <= qpos  # (R, PS)
        if window is not None:
            mask &= kpos > qpos - window
        _flash_update(m_ref, l_ref, acc_ref, q, k, v, mask, softcap)

    @pl.when(p == last)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def mx_attention_verify_fused(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              window=None, page_fmts=None, mixed_fmts=None,
                              debug_visits: bool = False,
                              interpret: bool | None = None):
    """Single-pass fused paged attention for ``Tq >= 1`` query tokens.

    The speculative-decoding verify kernel: the draft tokens' K/V have
    already been written into the sequence's pages, and all ``Tq``
    queries — the last accepted token plus the drafts, at absolute
    positions ``seq_len - Tq .. seq_len - 1`` — share one page walk.
    One Pallas kernel with grid (B, KVH, P): the BlockSpec index maps
    read the scalar-prefetched page table, each grid step dequantizes one
    compact fp8/fp4 + E8M0 pool page tile in-register exactly once for
    the whole chunk (this is the amortization speculative decoding buys:
    K+1 tokens of attention per page-tile DMA + dequant instead of one),
    and the softmax is accumulated online per query row in VMEM scratch.
    Causal intra-chunk masking is per row: query ``i`` attends keys
    ``kpos <= seq_len - Tq + i``, so a draft never sees its successors
    and row ``i``'s output is exactly what a one-token decode at position
    ``seq_len - Tq + i`` would compute.

    q: (B, KVH, Tq, G, D); pools (NP, PS, KVH, ED/NB); page_table (B, P)
    i32 (entries < 0 = unallocated, clamped); seq_lens (B,) valid cache
    rows per sequence *including* the chunk's own tokens (inactive rows
    may pass 0, clamped to Tq so every query position stays valid —
    garbage rows whose logits the host ignores). ``window`` masks keys
    at ``kpos <= qpos - window`` per query row. Returns
    (B, KVH, Tq, G, D) f32.

    ``debug_visits=True`` additionally returns a (B, KVH, 1) i32 count of
    page bodies actually executed per cell — the kernel always maintains
    it (one scalar store per visited tile), and tests/benchmarks assert
    it equals ``ceil(seq_lens / PS)`` exactly (minus, under a sliding
    window, the head pages wholly below the oldest query's window, which
    are skipped like tail pages — visits is then exactly the page count
    actually *inside* the window), making the page-skip predicate
    falsifiable on every backend (off-TPU, interpret-mode wall-clock
    cannot see the skip: the grid loop visits every cell and only the
    body is predicated away).

    ``page_fmts`` switches the kernel to mixed-format (tiered) pools:
    a (NP,) i32 array of per-*pool-page* format ids
    (:data:`repro.core.formats.FORMAT_IDS`), prefetched alongside the
    page table; the pools must then be full-width uint8 byte rows
    (narrower formats occupy the row prefix). ``mixed_fmts`` is the
    static candidate-format tuple compiled into the dequant select
    (default :data:`MIXED_FMTS_DEFAULT`).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mixed = page_fmts is not None
    _check_fmt(ke_pool, fmt_name, mixed=mixed)
    if mixed and mixed_fmts is None:
        mixed_fmts = MIXED_FMTS_DEFAULT
    mixed_fmts = tuple(mixed_fmts) if mixed else None
    b, kvh, tq, g, d = q.shape
    rows = tq * g
    npages, ps = ke_pool.shape[0], ke_pool.shape[1]
    ed = ke_pool.shape[-1]
    nb = ks_pool.shape[-1]
    pmax = page_table.shape[1]
    table = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, npages - 1)
    lens = jnp.maximum(jnp.asarray(seq_lens, jnp.int32), tq)
    qr = q.reshape(b, kvh, rows, d)

    def pool_spec(width):
        def imap(i, j, p, tbl, ln, *_fmts):
            # clamp skipped steps into the live page range: tail steps
            # (p >= valid) re-point at the last valid page, head steps
            # wholly below the sliding window at the first in-window
            # page (ln is wrapper-clamped >= Tq >= 1, so valid >= 1).
            # An unchanged block index means the pipeline elides the
            # DMA entirely, so skipped pages cost no HBM traffic.
            valid = pl.cdiv(ln[i], ps)
            first = _first_window_page(ln[i] - tq, window, ps)
            return (tbl[i, jnp.clip(p, first, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, ps, 1, width), imap)

    scalar_ops = [table, lens]
    if mixed:
        scalar_ops.append(jnp.asarray(page_fmts, jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_ops),
        grid=(b, kvh, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, *_: (i, j, 0, 0)),
            pool_spec(ed), pool_spec(nb), pool_spec(ed), pool_spec(nb),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, *_: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j, p, *_: (i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),  # running max m
            pltpu.VMEM((rows, 1), jnp.float32),  # running denominator l
            pltpu.VMEM((rows, d), jnp.float32),  # rescaled partial output
        ],
    )
    kernel = functools.partial(
        _mx_attn_fused_kernel, page_size=ps, fmt_name=fmt_name,
        block_size=block_size, softcap=softcap, window=window,
        num_q=tq, group=g, mixed_fmts=mixed_fmts)
    out, visits = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*scalar_ops, qr, ke_pool, ks_pool, ve_pool, vs_pool)
    out = out.reshape(b, kvh, tq, g, d)
    return (out, visits) if debug_visits else out


def mx_attention_decode_fused(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              window=None, page_fmts=None, mixed_fmts=None,
                              debug_visits: bool = False,
                              interpret: bool | None = None):
    """Single-pass fused paged decode attention (the serve-engine hot path).

    The ``Tq == 1`` case of :func:`mx_attention_verify_fused` (one kernel
    serves both paths — decode is just a verify chunk of one): the
    BlockSpec index maps read the scalar-prefetched page table, each grid
    step dequantizes one compact fp8/fp4 + E8M0 pool page tile
    in-register, and the softmax is accumulated online (flash-decoding)
    in VMEM scratch — no gathered cache, wide or compact, ever exists in
    HBM, and page tiles at or past ``ceil(seq_len / page_size)`` are
    skipped, so per-step work scales with resident tokens rather than
    the padded table.

    q: (B, KVH, G, D); pools (NP, PS, KVH, ED/NB); page_table (B, P) i32
    (entries < 0 = unallocated, clamped — rows past ``seq_lens`` never
    contribute); seq_lens (B,) valid cache rows per sequence (the query
    sits at seq_len - 1; inactive rows may pass 0, clamped to 1 so the
    denominator stays finite, matching the einsum path's pos=0 garbage
    rows whose logits the host ignores). ``window`` masks keys at
    ``kpos <= pos - window`` (sliding-window layers). Returns
    (B, KVH, G, D) f32; matches the two-pass/einsum f32 reference to
    online-softmax rounding (~1e-7, well inside 1e-5). ``debug_visits``
    as in :func:`mx_attention_verify_fused`.
    """
    res = mx_attention_verify_fused(
        q[:, :, None], ke_pool, ks_pool, ve_pool, vs_pool, page_table,
        seq_lens, fmt_name=fmt_name, block_size=block_size,
        softcap=softcap, window=window, page_fmts=page_fmts,
        mixed_fmts=mixed_fmts, debug_visits=debug_visits,
        interpret=interpret)
    if debug_visits:
        out, visits = res
        return out[:, :, 0], visits
    return res[:, :, 0]


# ---------------------------------------------------------------------------
# single-pass fused chunked prefill: page walk + quantize-write + attention
# ---------------------------------------------------------------------------


def _mx_attn_prefill_kernel(*refs, page_size: int, fmt_name: str,
                            block_size: int, softcap, window, chunk: int,
                            group: int, mixed_fmts=None):
    """One page tile of one (batch, kv-head) prefill cell.

    The page walk splits into three regions per cell:

      * ``p < c0`` (resident pages, written by earlier chunks / a shared
        prefix): read the compact pool tile, dequantize in-register, fold
        into the online softmax — exactly the verify kernel's body.
      * ``c0 <= p < valid`` (this chunk's own pages): quantize the
        chunk's wide K/V page slice in-register (``_quantize_rows``, the
        exact ``core.quantize`` math), store the compact tile to the
        sequence's pool page through the *output* index map, and attend
        over the in-register dequantized snap — the same bytes any later
        reader will load, so prefill, decode and verify agree
        bit-for-bit. The wide K/V rows never touch HBM beyond the
        one-chunk projection output.
      * ``p >= valid`` / ``p < first`` (past the resident rows / wholly
        below the sliding window): body predicated away, DMA elided by
        index-map clamping.

    Chunk alignment contract (enforced by the nn wrapper): chunk starts
    are page-aligned and the chunk covers whole pages, so every visited
    page is *either* fully resident *or* fully owned by this chunk —
    never a blend. The last chunk of a prompt is padded up to the fixed
    chunk length; ``seq_len`` counts only the real rows, so wholly-padded
    pages are never written and the partial last page's padding rows are
    dead by position masking (exactly like rejected speculative drafts).

    Mixed-format (tiered) pools (``mixed_fmts`` set): resident pages
    dequantize through the per-page format id (fourth scalar-prefetch
    operand, indexed via the page table exactly like the verify kernel);
    chunk pages are always written in the hot format ``fmt_name`` (an
    fp8 — the engine marks freshly written pages hot) with the fp8 bytes
    bitcast into the full-width uint8 rows.
    """
    if mixed_fmts is None:
        (tbl_ref, start_ref, lens_ref, q_ref, kc_ref, vc_ref,
         ke_ref, ks_ref, ve_ref, vs_ref, o_ref,
         oke_ref, oks_ref, ove_ref, ovs_ref, visits_ref,
         m_ref, l_ref, acc_ref) = refs
        fmts_ref = None
    else:
        (tbl_ref, start_ref, lens_ref, fmts_ref, q_ref, kc_ref, vc_ref,
         ke_ref, ks_ref, ve_ref, vs_ref, o_ref,
         oke_ref, oks_ref, ove_ref, ovs_ref, visits_ref,
         m_ref, l_ref, acc_ref) = refs
    i = pl.program_id(0)
    p = pl.program_id(2)
    last = pl.num_programs(2) - 1

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        visits_ref[0, 0, 0] = 0

    start = start_ref[i]  # chunk start row, page-aligned
    seq_len = lens_ref[i]  # resident rows incl. this chunk's real tokens
    c0 = start // page_size
    valid_pages = pl.cdiv(seq_len, page_size)
    first_page = _first_window_page(start, window, page_size)

    def _attend_tile(k, v):
        q = q_ref[0, 0].astype(jnp.float32)  # (chunk * G, D)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        rows = chunk * group
        # row r belongs to chunk query r // group at absolute position
        # start + r // group (intra-chunk causality per row)
        qpos = start + jax.lax.broadcasted_iota(
            jnp.int32, (rows, 1), 0) // group
        mask = kpos <= qpos  # (R, PS)
        if window is not None:
            mask &= kpos > qpos - window
        _flash_update(m_ref, l_ref, acc_ref, q, k, v, mask, softcap)

    @pl.when((p >= first_page) & (p < c0))
    def _resident_page():
        visits_ref[0, 0, 0] += 1
        if mixed_fmts is None:
            k = _dequant_rows(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                              fmt_name, block_size)  # (PS, D)
            v = _dequant_rows(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                              fmt_name, block_size)
        else:
            fid = fmts_ref[tbl_ref[i, p]]
            k = _dequant_rows_mixed(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                                    fid, mixed_fmts, block_size)
            v = _dequant_rows_mixed(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                                    fid, mixed_fmts, block_size)
        _attend_tile(k, v)

    @pl.when((p >= c0) & (p < valid_pages))
    def _chunk_page():
        visits_ref[0, 0, 0] += 1
        kw = kc_ref[0, :, 0, :].astype(jnp.float32)  # (PS, D) wide
        vw = vc_ref[0, :, 0, :].astype(jnp.float32)
        kq_e, kq_s = _quantize_rows(kw, fmt_name, block_size)
        vq_e, vq_s = _quantize_rows(vw, fmt_name, block_size)
        if mixed_fmts is None:
            oke_ref[0, :, 0, :] = kq_e
            ove_ref[0, :, 0, :] = vq_e
        else:
            # hot-format fp8 bytes into the full-width uint8 rows
            oke_ref[0, :, 0, :] = jax.lax.bitcast_convert_type(
                kq_e, jnp.uint8)
            ove_ref[0, :, 0, :] = jax.lax.bitcast_convert_type(
                vq_e, jnp.uint8)
        oks_ref[0, :, 0, :] = kq_s
        ovs_ref[0, :, 0, :] = vq_s
        # attend over the in-register dequantized snap — identical bytes
        # (and therefore identical f32 values) to what a later page read
        # would produce, without a round trip through HBM
        _attend_tile(_dequant_rows(kq_e, kq_s, fmt_name, block_size),
                     _dequant_rows(vq_e, vq_s, fmt_name, block_size))

    @pl.when(p == last)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def mx_attention_prefill_fused(q, k_chunk, v_chunk, ke_pool, ks_pool,
                               ve_pool, vs_pool, page_table, chunk_start,
                               seq_lens, *, fmt_name: str = "fp8_e4m3",
                               block_size: int = 32, softcap=None,
                               window=None, page_fmts=None, mixed_fmts=None,
                               debug_visits: bool = False,
                               interpret: bool | None = None):
    """Single-pass fused chunked paged prefill (quantize-into-pages).

    One prompt chunk of ``C`` tokens runs against the MX page pool in a
    single Pallas kernel: the chunk's queries attend over every page
    written so far *plus* the chunk itself (per-row causal masking, the
    prefill generalization of :func:`mx_attention_verify_fused`'s draft
    chunk), and the chunk's own K/V is quantized in-register and written
    straight into its pool pages through aliased outputs whose index maps
    walk the scalar-prefetched page table. No wide prefill cache is ever
    materialized and no separate install pass runs: per-chunk work scales
    with the tokens resident so far, and the serve engine's jitted trace
    population for prefill is O(1) fixed chunk shapes.

    Layouts::

      q          (B, KVH, C, G, D)  wide chunk queries (RoPE'd)
      k_chunk    (B, C, KVH, D)     wide chunk keys (RoPE'd)
      v_chunk    (B, C, KVH, D)     wide chunk values
      pools      (NP, PS, KVH, ED/NB) as the decode/verify kernels
      page_table (B, P) i32         entries < 0 = unallocated (clamped)
      chunk_start (B,) i32          chunk's first absolute row; must be
                                    page-aligned (see alignment contract)
      seq_lens   (B,) i32           resident rows *including* the chunk's
                                    real tokens, i.e. chunk_start + the
                                    number of non-padding chunk rows

    Alignment contract (the nn layer enforces it statically): ``C`` is a
    page multiple and ``chunk_start`` is page-aligned, so every page is
    either fully resident or fully this chunk's — the kernel never blends
    pool rows and chunk rows inside one tile. The last chunk of a prompt
    is padded up to ``C``; ``seq_lens`` counts only real rows, so pages
    wholly past ``seq_lens`` are neither written nor read, and padding
    rows sharing the final partial page are written as garbage that every
    reader masks by position (the same dead-row contract as rejected
    speculative drafts). Padding queries produce garbage output rows the
    caller ignores.

    Returns ``(out (B, KVH, C, G, D) f32, (ke, ks, ve, vs) updated
    pools)`` — the pool outputs alias the inputs (in-place page writes
    under jit donation). With ``debug_visits=True`` additionally returns
    the (B, KVH, 1) executed-page counter; it must equal
    ``ceil(seq_lens / PS)`` minus the pages wholly below the sliding
    window, exactly as in the decode/verify kernels.

    When ``B > 1``, rows must not share pages between one row's chunk
    range and another row's read range (the serve engine prefills one
    sequence per call; batched calls are for tests/benchmarks with
    disjoint tables).

    When ``B > 1`` every row's chunk pages must be freshly allocated
    (never shared), which the engine guarantees — chunk pages are new
    allocations by construction. Same-shape chunks from *different*
    concurrently-prefilling sequences may therefore batch into one
    dispatch (each row reads only its own table row; resident pages may
    be COW-shared across rows since they are read-only here).

    ``page_fmts``/``mixed_fmts`` switch to mixed-format (tiered) pools
    exactly as in :func:`mx_attention_verify_fused`; ``fmt_name`` must
    then be an fp8 (the hot format freshly written pages get).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mixed = page_fmts is not None
    _check_fmt(ke_pool, fmt_name, mixed=mixed)
    if mixed:
        if mixed_fmts is None:
            mixed_fmts = MIXED_FMTS_DEFAULT
        mixed_fmts = tuple(mixed_fmts)
        if F.get_format(fmt_name).bits != 8:
            raise ValueError(
                "tiered prefill writes chunk pages in the hot format, "
                f"which must be an fp8; got {fmt_name!r}")
    else:
        mixed_fmts = None
    b, kvh, c, g, d = q.shape
    rows = c * g
    npages, ps = ke_pool.shape[0], ke_pool.shape[1]
    ed = ke_pool.shape[-1]
    nb = ks_pool.shape[-1]
    pmax = page_table.shape[1]
    if c % ps != 0:
        raise ValueError(
            f"chunk length {c} must be a whole number of pages "
            f"(page_size={ps}): a partial chunk page would blend resident "
            "and chunk rows inside one tile")
    cps = c // ps  # chunk pages (static)
    table = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, npages - 1)
    start = jnp.asarray(chunk_start, jnp.int32)
    # at least one real token per chunk, at most the whole chunk
    lens = jnp.clip(jnp.asarray(seq_lens, jnp.int32), start + 1, start + c)
    qr = q.reshape(b, kvh, rows, d)

    def pool_in_spec(width):
        def imap(i, j, p, tbl, st, ln, *_fmts):
            # resident pages map to themselves; chunk pages (whose pool
            # bytes are stale — the kernel writes them this pass) and
            # below-window head pages re-point at the nearest live
            # resident page so their DMA is elided by the revisit rule.
            # A chunk starting at row 0 has no resident pages at all;
            # the clamp then parks every read on the first chunk page's
            # pool slot, whose bytes the body never uses.
            c0 = st[i] // ps
            first = _first_window_page(st[i], window, ps)
            hi = jnp.maximum(c0 - 1, first)
            return (tbl[i, jnp.clip(p, first, hi)], 0, j, 0)
        return pl.BlockSpec((1, ps, 1, width), imap)

    def chunk_in_spec():
        def imap(i, j, p, tbl, st, ln, *_fmts):
            # page p of the walk is chunk page p - c0; steps outside the
            # chunk range clamp to its ends (same-index revisit = no DMA)
            return (i, jnp.clip(p - st[i] // ps, 0, cps - 1), j, 0)
        return pl.BlockSpec((1, ps, 1, d), imap)

    def pool_out_spec(width):
        def imap(i, j, p, tbl, st, ln, *_fmts):
            # steps below the chunk park on the first chunk page (it is
            # written before the index ever changes), steps past the
            # last written page park on it (flushed once at cell end)
            c0 = st[i] // ps
            valid = pl.cdiv(ln[i], ps)
            return (tbl[i, jnp.clip(p, c0, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, ps, 1, width), imap)

    scalar_ops = [table, start, lens]
    if mixed:
        scalar_ops.append(jnp.asarray(page_fmts, jnp.int32))
    ns = len(scalar_ops)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=ns,
        grid=(b, kvh, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, *_: (i, j, 0, 0)),
            chunk_in_spec(), chunk_in_spec(),
            pool_in_spec(ed), pool_in_spec(nb),
            pool_in_spec(ed), pool_in_spec(nb),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, *_: (i, j, 0, 0)),
            pool_out_spec(ed), pool_out_spec(nb),
            pool_out_spec(ed), pool_out_spec(nb),
            pl.BlockSpec((1, 1, 1), lambda i, j, p, *_: (i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),  # running max m
            pltpu.VMEM((rows, 1), jnp.float32),  # running denominator l
            pltpu.VMEM((rows, d), jnp.float32),  # rescaled partial output
        ],
    )
    kernel = functools.partial(
        _mx_attn_prefill_kernel, page_size=ps, fmt_name=fmt_name,
        block_size=block_size, softcap=softcap, window=window,
        chunk=c, group=g, mixed_fmts=mixed_fmts)
    out, oke, oks, ove, ovs, visits = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, rows, d), jnp.float32),
            jax.ShapeDtypeStruct(ke_pool.shape, ke_pool.dtype),
            jax.ShapeDtypeStruct(ks_pool.shape, ks_pool.dtype),
            jax.ShapeDtypeStruct(ve_pool.shape, ve_pool.dtype),
            jax.ShapeDtypeStruct(vs_pool.shape, vs_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, 1), jnp.int32),
        ],
        # pools update in place (operand indices count the scalar-prefetch
        # operands, then q, k_chunk, v_chunk, then the four pools)
        input_output_aliases={ns + 3 + k: 1 + k for k in range(4)},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*scalar_ops, qr, k_chunk, v_chunk,
      ke_pool, ks_pool, ve_pool, vs_pool)
    out = out.reshape(b, kvh, c, g, d)
    pools = (oke, oks, ove, ovs)
    return (out, pools, visits) if debug_visits else (out, pools)


# ---------------------------------------------------------------------------
# single-pass fused ragged engine step: decode + verify + prefill-chunk rows
# in one page walk, with the write window quantized in-kernel
# ---------------------------------------------------------------------------


def _mx_attn_ragged_kernel(*refs, page_size: int, fmt_name: str,
                           block_size: int, softcap, window, width: int,
                           group: int, mixed_fmts=None):
    """One page tile of one (row, kv-head) ragged-step cell.

    The generalization that lets decode rows (1 new token), speculative
    verify windows (1 + K new tokens), and prefill chunks (up to W new
    tokens) coexist in ONE grid: each row carries only ``(row_start,
    seq_len)`` scalars — ``row_start`` is where this step's new tokens
    begin and ``seq_len = row_start + n_new`` where they end — and the
    page walk splits into three regions per cell:

      * ``first <= p < w0`` (resident pages, ``w0 = row_start // PS``):
        read the compact pool tile, dequantize in-register, fold into the
        online softmax — exactly the verify kernel's body.
      * ``w0 <= p < valid`` (the row's *write window*): the step's wide
        new K/V rows are scattered onto page-row positions by an exact
        one-hot (PS, W) f32 matmul (each product is 1.0 * x or 0.0 * x,
        so the gather is bit-exact), quantized in-register
        (``_quantize_rows``, the same math as the host install path),
        merged with the page's existing codes row-by-row in the *code*
        domain (``where(row_start <= kpos < seq_len, new, old)`` — rows
        outside the window keep their stored bytes untouched), written
        back through the aliased pool outputs, and attended over the
        merged dequantized tile. This is what removes the split path's
        per-token host ``.at[].set`` HBM round-trip: unlike the prefill
        kernel, the window need NOT be page-aligned — a decode token in
        the middle of a half-full page merges into it in-register.
      * ``p < first`` / ``p >= valid``: body predicated away, DMA elided
        by index-map clamping (the decode/verify kernels' skip rule).

    Query rows: the cell holds ``W * G`` query rows; row r belongs to
    query ``t = r // G`` at absolute position ``row_start + min(t,
    n_new - 1)`` — padding queries (t >= n_new: decode rows in a W > 1
    batch, the tail of a final partial chunk) clamp onto the last real
    position, producing duplicate garbage output rows the host ignores,
    while real rows see exactly the mask the split kernels apply.

    Inactive slots pass ``row_start = 0, seq_len = 1`` with an
    all-negative table row: the wrapper maps negative entries onto the
    pool's LAST page, which callers must reserve as a scratch ("trash")
    page — inactive rows then read and write only that page and no live
    page is ever touched by a dead row.

    Mixed-format (tiered) pools: resident pages dequantize through the
    per-page format id; write-window pages are guaranteed base-fp8 by
    the engine (freshly written pages are hot), so old and new codes
    merge in one format and the fp8 bytes bitcast into the full-width
    uint8 rows exactly as in the prefill kernel.
    """
    if mixed_fmts is None:
        (tbl_ref, start_ref, lens_ref, q_ref, kn_ref, vn_ref,
         ke_ref, ks_ref, ve_ref, vs_ref, o_ref,
         oke_ref, oks_ref, ove_ref, ovs_ref, visits_ref,
         m_ref, l_ref, acc_ref) = refs
        fmts_ref = None
    else:
        (tbl_ref, start_ref, lens_ref, fmts_ref, q_ref, kn_ref, vn_ref,
         ke_ref, ks_ref, ve_ref, vs_ref, o_ref,
         oke_ref, oks_ref, ove_ref, ovs_ref, visits_ref,
         m_ref, l_ref, acc_ref) = refs
    i = pl.program_id(0)
    p = pl.program_id(2)
    last = pl.num_programs(2) - 1

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        visits_ref[0, 0, 0] = 0

    start = start_ref[i]  # first new-token row of this step
    seq_len = lens_ref[i]  # resident rows incl. this step's new tokens
    n_new = seq_len - start
    w0 = start // page_size
    valid_pages = pl.cdiv(seq_len, page_size)
    first_page = _first_window_page(start, window, page_size)

    def _attend_tile(k, v):
        q = q_ref[0, 0].astype(jnp.float32)  # (W * G, D)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        rows = width * group
        # row r belongs to query t = r // G at absolute position
        # start + min(t, n_new - 1): real queries get exactly the split
        # kernels' positions, padding queries clamp onto the last real one
        t = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // group
        qpos = start + jnp.minimum(t, n_new - 1)
        mask = kpos <= qpos  # (R, PS)
        if window is not None:
            mask &= kpos > qpos - window
        _flash_update(m_ref, l_ref, acc_ref, q, k, v, mask, softcap)

    @pl.when((p >= first_page) & (p < w0))
    def _resident_page():
        visits_ref[0, 0, 0] += 1
        if mixed_fmts is None:
            k = _dequant_rows(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                              fmt_name, block_size)  # (PS, D)
            v = _dequant_rows(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                              fmt_name, block_size)
        else:
            fid = fmts_ref[tbl_ref[i, p]]
            k = _dequant_rows_mixed(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                                    fid, mixed_fmts, block_size)
            v = _dequant_rows_mixed(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                                    fid, mixed_fmts, block_size)
        _attend_tile(k, v)

    @pl.when((p >= w0) & (p < valid_pages))
    def _write_page():
        visits_ref[0, 0, 0] += 1
        kw = kn_ref[0, :, 0, :].astype(jnp.float32)  # (W, D) wide new rows
        vw = vn_ref[0, :, 0, :].astype(jnp.float32)
        # scatter new row t onto page row j where start + t == p*PS + j:
        # a one-hot f32 matmul (products are 1.0*x or 0.0*x — exact), so
        # page rows outside [start, seq_len) gather exact zeros that the
        # merge below discards anyway
        jrow = jax.lax.broadcasted_iota(
            jnp.int32, (page_size, width), 0)  # page row
        tcol = jax.lax.broadcasted_iota(
            jnp.int32, (page_size, width), 1)  # new-row index
        kpos_rows = p * page_size + jrow[:, :1]  # (PS, 1)
        onehot = ((start + tcol) == (p * page_size + jrow)
                  ).astype(jnp.float32)  # (PS, W)
        k_page = jax.lax.dot_general(
            onehot, kw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (PS, D)
        v_page = jax.lax.dot_general(
            onehot, vw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        kq_e, kq_s = _quantize_rows(k_page, fmt_name, block_size)
        vq_e, vq_s = _quantize_rows(v_page, fmt_name, block_size)
        if mixed_fmts is not None:
            kq_e = jax.lax.bitcast_convert_type(kq_e, jnp.uint8)
            vq_e = jax.lax.bitcast_convert_type(vq_e, jnp.uint8)
        # merge in the CODE domain: in-window page rows take this step's
        # freshly quantized codes, the rest keep their stored bytes —
        # then write the whole tile back through the aliased output
        in_w = (kpos_rows >= start) & (kpos_rows < seq_len)  # (PS, 1)
        k_codes = jnp.where(in_w, kq_e, ke_ref[0, :, 0, :])
        v_codes = jnp.where(in_w, vq_e, ve_ref[0, :, 0, :])
        k_scales = jnp.where(in_w, kq_s, ks_ref[0, :, 0, :])
        v_scales = jnp.where(in_w, vq_s, vs_ref[0, :, 0, :])
        oke_ref[0, :, 0, :] = k_codes
        ove_ref[0, :, 0, :] = v_codes
        oks_ref[0, :, 0, :] = k_scales
        ovs_ref[0, :, 0, :] = v_scales
        # attend over the merged tile — identical bytes (and therefore
        # identical f32 values) to what the split path's separate host
        # install + page re-read would produce
        if mixed_fmts is None:
            _attend_tile(
                _dequant_rows(k_codes, k_scales, fmt_name, block_size),
                _dequant_rows(v_codes, v_scales, fmt_name, block_size))
        else:
            fid = fmts_ref[tbl_ref[i, p]]
            _attend_tile(
                _dequant_rows_mixed(k_codes, k_scales, fid, mixed_fmts,
                                    block_size),
                _dequant_rows_mixed(v_codes, v_scales, fid, mixed_fmts,
                                    block_size))

    @pl.when(p == last)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def mx_attention_ragged_fused(q, k_new, v_new, ke_pool, ks_pool, ve_pool,
                              vs_pool, page_table, row_start, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              window=None, page_fmts=None, mixed_fmts=None,
                              debug_visits: bool = False,
                              interpret: bool | None = None):
    """One-dispatch ragged engine step over the MX page pool.

    The single kernel behind ``ServeConfig.step_mode="ragged"``: every
    engine-step row — a plain decode token, a speculative verify window,
    or an in-flight prefill chunk — is one grid row of the SAME
    ``(R, KVH, P)`` scalar-prefetch page walk, distinguished only by its
    ``(row_start, seq_len)`` metadata. Each row's new K/V rows are
    quantized and merged into its pages *inside* the kernel through
    aliased pool outputs (see :func:`_mx_attn_ragged_kernel`), so a
    steady-state mixed batch costs exactly one device dispatch and the
    decode/verify paths stop paying a separate 1-row ``.at[].set`` HBM
    round-trip per token.

    Layouts::

      q          (R, KVH, W, G, D)  wide step queries (RoPE'd); W is the
                                    static row width = max over modes of
                                    the per-row new-token count
      k_new      (R, W, KVH, D)     wide new keys (RoPE'd)
      v_new      (R, W, KVH, D)     wide new values
      pools      (NP, PS, KVH, ED/NB) as the decode/verify kernels
      page_table (R, P) i32         entries < 0 map to pool page NP - 1
      row_start  (R,) i32           first absolute row this step writes
      seq_lens   (R,) i32           row_start + n_new (n_new in [1, W])

    Unlike the prefill kernel, ``row_start`` need NOT be page-aligned —
    the write window merges into partially filled pages row-by-row in
    the code domain. Rows only ever write pages in ``[row_start // PS,
    ceil(seq_len / PS))`` and the engine guarantees those pages are
    exclusively owned (COW for decode/verify windows, fresh allocations
    for chunk pages), so concurrent rows never write the same page.

    Trash-page contract: negative table entries (inactive slots, table
    tails) are mapped to the pool's **last** page, which the caller must
    reserve as scratch — the ragged engine allocates ``num_pages + 1``
    physical pages and never hands out the last one. Inactive rows
    (``row_start = 0, seq_len = 1``) then write their garbage there.

    Returns ``(out (R, KVH, W, G, D) f32, (ke, ks, ve, vs) updated
    pools)`` — pool outputs alias the inputs. ``debug_visits=True``
    additionally returns the (R, KVH, 1) executed-page counter, exactly
    ``ceil(seq_lens / PS)`` minus sliding-window head pages as in the
    other fused kernels. ``page_fmts``/``mixed_fmts`` switch to
    mixed-format (tiered) pools; ``fmt_name`` must then be an fp8 (the
    hot format) and every write-window page must already be base-fp8
    (the engine's hot-write invariant).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mixed = page_fmts is not None
    _check_fmt(ke_pool, fmt_name, mixed=mixed)
    if mixed:
        if mixed_fmts is None:
            mixed_fmts = MIXED_FMTS_DEFAULT
        mixed_fmts = tuple(mixed_fmts)
        if F.get_format(fmt_name).bits != 8:
            raise ValueError(
                "tiered ragged steps write the window in the hot format, "
                f"which must be an fp8; got {fmt_name!r}")
    else:
        mixed_fmts = None
    r, kvh, w, g, d = q.shape
    rows = w * g
    npages, ps = ke_pool.shape[0], ke_pool.shape[1]
    ed = ke_pool.shape[-1]
    nb = ks_pool.shape[-1]
    pmax = page_table.shape[1]
    table = jnp.asarray(page_table, jnp.int32)
    # negative entries -> the reserved trash page (see docstring); live
    # entries clamp defensively into the pool
    table = jnp.where(table < 0, npages - 1,
                      jnp.clip(table, 0, npages - 1))
    start = jnp.asarray(row_start, jnp.int32)
    # at least one new token per row, at most the whole width
    lens = jnp.clip(jnp.asarray(seq_lens, jnp.int32), start + 1, start + w)
    qr = q.reshape(r, kvh, rows, d)

    def pool_in_spec(width_):
        def imap(i, j, p, tbl, st, ln, *_fmts):
            # every page in [first, valid) is read — resident pages to
            # attend, write-window pages to merge with; skipped steps
            # clamp into that range so their DMA is elided
            valid = pl.cdiv(ln[i], ps)
            first = _first_window_page(st[i], window, ps)
            return (tbl[i, jnp.clip(p, first, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, ps, 1, width_), imap)

    def new_in_spec():
        # the step's wide new rows: one (W, D) slab per (row, head),
        # constant across the page walk (fetched once per cell)
        return pl.BlockSpec((1, w, 1, d),
                            lambda i, j, p, *_: (i, 0, j, 0))

    def pool_out_spec(width_):
        def imap(i, j, p, tbl, st, ln, *_fmts):
            # steps below the write window park on its first page (it is
            # written before the index ever changes), steps past the
            # last written page park on it (flushed once at cell end)
            w0 = st[i] // ps
            valid = pl.cdiv(ln[i], ps)
            return (tbl[i, jnp.clip(p, w0, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, ps, 1, width_), imap)

    scalar_ops = [table, start, lens]
    if mixed:
        scalar_ops.append(jnp.asarray(page_fmts, jnp.int32))
    ns = len(scalar_ops)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=ns,
        grid=(r, kvh, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, *_: (i, j, 0, 0)),
            new_in_spec(), new_in_spec(),
            pool_in_spec(ed), pool_in_spec(nb),
            pool_in_spec(ed), pool_in_spec(nb),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, *_: (i, j, 0, 0)),
            pool_out_spec(ed), pool_out_spec(nb),
            pool_out_spec(ed), pool_out_spec(nb),
            pl.BlockSpec((1, 1, 1), lambda i, j, p, *_: (i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),  # running max m
            pltpu.VMEM((rows, 1), jnp.float32),  # running denominator l
            pltpu.VMEM((rows, d), jnp.float32),  # rescaled partial output
        ],
    )
    kernel = functools.partial(
        _mx_attn_ragged_kernel, page_size=ps, fmt_name=fmt_name,
        block_size=block_size, softcap=softcap, window=window,
        width=w, group=g, mixed_fmts=mixed_fmts)
    out, oke, oks, ove, ovs, visits = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, kvh, rows, d), jnp.float32),
            jax.ShapeDtypeStruct(ke_pool.shape, ke_pool.dtype),
            jax.ShapeDtypeStruct(ks_pool.shape, ks_pool.dtype),
            jax.ShapeDtypeStruct(ve_pool.shape, ve_pool.dtype),
            jax.ShapeDtypeStruct(vs_pool.shape, vs_pool.dtype),
            jax.ShapeDtypeStruct((r, kvh, 1), jnp.int32),
        ],
        # pools update in place (operand indices count the scalar-prefetch
        # operands, then q, k_new, v_new, then the four pools)
        input_output_aliases={ns + 3 + k: 1 + k for k in range(4)},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*scalar_ops, qr, k_new, v_new,
      ke_pool, ks_pool, ve_pool, vs_pool)
    out = out.reshape(r, kvh, w, g, d)
    pools = (oke, oks, ove, ovs)
    return (out, pools, visits) if debug_visits else (out, pools)
