"""Pallas decode-attention kernels over an MX-quantized KV cache.

The serving-side application of VMXDOTP's insight: decode attention is
HBM-bandwidth-bound on the KV cache, so the cache is stored block-scaled
(fp8 elements + E8M0 scales along head_dim) and decoded **in-register** —
the wide K/V never exist in HBM. This is the vector-scalar instruction
family (`vmxdotp.*f`): one wide query operand against compact MX operands.

Three entry points, two cache layouts:

  * **contiguous** (`mx_attention_decode`): one (T, D) tile per (batch,
    kv-head), the fixed-slot serving layout. ``kpos``/``pos`` may be shared
    across the batch or per-sequence (continuous batching decodes requests
    at different positions in the same step).
  * **paged, two-pass** (`mx_attention_decode_paged`): the cache lives in a
    global page pool (num_pages, page_size, KVH, D) and each sequence owns
    a list of pages (its page-table row). `gather_kv_pages` is a Pallas
    kernel whose BlockSpec index maps read the scalar-prefetched page
    table — the DMA engine walks the page list directly, and the gathered
    operands stay **compact** (fp8/fp4 + E8M0). Decode then reuses the
    contiguous kernel bit-for-bit, which is what makes paged-vs-contiguous
    equivalence exact rather than approximate. Kept as the bit-exactness
    oracle; the engine no longer runs it.
  * **paged, single-pass fused** (`mx_attention_decode_fused` /
    `mx_attention_verify_fused`): the serve engine's hot path. One
    kernel, grid (B, KVH, num_kv_pages) with the page dimension
    innermost: the BlockSpec index maps read the scalar-prefetched page
    table, so each grid step DMAs one *compact* pool page tile straight
    into VMEM, dequantizes it in-register, and folds it into a
    flash-style online softmax (running max / rescaled partial sums in
    VMEM scratch). The gathered cache never exists — not wide, not even
    compact — and ``pl.when`` skips every page tile past
    ``ceil(seq_len / page_size)`` (the index map also re-points skipped
    steps at the last valid page, so the pipeline's DMA is elided by the
    revisit rule). Per-step work is proportional to *resident* tokens,
    not the padded table width. The verify variant runs Tq > 1 query
    tokens (speculative decoding's batched multi-token verify) through
    the *same* page walk with per-row causal intra-chunk masking — one
    tile DMA + dequant now feeds K+1 tokens of attention, the serving
    analogue of the paper's keep-the-MX-dataflow-dense argument; decode
    is its Tq == 1 case.

Per grid cell (batch b, kv-head h): load the query group (G, D) wide, the
K/V cache tiles compact, fold scales in VREGs, run the (G, ·) logits
matmul + masked f32 softmax + (G, D) output matmul.

Layouts:
  q        (B, KVH, G, D)    bf16/f32 (G = query heads per kv head)
  k_elems  (B, KVH, T, D)    fp8   k_scales (B, KVH, T, D//k) u8
  v_elems  (B, KVH, T, D)    fp8   v_scales (B, KVH, T, D//k) u8
  kpos     (T,) or (B, T)    i32 (absolute positions; -1 = empty slot)
  pos      scalar or (B,)    i32 (last valid position per sequence)
  out      (B, KVH, G, D)    f32
Paged pools: (NP, PS, KVH, D[/2]) elems, (NP, PS, KVH, D//k) scales,
page_table (B, P) i32 (entries < 0 = unallocated; rows are masked out via
seq_lens so garbage pages never contribute).

Element formats are threaded explicitly (``fmt_name``, as ``mx_matmul``
does) — fp4 packs two nibbles per stored byte, so the storage dtype alone
cannot name the format once more than one byte-backed format exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams
from .mx_matmul import _decode_e8m0, _decode_tile

NEG_INF = -2.0e38


def _check_fmt(elems, fmt_name: str):
    """Fail loudly when ``fmt_name`` contradicts the storage dtype.

    fp4 packs two nibbles per uint8 byte, so decoding it as fp8 (or vice
    versa) produces shape garbage deep inside the kernel; catching the
    mismatch at the wrapper names the actual mistake.
    """
    packed = elems.dtype == jnp.uint8
    if packed != (fmt_name == "fp4_e2m1"):
        raise ValueError(
            f"fmt_name {fmt_name!r} does not match the cache storage dtype "
            f"{elems.dtype} (packed fp4 pools need fmt_name='fp4_e2m1', "
            "fp8 pools an fp8 format)")


def _dequant_rows(elems, scales, fmt_name: str, block_size: int):
    """(T, D) stored elements + (T, D//k) scales -> (T, D) f32.

    ``fmt_name`` is threaded explicitly from the caller (never sniffed from
    the storage dtype): fp8 variants share decode-by-astype but fp4 stores
    two packed nibbles per byte, and any future byte-backed format would
    make dtype sniffing silently wrong.
    """
    t = elems.shape[0]
    vals = _decode_tile(elems, fmt_name)
    d = vals.shape[-1]
    nb = d // block_size
    s = _decode_e8m0(scales)  # (T, nb)
    return (vals.reshape(t, nb, block_size) * s[:, :, None]).reshape(t, d)


def _mx_attn_kernel(q_ref, ke_ref, ks_ref, ve_ref, vs_ref, kpos_ref,
                    pos_ref, o_ref, *, fmt_name: str, block_size: int,
                    softcap):
    """One (batch, kv_head) cell: full-T attention with masked f32 softmax."""
    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = _dequant_rows(ke_ref[0, 0], ks_ref[0, 0], fmt_name, block_size)
    v = _dequant_rows(ve_ref[0, 0], vs_ref[0, 0], fmt_name, block_size)
    d = q.shape[-1]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (d ** -0.5)  # (G, T)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    kpos = kpos_ref[0]
    pos = pos_ref[0]
    mask = (kpos <= pos) & (kpos >= 0)
    logits = jnp.where(mask[None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[0, 0] = (out / denom).astype(o_ref.dtype)


def mx_attention_decode(q, k_elems, k_scales, v_elems, v_scales, kpos, pos,
                        *, fmt_name: str = "fp8_e4m3", block_size: int = 32,
                        softcap=None, interpret: bool | None = None):
    """Decode attention against an MX-quantized cache. Returns (B,KVH,G,D).

    ``kpos`` may be (T,) shared or (B, T) per-sequence; ``pos`` a scalar or
    (B,) per-sequence — the ragged-batch form continuous batching needs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    _check_fmt(k_elems, fmt_name)
    b, kvh, g, d = q.shape
    t = k_elems.shape[2]
    nb = k_scales.shape[-1]
    kpos = jnp.asarray(kpos, jnp.int32)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos[None], (b, t))
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos[None], (b,))
    kernel = functools.partial(_mx_attn_kernel, fmt_name=fmt_name,
                               block_size=block_size, softcap=softcap)
    ed = k_elems.shape[-1]
    return pl.pallas_call(
        kernel,
        grid=(b, kvh),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k_elems, k_scales, v_elems, v_scales, kpos, pos)


# ---------------------------------------------------------------------------
# paged cache: page-table gather kernel + decode wrapper
# ---------------------------------------------------------------------------


def _gather_pages_kernel(pt_ref, ke_ref, ks_ref, ve_ref, vs_ref,
                         oke_ref, oks_ref, ove_ref, ovs_ref):
    """Copy one pool page tile into its contiguous slot (pure DMA shuffle).

    The interesting part is outside the body: the *input* BlockSpec index
    maps read the scalar-prefetched page table, so block (b, h, p) is DMA'd
    straight from pool page ``page_table[b, p]`` — the kernel never touches
    a wide value and never materializes an indirection on the compute units.
    """
    oke_ref[0, 0] = ke_ref[0, :, 0, :]
    oks_ref[0, 0] = ks_ref[0, :, 0, :]
    ove_ref[0, 0] = ve_ref[0, :, 0, :]
    ovs_ref[0, 0] = vs_ref[0, :, 0, :]


def gather_kv_pages(ke_pool, ks_pool, ve_pool, vs_pool, page_table,
                    *, interpret: bool | None = None):
    """Gather per-sequence K/V pages into contiguous compact caches.

    Pools: (NP, PS, KVH, ED) elems + (NP, PS, KVH, NB) scales.
    page_table: (B, P) int32, entries < 0 = unallocated (clamped to page 0;
    callers mask those rows via seq_lens).
    Returns (k_elems, k_scales, v_elems, v_scales) shaped (B, KVH, P*PS, ·).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    npages, ps, kvh, ed = ke_pool.shape
    nb = ks_pool.shape[-1]
    b, pmax = page_table.shape
    t = pmax * ps
    table = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, npages - 1)

    def pool_spec(width):
        return pl.BlockSpec((1, ps, 1, width),
                            lambda i, j, p, pt: (pt[i, p], 0, j, 0))

    def out_spec(width):
        return pl.BlockSpec((1, 1, ps, width),
                            lambda i, j, p, pt: (i, j, p, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, pmax),
        in_specs=[pool_spec(ed), pool_spec(nb), pool_spec(ed), pool_spec(nb)],
        out_specs=[out_spec(ed), out_spec(nb), out_spec(ed), out_spec(nb)],
    )
    return pl.pallas_call(
        _gather_pages_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, t, ed), ke_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, nb), ks_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, ed), ve_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, nb), vs_pool.dtype),
        ],
        interpret=interpret,
    )(table, ke_pool, ks_pool, ve_pool, vs_pool)


def mx_attention_decode_paged(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              interpret: bool | None = None):
    """Two-pass decode attention through a page table over an MX page pool.

    q: (B, KVH, G, D); pools per :func:`gather_kv_pages`; seq_lens (B,) =
    number of valid cache rows per sequence (query sits at seq_len - 1).
    Returns (B, KVH, G, D) f32, bit-identical to `mx_attention_decode` on
    the equivalent contiguous cache (same gather order, same kernel).

    This materializes the gathered *compact* cache (pass 1) before
    attending over the full padded table (pass 2) — kept as the exactness
    oracle for :func:`mx_attention_decode_fused`, which does both in one
    kernel and never materializes the gather.
    """
    ke, ks, ve, vs = gather_kv_pages(ke_pool, ks_pool, ve_pool, vs_pool,
                                     page_table, interpret=interpret)
    t = ke.shape[2]
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                            (q.shape[0], t))
    return mx_attention_decode(q, ke, ks, ve, vs, kpos, seq_lens - 1,
                               fmt_name=fmt_name, block_size=block_size,
                               softcap=softcap, interpret=interpret)


# ---------------------------------------------------------------------------
# single-pass fused paged decode: page-table walk + dequant + online softmax
# ---------------------------------------------------------------------------


def _mx_attn_fused_kernel(tbl_ref, lens_ref, q_ref, ke_ref, ks_ref, ve_ref,
                          vs_ref, o_ref, visits_ref, m_ref, l_ref, acc_ref,
                          *, page_size: int, fmt_name: str, block_size: int,
                          softcap, window, num_q: int, group: int):
    """One page tile of one (batch, kv-head) cell, flash-style.

    Grid is (B, KVH, P) with P innermost ("arbitrary"), so the VMEM
    scratch — running max ``m``, running denominator ``l``, rescaled
    partial output ``acc`` — persists across the page walk of a cell and
    is re-initialized at page 0. ``pl.when`` skips tiles past
    ``ceil(seq_len / page_size)`` entirely: masked-out pages cost neither
    dequant nor MXU work, and their DMA is elided because the index map
    re-points them at the last valid page (unchanged block index = no
    refetch). The wide K/V tile exists only in VREGs.

    ``num_q`` query tokens per sequence share the page walk (speculative
    verify): the query tile holds ``num_q * group`` rows, rows
    ``[i*group, (i+1)*group)`` belonging to the query at absolute
    position ``seq_len - num_q + i``, and the causal mask is per-row —
    query ``i`` sees keys ``kpos <= seq_len - num_q + i`` (intra-chunk
    causality), so drafted tokens never attend to their own successors.
    ``num_q == 1`` is exactly the decode kernel this generalizes.
    """
    i = pl.program_id(0)
    p = pl.program_id(2)
    last = pl.num_programs(2) - 1

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        visits_ref[0, 0, 0] = 0

    seq_len = lens_ref[i]  # wrapper-clamped to >= num_q
    valid_pages = pl.cdiv(seq_len, page_size)

    @pl.when(p < valid_pages)
    def _page():
        # the skip predicate's audit trail: counts page bodies actually
        # executed, so tests/benchmarks can assert work == resident pages
        visits_ref[0, 0, 0] += 1
        q = q_ref[0, 0].astype(jnp.float32)  # (num_q * G, D)
        k = _dequant_rows(ke_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                          fmt_name, block_size)  # (PS, D)
        v = _dequant_rows(ve_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                          fmt_name, block_size)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * (d ** -0.5)  # (R, PS)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        rows = num_q * group
        # row r belongs to query index r // group; query i sits at
        # absolute position seq_len - num_q + i
        qpos = seq_len - num_q + jax.lax.broadcasted_iota(
            jnp.int32, (rows, 1), 0) // group
        mask = kpos <= qpos  # (R, PS)
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]  # (R, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # the explicit mask (not just exp(NEG_INF - m)) guards the
        # all-masked tile: there m_new == NEG_INF and the difference is 0
        probs = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (R, PS)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(probs, axis=-1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            probs, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == last)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def mx_attention_verify_fused(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              window=None, debug_visits: bool = False,
                              interpret: bool | None = None):
    """Single-pass fused paged attention for ``Tq >= 1`` query tokens.

    The speculative-decoding verify kernel: the draft tokens' K/V have
    already been written into the sequence's pages, and all ``Tq``
    queries — the last accepted token plus the drafts, at absolute
    positions ``seq_len - Tq .. seq_len - 1`` — share one page walk.
    One Pallas kernel with grid (B, KVH, P): the BlockSpec index maps
    read the scalar-prefetched page table, each grid step dequantizes one
    compact fp8/fp4 + E8M0 pool page tile in-register exactly once for
    the whole chunk (this is the amortization speculative decoding buys:
    K+1 tokens of attention per page-tile DMA + dequant instead of one),
    and the softmax is accumulated online per query row in VMEM scratch.
    Causal intra-chunk masking is per row: query ``i`` attends keys
    ``kpos <= seq_len - Tq + i``, so a draft never sees its successors
    and row ``i``'s output is exactly what a one-token decode at position
    ``seq_len - Tq + i`` would compute.

    q: (B, KVH, Tq, G, D); pools (NP, PS, KVH, ED/NB); page_table (B, P)
    i32 (entries < 0 = unallocated, clamped); seq_lens (B,) valid cache
    rows per sequence *including* the chunk's own tokens (inactive rows
    may pass 0, clamped to Tq so every query position stays valid —
    garbage rows whose logits the host ignores). ``window`` masks keys
    at ``kpos <= qpos - window`` per query row. Returns
    (B, KVH, Tq, G, D) f32.

    ``debug_visits=True`` additionally returns a (B, KVH, 1) i32 count of
    page bodies actually executed per cell — the kernel always maintains
    it (one scalar store per visited tile), and tests/benchmarks assert
    it equals ``ceil(seq_lens / PS)`` exactly, making the page-skip
    predicate falsifiable on every backend (off-TPU, interpret-mode
    wall-clock cannot see the skip: the grid loop visits every cell and
    only the body is predicated away).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    _check_fmt(ke_pool, fmt_name)
    b, kvh, tq, g, d = q.shape
    rows = tq * g
    npages, ps = ke_pool.shape[0], ke_pool.shape[1]
    ed = ke_pool.shape[-1]
    nb = ks_pool.shape[-1]
    pmax = page_table.shape[1]
    table = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, npages - 1)
    lens = jnp.maximum(jnp.asarray(seq_lens, jnp.int32), tq)
    qr = q.reshape(b, kvh, rows, d)

    def pool_spec(width):
        def imap(i, j, p, tbl, ln):
            # clamp skipped steps to the last valid page (ln is
            # wrapper-clamped >= Tq >= 1, so valid >= 1): an unchanged
            # block index means the pipeline elides the DMA entirely
            valid = pl.cdiv(ln[i], ps)
            return (tbl[i, jnp.minimum(p, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, ps, 1, width), imap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, tbl, ln: (i, j, 0, 0)),
            pool_spec(ed), pool_spec(nb), pool_spec(ed), pool_spec(nb),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, tbl, ln: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j, p, tbl, ln: (i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),  # running max m
            pltpu.VMEM((rows, 1), jnp.float32),  # running denominator l
            pltpu.VMEM((rows, d), jnp.float32),  # rescaled partial output
        ],
    )
    kernel = functools.partial(
        _mx_attn_fused_kernel, page_size=ps, fmt_name=fmt_name,
        block_size=block_size, softcap=softcap, window=window,
        num_q=tq, group=g)
    out, visits = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, lens, qr, ke_pool, ks_pool, ve_pool, vs_pool)
    out = out.reshape(b, kvh, tq, g, d)
    return (out, visits) if debug_visits else out


def mx_attention_decode_fused(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *,
                              fmt_name: str = "fp8_e4m3",
                              block_size: int = 32, softcap=None,
                              window=None, debug_visits: bool = False,
                              interpret: bool | None = None):
    """Single-pass fused paged decode attention (the serve-engine hot path).

    The ``Tq == 1`` case of :func:`mx_attention_verify_fused` (one kernel
    serves both paths — decode is just a verify chunk of one): the
    BlockSpec index maps read the scalar-prefetched page table, each grid
    step dequantizes one compact fp8/fp4 + E8M0 pool page tile
    in-register, and the softmax is accumulated online (flash-decoding)
    in VMEM scratch — no gathered cache, wide or compact, ever exists in
    HBM, and page tiles at or past ``ceil(seq_len / page_size)`` are
    skipped, so per-step work scales with resident tokens rather than
    the padded table.

    q: (B, KVH, G, D); pools (NP, PS, KVH, ED/NB); page_table (B, P) i32
    (entries < 0 = unallocated, clamped — rows past ``seq_lens`` never
    contribute); seq_lens (B,) valid cache rows per sequence (the query
    sits at seq_len - 1; inactive rows may pass 0, clamped to 1 so the
    denominator stays finite, matching the einsum path's pos=0 garbage
    rows whose logits the host ignores). ``window`` masks keys at
    ``kpos <= pos - window`` (sliding-window layers). Returns
    (B, KVH, G, D) f32; matches the two-pass/einsum f32 reference to
    online-softmax rounding (~1e-7, well inside 1e-5). ``debug_visits``
    as in :func:`mx_attention_verify_fused`.
    """
    res = mx_attention_verify_fused(
        q[:, :, None], ke_pool, ks_pool, ve_pool, vs_pool, page_table,
        seq_lens, fmt_name=fmt_name, block_size=block_size,
        softcap=softcap, window=window, debug_visits=debug_visits,
        interpret=interpret)
    if debug_visits:
        out, visits = res
        return out[:, :, 0], visits
    return res[:, :, 0]
