"""Pallas decode-attention kernels over an MX-quantized KV cache.

The serving-side application of VMXDOTP's insight: decode attention is
HBM-bandwidth-bound on the KV cache, so the cache is stored block-scaled
(fp8 elements + E8M0 scales along head_dim) and decoded **in-register** —
the wide K/V never exist in HBM. This is the vector-scalar instruction
family (`vmxdotp.*f`): one wide query operand against compact MX operands.

Two cache layouts are supported:

  * **contiguous** (`mx_attention_decode`): one (T, D) tile per (batch,
    kv-head), the fixed-slot serving layout. ``kpos``/``pos`` may be shared
    across the batch or per-sequence (continuous batching decodes requests
    at different positions in the same step).
  * **paged** (`mx_attention_decode_paged`): the cache lives in a global
    page pool (num_pages, page_size, KVH, D) and each sequence owns a list
    of pages (its page-table row). `gather_kv_pages` is a Pallas kernel
    whose BlockSpec index maps read the scalar-prefetched page table — the
    DMA engine walks the page list directly, and the gathered operands stay
    **compact** (fp8/fp4 + E8M0), so the bandwidth win survives paging.
    Decode then reuses the contiguous kernel bit-for-bit, which is what
    makes paged-vs-contiguous equivalence exact rather than approximate.

Per grid cell (batch b, kv-head h): load the query group (G, D) wide, the
K/V cache tiles (T, D) compact, fold scales in VREGs, run the (G, T) logits
matmul + masked f32 softmax + (G, D) output matmul.

Layouts:
  q        (B, KVH, G, D)    bf16/f32 (G = query heads per kv head)
  k_elems  (B, KVH, T, D)    fp8   k_scales (B, KVH, T, D//k) u8
  v_elems  (B, KVH, T, D)    fp8   v_scales (B, KVH, T, D//k) u8
  kpos     (T,) or (B, T)    i32 (absolute positions; -1 = empty slot)
  pos      scalar or (B,)    i32 (last valid position per sequence)
  out      (B, KVH, G, D)    f32
Paged pools: (NP, PS, KVH, D[/2]) elems, (NP, PS, KVH, D//k) scales,
page_table (B, P) i32 (entries < 0 = unallocated; rows are masked out via
seq_lens so garbage pages never contribute).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams
from .mx_matmul import _decode_e8m0, _decode_tile

NEG_INF = -2.0e38


def _dequant_rows(elems, scales, block_size: int):
    """(T, D) stored elements + (T, D//k) scales -> (T, D) f32."""
    t, d_store = elems.shape
    vals = _decode_tile(elems, "fp8_e4m3" if elems.dtype != jnp.uint8
                        else "fp4_e2m1")
    d = vals.shape[-1]
    nb = d // block_size
    s = _decode_e8m0(scales)  # (T, nb)
    return (vals.reshape(t, nb, block_size) * s[:, :, None]).reshape(t, d)


def _mx_attn_kernel(q_ref, ke_ref, ks_ref, ve_ref, vs_ref, kpos_ref,
                    pos_ref, o_ref, *, block_size: int, softcap):
    """One (batch, kv_head) cell: full-T attention with masked f32 softmax."""
    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = _dequant_rows(ke_ref[0, 0], ks_ref[0, 0], block_size)  # (T, D)
    v = _dequant_rows(ve_ref[0, 0], vs_ref[0, 0], block_size)
    d = q.shape[-1]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (d ** -0.5)  # (G, T)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    kpos = kpos_ref[0]
    pos = pos_ref[0]
    mask = (kpos <= pos) & (kpos >= 0)
    logits = jnp.where(mask[None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[0, 0] = (out / denom).astype(o_ref.dtype)


def mx_attention_decode(q, k_elems, k_scales, v_elems, v_scales, kpos, pos,
                        *, block_size: int = 32, softcap=None,
                        interpret: bool | None = None):
    """Decode attention against an MX-quantized cache. Returns (B,KVH,G,D).

    ``kpos`` may be (T,) shared or (B, T) per-sequence; ``pos`` a scalar or
    (B,) per-sequence — the ragged-batch form continuous batching needs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, kvh, g, d = q.shape
    t = k_elems.shape[2]
    nb = k_scales.shape[-1]
    kpos = jnp.asarray(kpos, jnp.int32)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos[None], (b, t))
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos[None], (b,))
    kernel = functools.partial(_mx_attn_kernel, block_size=block_size,
                               softcap=softcap)
    ed = k_elems.shape[-1]
    return pl.pallas_call(
        kernel,
        grid=(b, kvh),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k_elems, k_scales, v_elems, v_scales, kpos, pos)


# ---------------------------------------------------------------------------
# paged cache: page-table gather kernel + decode wrapper
# ---------------------------------------------------------------------------


def _gather_pages_kernel(pt_ref, ke_ref, ks_ref, ve_ref, vs_ref,
                         oke_ref, oks_ref, ove_ref, ovs_ref):
    """Copy one pool page tile into its contiguous slot (pure DMA shuffle).

    The interesting part is outside the body: the *input* BlockSpec index
    maps read the scalar-prefetched page table, so block (b, h, p) is DMA'd
    straight from pool page ``page_table[b, p]`` — the kernel never touches
    a wide value and never materializes an indirection on the compute units.
    """
    oke_ref[0, 0] = ke_ref[0, :, 0, :]
    oks_ref[0, 0] = ks_ref[0, :, 0, :]
    ove_ref[0, 0] = ve_ref[0, :, 0, :]
    ovs_ref[0, 0] = vs_ref[0, :, 0, :]


def gather_kv_pages(ke_pool, ks_pool, ve_pool, vs_pool, page_table,
                    *, interpret: bool | None = None):
    """Gather per-sequence K/V pages into contiguous compact caches.

    Pools: (NP, PS, KVH, ED) elems + (NP, PS, KVH, NB) scales.
    page_table: (B, P) int32, entries < 0 = unallocated (clamped to page 0;
    callers mask those rows via seq_lens).
    Returns (k_elems, k_scales, v_elems, v_scales) shaped (B, KVH, P*PS, ·).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    npages, ps, kvh, ed = ke_pool.shape
    nb = ks_pool.shape[-1]
    b, pmax = page_table.shape
    t = pmax * ps
    table = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, npages - 1)

    def pool_spec(width):
        return pl.BlockSpec((1, ps, 1, width),
                            lambda i, j, p, pt: (pt[i, p], 0, j, 0))

    def out_spec(width):
        return pl.BlockSpec((1, 1, ps, width),
                            lambda i, j, p, pt: (i, j, p, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, pmax),
        in_specs=[pool_spec(ed), pool_spec(nb), pool_spec(ed), pool_spec(nb)],
        out_specs=[out_spec(ed), out_spec(nb), out_spec(ed), out_spec(nb)],
    )
    return pl.pallas_call(
        _gather_pages_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, t, ed), ke_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, nb), ks_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, ed), ve_pool.dtype),
            jax.ShapeDtypeStruct((b, kvh, t, nb), vs_pool.dtype),
        ],
        interpret=interpret,
    )(table, ke_pool, ks_pool, ve_pool, vs_pool)


def mx_attention_decode_paged(q, ke_pool, ks_pool, ve_pool, vs_pool,
                              page_table, seq_lens, *, block_size: int = 32,
                              softcap=None, interpret: bool | None = None):
    """Decode attention through a page table over an MX page pool.

    q: (B, KVH, G, D); pools per :func:`gather_kv_pages`; seq_lens (B,) =
    number of valid cache rows per sequence (query sits at seq_len - 1).
    Returns (B, KVH, G, D) f32, bit-identical to `mx_attention_decode` on
    the equivalent contiguous cache (same gather order, same kernel).
    """
    ke, ks, ve, vs = gather_kv_pages(ke_pool, ks_pool, ve_pool, vs_pool,
                                     page_table, interpret=interpret)
    t = ke.shape[2]
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                            (q.shape[0], t))
    return mx_attention_decode(q, ke, ks, ve, vs, kpos, seq_lens - 1,
                               block_size=block_size, softcap=softcap,
                               interpret=interpret)
