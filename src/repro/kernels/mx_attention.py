"""Pallas decode-attention kernel over an MX-quantized KV cache.

The serving-side application of VMXDOTP's insight: decode attention is
HBM-bandwidth-bound on the KV cache, so the cache is stored block-scaled
(fp8 elements + E8M0 scales along head_dim) and decoded **in-register** —
the wide K/V never exist in HBM. This is the vector-scalar instruction
family (`vmxdotp.*f`): one wide query operand against compact MX operands.

Per grid cell (batch b, kv-head h): load the query group (G, D) wide, the
K/V cache tiles (T, D) compact, fold scales in VREGs, run the (G, T) logits
matmul + masked f32 softmax + (G, D) output matmul. T tiles fit VMEM
(32k x 128 fp8 = 4 MiB); longer caches tile over T with running
(max, sum, acc) online-softmax state.

Layouts:
  q        (B, KVH, G, D)    bf16/f32 (G = query heads per kv head)
  k_elems  (B, KVH, T, D)    fp8   k_scales (B, KVH, T, D//k) u8
  v_elems  (B, KVH, T, D)    fp8   v_scales (B, KVH, T, D//k) u8
  kpos     (T,)              i32 (absolute positions; -1 = empty slot)
  out      (B, KVH, G, D)    f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mx_matmul import _decode_e8m0, _decode_tile

NEG_INF = -2.0e38


def _dequant_rows(elems, scales, block_size: int):
    """(T, D) stored elements + (T, D//k) scales -> (T, D) f32."""
    t, d_store = elems.shape
    vals = _decode_tile(elems, "fp8_e4m3" if elems.dtype != jnp.uint8
                        else "fp4_e2m1")
    d = vals.shape[-1]
    nb = d // block_size
    s = _decode_e8m0(scales)  # (T, nb)
    return (vals.reshape(t, nb, block_size) * s[:, :, None]).reshape(t, d)


def _mx_attn_kernel(q_ref, ke_ref, ks_ref, ve_ref, vs_ref, kpos_ref,
                    pos_ref, o_ref, *, block_size: int, softcap):
    """One (batch, kv_head) cell: full-T attention with masked f32 softmax."""
    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = _dequant_rows(ke_ref[0, 0], ks_ref[0, 0], block_size)  # (T, D)
    v = _dequant_rows(ve_ref[0, 0], vs_ref[0, 0], block_size)
    d = q.shape[-1]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (d ** -0.5)  # (G, T)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    kpos = kpos_ref[...]
    pos = pos_ref[0]
    mask = (kpos <= pos) & (kpos >= 0)
    logits = jnp.where(mask[None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[0, 0] = (out / denom).astype(o_ref.dtype)


def mx_attention_decode(q, k_elems, k_scales, v_elems, v_scales, kpos, pos,
                        *, block_size: int = 32, softcap=None,
                        interpret: bool | None = None):
    """Decode attention against an MX-quantized cache. Returns (B,KVH,G,D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, kvh, g, d = q.shape
    t = k_elems.shape[2]
    nb = k_scales.shape[-1]
    kernel = functools.partial(_mx_attn_kernel, block_size=block_size,
                               softcap=softcap)
    ed = k_elems.shape[-1]
    return pl.pallas_call(
        kernel,
        grid=(b, kvh),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, ed), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, nb), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((t,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k_elems, k_scales, v_elems, v_scales, kpos,
      jnp.asarray(pos, jnp.int32)[None])
