"""Pallas repack kernel: requantize KV pool pages down the format ladder.

The tiering engine's workhorse: a batch of cold pages is re-encoded from
their current element format (fp8 hot tier) to a narrower one (fp6 mid /
fp4 cold) **in place**, inside the mixed-format uint8 page pool that the
fused attention kernels read. Per page the kernel

  1. dequantizes the stored rows exactly (the same per-page-format decode
     select the attention kernels use — see
     :func:`repro.kernels.mx_attention._dequant_rows_mixed`),
  2. requantizes to the target format with the exact ``core.quantize``
     math (:func:`repro.kernels.mx_attention._quantize_rows` — block amax
     -> E8M0 shared exponent -> RNE saturating cast). Scales are
     **recomputed**, not copied: emax differs per format, so the old
     shared exponents are wrong for the new element grid.
  3. writes the packed codes into the row *prefix* (fp8 = D bytes,
     fp6 = 3D/4, fp4 = D/2) and zeroes the dead tail bytes, so repacked
     pages are bit-deterministic end to end — tests assert the prefix is
     bit-identical to a host ``core.quantize`` re-encode of the decoded
     values and the tail is zero.

The page list rides scalar prefetch, like the attention kernels' page
tables: the BlockSpec index maps send each grid step's DMA straight at
pool page ``page_ids[n]``. The list is a fixed-size operand so the
engine's jitted repack call is one trace regardless of how many pages
this step actually repacks: ``count`` names the live prefix, and padding
entries must **repeat the last live id (and its source format)** — their
bodies are predicated off, so the parked input/output blocks keep the
already-correct bytes of a page this call just wrote (safe under both
the revisit-elision rule on TPU and per-step copies in interpret mode).
Callers must not invoke the kernel with ``count == 0`` (skip at host
level instead — the pad contract needs at least one live entry).

COW safety is the caller's contract: the engine repacks a shared page
once (pages are keyed physically, not per sequence) and flips the
per-page format id *after* the kernel completes, between engine steps,
so no attention call ever sees bytes and format id out of sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F

from .compat import CompilerParams
from .mx_attention import (MIXED_FMTS_DEFAULT, _dequant_rows_mixed,
                           _quantize_rows)


def _repack_kernel(ids_ref, fmts_ref, cnt_ref, ke_ref, ks_ref, ve_ref,
                   vs_ref, oke_ref, oks_ref, ove_ref, ovs_ref, *,
                   dst_fmt_name: str, mixed_fmts, block_size: int):
    n = pl.program_id(0)
    dst = F.get_format(dst_fmt_name)

    @pl.when(n < cnt_ref[0])
    def _do():
        fid = fmts_ref[n]  # source format id of this page
        for e_in, s_in, e_out, s_out in (
                (ke_ref, ks_ref, oke_ref, oks_ref),
                (ve_ref, vs_ref, ove_ref, ovs_ref)):
            rows = e_in[0, :, 0, :]  # (PS, D) uint8
            ps, d = rows.shape
            wide = _dequant_rows_mixed(rows, s_in[0, :, 0, :], fid,
                                       mixed_fmts, block_size)
            q_e, q_s = _quantize_rows(wide, dst_fmt_name, block_size)
            if dst.bits == 8:
                qb = jax.lax.bitcast_convert_type(q_e, jnp.uint8)
            else:
                w = dst.storage_len(d)
                qb = jnp.concatenate(
                    [q_e, jnp.zeros((ps, d - w), jnp.uint8)], axis=-1)
            e_out[0, :, 0, :] = qb
            s_out[0, :, 0, :] = q_s


def mx_repack_pages(ke_pool, ks_pool, ve_pool, vs_pool, page_ids, src_fmts,
                    count, *, dst_fmt_name: str, mixed_fmts=None,
                    block_size: int = 32, interpret: bool | None = None):
    """Repack ``count`` pool pages to ``dst_fmt_name`` in place.

    Pools are the tiered layout: (NP, PS, KVH, D) uint8 elements +
    (NP, PS, KVH, D//k) uint8 E8M0 scales. ``page_ids``/``src_fmts`` are
    fixed-size (N,) i32 arrays — the live prefix of length ``count``
    names the pages to repack and their *current* format ids
    (:data:`repro.core.formats.FORMAT_IDS`); padding entries repeat the
    last live entry (see module docstring for why). ``count`` may be a
    traced scalar; it must be >= 1.

    Returns the four updated pools (inputs are aliased: in-place under
    jit donation). Works per page, so one call can mix target-distinct
    batches only by issuing one call per target format — the ladder
    steps (fp8 -> fp6, fp6 -> fp4) are separate calls anyway since the
    engine ages tiers independently.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if ke_pool.dtype != jnp.uint8:
        raise ValueError(
            "mx_repack_pages operates on mixed-format (tiered) pools, "
            f"which store raw uint8 bytes; got {ke_pool.dtype}")
    if mixed_fmts is None:
        mixed_fmts = MIXED_FMTS_DEFAULT
    mixed_fmts = tuple(mixed_fmts)
    if dst_fmt_name not in F.FORMAT_IDS:
        raise ValueError(f"unknown target format {dst_fmt_name!r}")
    npages, ps, kvh, d = ke_pool.shape
    nb = ks_pool.shape[-1]
    nlist = page_ids.shape[0]
    ids = jnp.clip(jnp.asarray(page_ids, jnp.int32), 0, npages - 1)
    fmts = jnp.asarray(src_fmts, jnp.int32)
    cnt = jnp.asarray(count, jnp.int32).reshape(1)

    def spec(width):
        return pl.BlockSpec((1, ps, 1, width),
                            lambda n, j, ids, fmts, cnt: (ids[n], 0, j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nlist, kvh),
        in_specs=[spec(d), spec(nb), spec(d), spec(nb)],
        out_specs=[spec(d), spec(nb), spec(d), spec(nb)],
    )
    kernel = functools.partial(
        _repack_kernel, dst_fmt_name=dst_fmt_name, mixed_fmts=mixed_fmts,
        block_size=block_size)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(ke_pool.shape, jnp.uint8),
            jax.ShapeDtypeStruct(ks_pool.shape, jnp.uint8),
            jax.ShapeDtypeStruct(ve_pool.shape, jnp.uint8),
            jax.ShapeDtypeStruct(vs_pool.shape, jnp.uint8),
        ],
        # pools update in place (operands: ids=0, fmts=1, cnt=2, pools 3-6)
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(ids, fmts, cnt, ke_pool, ks_pool, ve_pool, vs_pool)
