"""JAX version compatibility for the Pallas TPU surface.

The ``compiler_params`` dataclass was renamed ``TPUCompilerParams`` ->
``CompilerParams`` across JAX releases; resolve whichever this JAX has so
the kernels import (and run in interpret mode) on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
