"""Pallas TPU kernels for the performance-critical MX compute hot-spots.

  mx_matmul.py    fused MX matmul (VMXDOTP analogue): vv + weight-only
  mx_attention.py decode/prefill attention over MX KV caches: contiguous,
                  paged two-pass (gather oracle), the single-pass fused
                  paged flash-decode/verify kernels the serve engine
                  runs, and the fused chunked-prefill kernel that
                  quantize-writes each chunk's K/V into its pages
  mx_megakernel.py layer-fused megakernel: the whole attention-only
                  decoder stack (norm, QKV+RoPE, ragged MX page walk,
                  output projection, gated MLP) as ONE pallas_call with
                  the layer as the outermost grid dimension
  mx_quantize.py  fused block quantization (amax + E8M0 + RNE cast)
  mx_repack.py    in-place page requantization down the tier ladder
                  (fp8 -> fp6 -> fp4) for the mixed-format KV pool
  ops.py          jit'd public wrappers (MXTensor-aware)
  ref.py          pure-jnp oracles defining exact semantics
"""
from . import ref
from .mx_attention import (gather_kv_pages, mx_attention_decode,
                           mx_attention_decode_fused,
                           mx_attention_decode_paged,
                           mx_attention_prefill_fused,
                           mx_attention_ragged_fused,
                           mx_attention_verify_fused)
from .mx_matmul import mx_matmul_dgrad
from .mx_megakernel import mx_megakernel_step
from .mx_repack import mx_repack_pages
from .ops import mx_matmul, mx_matmul_trainable, quantize_pallas

__all__ = ["gather_kv_pages", "mx_attention_decode",
           "mx_attention_decode_fused", "mx_attention_decode_paged",
           "mx_attention_prefill_fused", "mx_attention_ragged_fused",
           "mx_attention_verify_fused",
           "mx_matmul", "mx_matmul_dgrad", "mx_matmul_trainable",
           "mx_megakernel_step",
           "mx_repack_pages", "quantize_pallas", "ref"]
