"""Public jit'd wrappers around the Pallas MX kernels.

``mx_matmul`` accepts MXTensor / wide-array operands with arbitrary leading
batch dims and dispatches to the vector-vector or weight-only kernel;
``quantize_pallas`` produces an MXTensor via the fused quantization kernel.
On CPU backends (this container) kernels run in interpret mode; on TPU they
compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core.mx_tensor import MXTensor

from . import mx_matmul as _mm
from . import mx_quantize as _mq

Array = jnp.ndarray


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick(v, default):
    return default if v is None else v


def _tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (tries hw-aligned first)."""
    for cand in (pref, 512, 256, 128, 64, 32, 16, 8):
        if cand <= pref and dim % cand == 0:
            return cand
    return dim


def mx_matmul(
    a: Union[Array, MXTensor],
    b: MXTensor,
    *,
    acc_dtype=jnp.float32,
    out_dtype=None,
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Array:
    """``a (..., K) @ b (K, N)`` with MX semantics via the Pallas kernel.

    ``b`` must be an MXTensor blocked along K (axis=0 — stored (N, K),
    the paper's column-major layout). ``a`` is either an MXTensor blocked
    along its last axis (vector-vector) or a wide array (weight-only /
    vector-scalar variant).
    """
    interpret = _pick(interpret, _default_interpret())
    if not isinstance(b, MXTensor) or b.axis != 0:
        raise ValueError("b must be an MXTensor blocked along axis 0 (K)")
    k, n = b.shape
    block_size = b.block_size

    if isinstance(a, MXTensor):
        if a.axis not in (-1, len(a.shape) - 1):
            raise ValueError("a must be blocked along its last axis")
        if a.block_size != block_size or a.fmt_name != b.fmt_name:
            raise ValueError("operand quantization configs differ")
        lead = a.shape[:-1]
        m = 1
        for d in lead:
            m *= d
        ae = a.elements.reshape(m, -1)
        asc = a.scales.reshape(m, -1)
        bm_ = _tile(m, _pick(bm, 128))
        bn_ = _tile(n, _pick(bn, 128))
        bk_ = max(_tile(k, _pick(bk, 512)), block_size)
        out = _mm.mx_matmul_vv(
            ae,
            asc,
            b.elements,
            b.scales,
            fmt_name=b.fmt_name,
            block_size=block_size,
            acc_dtype=acc_dtype,
            bm=bm_,
            bn=bn_,
            bk=bk_,
            interpret=interpret,
        )
    else:
        lead = a.shape[:-1]
        m = 1
        for d in lead:
            m *= d
        a2 = a.reshape(m, k)
        bm_ = _tile(m, _pick(bm, 128))
        bn_ = _tile(n, _pick(bn, 128))
        bk_ = max(_tile(k, _pick(bk, 512)), block_size)
        out = _mm.mx_matmul_wo(
            a2,
            b.elements,
            b.scales,
            fmt_name=b.fmt_name,
            block_size=block_size,
            acc_dtype=acc_dtype,
            bm=bm_,
            bn=bn_,
            bk=bk_,
            interpret=interpret,
        )
    out = out.reshape(*lead, n)
    return out.astype(out_dtype or acc_dtype)


def quantize_pallas(
    x: Array,
    fmt_name: str = "fp8_e4m3",
    block_size: int = 32,
    *,
    interpret: Optional[bool] = None,
) -> MXTensor:
    """Fused block quantization of ``x (..., K)`` along the last axis."""
    interpret = _pick(interpret, _default_interpret())
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for d in lead:
        m *= d
    bm = _tile(m, 256)
    bk = max(_tile(k, 2048), block_size)
    elems, scales = _mq.mx_quantize(
        x.reshape(m, k),
        fmt_name=fmt_name,
        block_size=block_size,
        bm=bm,
        bk=bk,
        interpret=interpret,
    )
    ek = elems.shape[-1]
    return MXTensor(
        elements=elems.reshape(*lead, ek),
        scales=scales.reshape(*lead, k // block_size),
        fmt_name=fmt_name,
        block_size=block_size,
        axis=len(lead),
        shape=x.shape,
    )


# ---------------------------------------------------------------------------
# Trainable entry point: Pallas forward, straight-through wide backward
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def mx_matmul_trainable(x: Array, w_mx: MXTensor, fmt, block_size, acc_dtype):
    """Weight-only Pallas matmul with a differentiable wide backward."""
    return mx_matmul(x, w_mx, acc_dtype=acc_dtype)


def _fwd(x, w_mx, fmt, block_size, acc_dtype):
    y = mx_matmul(x, w_mx, acc_dtype=acc_dtype)
    return y, (x, w_mx)


def _bwd(fmt, block_size, acc_dtype, res, dy):
    x, w_mx = res
    dy32 = dy.astype(jnp.float32)
    # dx through the native dgrad kernel (the stored MX layout is already
    # W^T; scales fold in-register — no wide weight copy materializes)
    lead = dy32.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    n = dy32.shape[-1]
    k = w_mx.shape[0]
    dx = _mm.mx_matmul_dgrad(
        dy32.reshape(m, n), w_mx.elements, w_mx.scales,
        fmt_name=w_mx.fmt_name, block_size=w_mx.block_size,
        bm=_tile(m, 128), bn=_tile(n, 128),
        bk=max(_tile(k, 512), w_mx.block_size),
        interpret=_default_interpret(),
    ).reshape(*lead, k).astype(x.dtype)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    dy2 = dy32.reshape(-1, dy32.shape[-1])
    dw = jax.lax.dot_general(
        x2, dy2, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # Gradient w.r.t. the quantized weight flows to the master copy via the
    # straight-through estimator at the layer level; MXTensor itself is not
    # a differentiable leaf, so return a zero cotangent structure.
    zero_w = jax.tree_util.tree_map(jnp.zeros_like, w_mx)
    del dw  # layer-level QAT uses qat_matmul for weight grads
    return dx, zero_w


mx_matmul_trainable.defvjp(_fwd, _bwd)
