"""Pallas kernel for fused MX block quantization.

Computes, per block of ``block_size`` elements along the last axis: the
block amax, the E8M0 shared exponent (floor(log2(amax)) - emax via FP32
exponent-field extraction — no transcendentals), and the RNE+saturate cast
of the scaled elements to the target format. One pass over the data: the
wide input is read once, compact elements + scales are written.

This is the producer side of the VMXDOTP story: on-the-fly activation
quantization feeding the vector-vector MX matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

from repro.core import formats as F


def _floor_log2(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(x)) for normal positive f32 via exponent-field extraction."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return (jnp.right_shift(bits, 23) & 0xFF).astype(jnp.int32) - 127


def _encode_fp4_codes(v: jnp.ndarray) -> jnp.ndarray:
    """Arithmetic RNE+saturate encode of f32 to E2M1 codes (no gather).

    jnp.round implements round-half-to-even, so each regime below inherits
    correct tie behaviour; regime boundaries coincide with grid points.
    """
    sign = jnp.signbit(v)
    mag = jnp.clip(jnp.abs(v), 0.0, 6.0)
    r1 = jnp.round(mag * 2.0) * 0.5  # grid {0, .5, 1, 1.5, 2}
    r2 = jnp.round(mag)  # grid {2, 3, 4}
    r3 = jnp.round(mag * 0.5) * 2.0  # grid {4, 6}
    val = jnp.where(mag <= 1.75, r1, jnp.where(mag <= 3.5, r2, r3))
    code = jnp.where(val < 2.0, val * 2.0, jnp.where(val < 4.0, val + 2.0, val * 0.5 + 4.0))
    code = code.astype(jnp.uint8)
    return jnp.where(sign, code | jnp.uint8(0x8), code)


def _pack_fp4(codes: jnp.ndarray) -> jnp.ndarray:
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def _encode_fp6_codes(v: jnp.ndarray, fmt: F.ElementFormat) -> jnp.ndarray:
    """Arithmetic RNE+saturate encode of f32 to FP6 codes (no gather).

    Same construction as :func:`repro.core.formats.fp6_encode` (grid snap
    via the exponent-field quantum, then exact field recovery) — pure
    bitcast/shift/round arithmetic, so it is Pallas-safe. Kept in one
    place with the fp4 encoder so every in-kernel quantizer shares it.
    """
    sign = jnp.signbit(v)
    mag = jnp.clip(jnp.abs(v), 0.0, fmt.max)
    snapped = jnp.abs(F.snap_to_fp8_grid(mag, fmt))
    bits = jax.lax.bitcast_convert_type(snapped, jnp.uint32)
    e = (jnp.right_shift(bits, 23) & 0xFF).astype(jnp.int32) - 127
    is_norm = snapped >= 2.0 ** (1 - fmt.bias)
    e_field = jnp.where(is_norm, e + fmt.bias, 0)
    q_bits = ((e - fmt.mantissa_bits + 127) << 23).astype(jnp.uint32)
    quantum = jnp.where(
        is_norm, jax.lax.bitcast_convert_type(q_bits, jnp.float32),
        jnp.float32(fmt.min_subnormal))
    p_bits = ((e + 127) << 23).astype(jnp.uint32)
    frac = snapped - jnp.where(
        is_norm, jax.lax.bitcast_convert_type(p_bits, jnp.float32), 0.0)
    m = jnp.round(frac / quantum).astype(jnp.int32)
    code = ((e_field << fmt.mantissa_bits) | m).astype(jnp.uint8)
    return jnp.where(sign, code | jnp.uint8(0x20), code)


def _pack_fp6(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack quads of 6-bit codes into 3 bytes (low bits first)."""
    c = codes.reshape(*codes.shape[:-1], -1, 4)
    c0, c1, c2, c3 = c[..., 0], c[..., 1], c[..., 2], c[..., 3]
    b0 = c0 | (c1 << 6)
    b1 = (c1 >> 2) | (c2 << 4)
    b2 = (c2 >> 4) | (c3 << 2)
    packed = jnp.stack([b0, b1, b2], axis=-1)
    return packed.reshape(*codes.shape[:-1], -1).astype(jnp.uint8)


def _mx_quantize_kernel(x_ref, q_ref, e_ref, *, fmt: F.ElementFormat, block_size: int):
    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    bm, bk = x.shape
    nb = bk // block_size
    blocked = x.reshape(bm, nb, block_size)
    amax = jnp.max(jnp.abs(blocked), axis=-1)  # (bm, nb)
    e_unb = _floor_log2(amax) - fmt.emax + F.E8M0_BIAS
    e = jnp.clip(jnp.where(amax > 0, e_unb, 0), 0, 254).astype(jnp.uint8)
    e32 = e.astype(jnp.uint32)
    scale_bits = jnp.where(e32 > 0, e32 << 23, jnp.uint32(0x00400000))
    scale = jax.lax.bitcast_convert_type(scale_bits, jnp.float32)
    ratio = jnp.where(scale[:, :, None] > 0, blocked / scale[:, :, None], 0.0)
    ratio = jnp.clip(ratio, -fmt.max, fmt.max).reshape(bm, bk)
    if fmt.name == "fp4_e2m1":
        q_ref[...] = _pack_fp4(_encode_fp4_codes(ratio))
    elif fmt.bits == 6:
        q_ref[...] = _pack_fp6(_encode_fp6_codes(ratio, fmt))
    else:
        # exact RNE snap before the storage cast: XLA's direct fp8 cast
        # double-rounds via bf16 on some backends (see formats.py)
        q_ref[...] = F.snap_to_fp8_grid(ratio, fmt).astype(fmt.storage_dtype)
    e_ref[...] = e


def mx_quantize(
    x,
    *,
    fmt_name: str = "fp8_e4m3",
    block_size: int = 32,
    bm: int = 256,
    bk: int = 2048,
    interpret: bool = False,
):
    """Quantize ``x (M, K)`` along K. Returns (elements, e8m0_scales)."""
    fmt = F.get_format(fmt_name)
    m, k = x.shape
    bm, bk = min(bm, m), min(bk, k)
    if m % bm or k % bk or bk % block_size:
        raise ValueError(f"tiling mismatch: {(m, k)} vs {(bm, bk)}/{block_size}")
    ebk = fmt.storage_len(bk)
    ek = fmt.storage_len(k)
    nb = bk // block_size
    grid = (m // bm, k // bk)
    kernel = functools.partial(_mx_quantize_kernel, fmt=fmt, block_size=block_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, ebk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, nb), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, ek), fmt.storage_dtype),
            jax.ShapeDtypeStruct((m, k // block_size), jnp.uint8),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)
