"""Layer-fused megakernel: the ENTIRE ragged engine step as one pallas_call.

VMXDOTP's core argument is that MX's multi-step mixed-precision semantics
fragment regular pipelines — the fix is fusing the whole block-scaled
dot-product chain into one instruction so utilization stays dense. Our
serving stack had the same fragmentation one level up: the ragged step
(``mx_attention_ragged_fused``) fused decode/verify/prefill rows into one
dispatch *per layer*, but an L-layer model still paid L kernel launches,
L rounds of HLO glue, and an HBM round-trip of the residual stream (and
every q/k/v/attention/FFN intermediate) at every layer boundary.

This kernel runs the full attention-only decoder stack in ONE grid::

    grid = (L, R, KVH, P)      all dimensions sequential ("arbitrary")

with per-layer weights stacked along a leading ``L`` axis and
BlockSpec-indexed by the layer grid coordinate, and the residual stream
carried across layer steps in VMEM scratch (TPU grids iterate
sequentially, so the carry is well-defined: layer ``l`` of row ``i``
always runs after layer ``l - 1`` of row ``i`` has stored its output).
Each ``(l, i, j)`` cell is the ragged kernel's page walk verbatim; around
it the kernel folds the rest of the decoder layer:

  * at ``p == 0``: RMSNorm of the carried residual, the cell's KV-head
    column slice of the fused QKV projection (+ RoPE) — column-slicing a
    matmul is bitwise identical to slicing its output, which is the same
    argument that makes the KV-head-sharded serve step exact;
  * pages ``first..valid``: the EXACT per-layer ragged page walk —
    in-register MX dequant (``_dequant_rows`` / ``_dequant_rows_mixed``),
    per-row-causal online softmax (``_flash_update``), in-kernel
    quantized K/V writes through aliased stacked-pool outputs
    (``_quantize_rows`` + code-domain merge), per-page format select,
    trash-page isolation — all helpers imported from ``mx_attention`` so
    the arithmetic (and accumulation order) is bit-identical to the
    per-layer oracle by construction;
  * at the cell's last page: the head-group's normalized output parks in
    VMEM scratch; at the LAST kv-head's last page the layer tail runs —
    output projection, residual add, FFN RMSNorm, the gated MLP, second
    residual add — by calling the nn layer's own ``linear.apply`` /
    ``rmsnorm_apply`` / ``ffn.apply`` on the loaded blocks, so every
    elementwise op and matmul matches the oracle's XLA lowering exactly.

The device dispatch count of a mixed engine step collapses from O(L) to
exactly 1, and no inter-layer intermediate (residual, q/k/v, attention
output, FFN hidden) ever reaches HBM — the serving-stack analogue of the
paper's fuse-the-whole-MX-chain-into-one-instruction thesis.

Weight/pool layouts (``L`` = layer axis, indexed by grid dim 0)::

    x0          (R, W, DM)          post-embedding residual (compute dtype)
    norm_mixer  (L, DM)             RMSNorm scales (pre-``1 +``)
    wq          (L, DM, H*D)        fused; cell (l, j) reads cols [jGD,(j+1)GD)
    wk, wv      (L, DM, KVH*D)      cell (l, j) reads cols [jD, (j+1)D)
    wo          (L, H*D, DM)
    norm_ffn    (L, DM)
    gate/up     (L, DM, DFF)        (gate absent for ffn_kind "gelu")
    down        (L, DFF, DM)
    pools       (L, NP, PS, KVH, ED/NB)  stacked per-layer MX page pools
    page_table  (R, P) i32          shared by all layers; entries < 0 map
                                    to each layer's trash page (NP - 1)
    row_start   (R,) i32            first new-token row per ragged row
    seq_lens    (R,) i32            row_start + n_new

Returns ``(x (R, W, DM) final residual, (ke, ks, ve, vs) updated stacked
pools)`` — pool outputs alias the inputs. The final norm, logit-row
gather, and LM head stay outside (they are row-gathered to ``num_logits``
rows first; fusing the vocab matmul would multiply VMEM pressure for no
dispatch win). ``debug_visits=True`` additionally returns the
(L, R, KVH, 1) executed-page counter: each layer's page walk visits
exactly the pages the per-layer ragged kernel reports, so summing over
``L`` gives the whole step's page-visit audit.

VMEM budget note: every per-layer weight block must fit in VMEM
simultaneously with a pool tile, so very wide FFN blocks (8B-class
``DM x DFF``) exceed a real TPU core's ~16 MB VMEM — on hardware that
point needs an extra DFF-tiling grid dimension (a follow-on); off-TPU
interpret mode and the test/benchmark model sizes are unaffected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F

from .compat import CompilerParams
from .mx_attention import (NEG_INF, _check_fmt, _dequant_rows,
                           _dequant_rows_mixed, _first_window_page,
                           _flash_update, _quantize_rows,
                           MIXED_FMTS_DEFAULT)


def _mx_megakernel(*refs, page_size: int, fmt_name: str, block_size: int,
                   softcap, window, width: int, group: int, kvh: int,
                   head_dim: int, d_model: int, rope_theta: float,
                   norm_eps: float, ffn_kind: str, has_gate: bool, quant,
                   compute_dtype, mixed_fmts=None):
    """One page tile of one (layer, row, kv-head) megakernel cell."""
    # the nn layer's own math, applied in-kernel on loaded blocks so the
    # op sequence (and therefore every f32/bf16 rounding) matches the
    # per-layer oracle exactly; imported lazily to keep kernels <-> nn
    # imports acyclic
    from repro.nn import ffn as ffn_mod
    from repro.nn import linear
    from repro.nn.norms import rmsnorm_apply
    from repro.nn.rotary import apply_rope

    nw = 8 if has_gate else 7  # weight operands before the pools
    if mixed_fmts is None:
        (tbl_ref, start_ref, lens_ref, x0_ref, *rest) = refs
        fmts_ref = None
    else:
        (tbl_ref, start_ref, lens_ref, fmts_ref, x0_ref, *rest) = refs
    w_refs = rest[:nw + 1]
    (ke_ref, ks_ref, ve_ref, vs_ref, xo_ref,
     oke_ref, oks_ref, ove_ref, ovs_ref, visits_ref,
     m_ref, l_ref, acc_ref, q_s, kn_s, vn_s, attn_s, x_s) = rest[nw + 1:]
    if has_gate:
        (nm_ref, wq_ref, wk_ref, wv_ref, wo_ref, nf_ref,
         gate_ref, up_ref, down_ref) = w_refs
    else:
        (nm_ref, wq_ref, wk_ref, wv_ref, wo_ref, nf_ref,
         up_ref, down_ref) = w_refs
        gate_ref = None

    li = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    p = pl.program_id(3)
    last = pl.num_programs(3) - 1
    rows = width * group
    rs = pl.ds(i * width, width)

    @pl.when((li == 0) & (j == 0) & (p == 0))
    def _load_residual():
        # the residual stream enters VMEM exactly once per step (layer 0)
        # and lives in scratch until the last layer writes it back out
        x_s[rs, :] = x0_ref[0]

    start = start_ref[i]
    seq_len = lens_ref[i]
    n_new = seq_len - start
    w0 = start // page_size
    valid_pages = pl.cdiv(seq_len, page_size)
    first_page = _first_window_page(start, window, page_size)

    @pl.when(p == 0)
    def _start_cell():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        visits_ref[0, 0, 0, 0] = 0
        # this layer's pre-norm + this cell's KV-head slice of the fused
        # QKV projection (+ RoPE): the wq/wk/wv BlockSpecs already carved
        # out columns [j*G*D, (j+1)*G*D) / [j*D, (j+1)*D), and a
        # column-sliced matmul is bitwise identical to slicing the full
        # product — the same KV-major layout argument the sharded step
        # relies on. rmsnorm is recomputed per kv-head cell (same inputs,
        # same ops, bit-identical result; DM-wide, so the recompute is
        # noise next to the page walk).
        x = x_s[rs, :]
        h = rmsnorm_apply({"scale": nm_ref[0]}, x, norm_eps)
        q = linear.apply({"w": wq_ref[0]}, h, quant, compute_dtype)
        k = linear.apply({"w": wk_ref[0]}, h, quant, compute_dtype)
        v = linear.apply({"w": wv_ref[0]}, h, quant, compute_dtype)
        posv = start + jax.lax.broadcasted_iota(
            jnp.int32, (1, width), 1)[0]  # (W,)
        q = apply_rope(q.reshape(width, group, head_dim), posv, rope_theta)
        k = apply_rope(k.reshape(width, 1, head_dim), posv, rope_theta)
        q_s[...] = q.reshape(rows, head_dim)
        kn_s[...] = k.reshape(width, head_dim)
        vn_s[...] = v.reshape(width, head_dim)

    def _attend_tile(k, v):
        q = q_s[...].astype(jnp.float32)  # (W * G, D)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        t = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // group
        qpos = start + jnp.minimum(t, n_new - 1)
        mask = kpos <= qpos  # (R, PS)
        if window is not None:
            mask &= kpos > qpos - window
        _flash_update(m_ref, l_ref, acc_ref, q, k, v, mask, softcap)

    @pl.when((p >= first_page) & (p < w0))
    def _resident_page():
        visits_ref[0, 0, 0, 0] += 1
        if mixed_fmts is None:
            k = _dequant_rows(ke_ref[0, 0, :, 0, :], ks_ref[0, 0, :, 0, :],
                              fmt_name, block_size)  # (PS, D)
            v = _dequant_rows(ve_ref[0, 0, :, 0, :], vs_ref[0, 0, :, 0, :],
                              fmt_name, block_size)
        else:
            fid = fmts_ref[tbl_ref[i, p]]
            k = _dequant_rows_mixed(ke_ref[0, 0, :, 0, :],
                                    ks_ref[0, 0, :, 0, :],
                                    fid, mixed_fmts, block_size)
            v = _dequant_rows_mixed(ve_ref[0, 0, :, 0, :],
                                    vs_ref[0, 0, :, 0, :],
                                    fid, mixed_fmts, block_size)
        _attend_tile(k, v)

    @pl.when((p >= w0) & (p < valid_pages))
    def _write_page():
        visits_ref[0, 0, 0, 0] += 1
        kw = kn_s[...].astype(jnp.float32)  # (W, D) wide new rows
        vw = vn_s[...].astype(jnp.float32)
        # one-hot scatter + code-domain merge + aliased write: verbatim
        # the per-layer ragged kernel's write window (same helpers, same
        # accumulation order)
        jrow = jax.lax.broadcasted_iota(
            jnp.int32, (page_size, width), 0)  # page row
        tcol = jax.lax.broadcasted_iota(
            jnp.int32, (page_size, width), 1)  # new-row index
        kpos_rows = p * page_size + jrow[:, :1]  # (PS, 1)
        onehot = ((start + tcol) == (p * page_size + jrow)
                  ).astype(jnp.float32)  # (PS, W)
        k_page = jax.lax.dot_general(
            onehot, kw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (PS, D)
        v_page = jax.lax.dot_general(
            onehot, vw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        kq_e, kq_s = _quantize_rows(k_page, fmt_name, block_size)
        vq_e, vq_s = _quantize_rows(v_page, fmt_name, block_size)
        if mixed_fmts is not None:
            kq_e = jax.lax.bitcast_convert_type(kq_e, jnp.uint8)
            vq_e = jax.lax.bitcast_convert_type(vq_e, jnp.uint8)
        in_w = (kpos_rows >= start) & (kpos_rows < seq_len)  # (PS, 1)
        k_codes = jnp.where(in_w, kq_e, ke_ref[0, 0, :, 0, :])
        v_codes = jnp.where(in_w, vq_e, ve_ref[0, 0, :, 0, :])
        k_scales = jnp.where(in_w, kq_s, ks_ref[0, 0, :, 0, :])
        v_scales = jnp.where(in_w, vq_s, vs_ref[0, 0, :, 0, :])
        oke_ref[0, 0, :, 0, :] = k_codes
        ove_ref[0, 0, :, 0, :] = v_codes
        oks_ref[0, 0, :, 0, :] = k_scales
        ovs_ref[0, 0, :, 0, :] = v_scales
        if mixed_fmts is None:
            _attend_tile(
                _dequant_rows(k_codes, k_scales, fmt_name, block_size),
                _dequant_rows(v_codes, v_scales, fmt_name, block_size))
        else:
            fid = fmts_ref[tbl_ref[i, p]]
            _attend_tile(
                _dequant_rows_mixed(k_codes, k_scales, fid, mixed_fmts,
                                    block_size),
                _dequant_rows_mixed(v_codes, v_scales, fid, mixed_fmts,
                                    block_size))

    @pl.when(p == last)
    def _finish_head():
        # normalized head-group output parks in scratch until the layer's
        # last kv-head cell assembles the full attention output — same
        # f32 value the per-layer kernel writes to its output ref
        attn_s[pl.ds(j * rows, rows), :] = acc_ref[...] / l_ref[...]

    @pl.when((j == kvh - 1) & (p == last))
    def _layer_tail():
        x = x_s[rs, :]
        # (KVH, W, G, D) -> (W, KVH*G*D): exactly the oracle wrapper's
        # transpose(0, 2, 1, 3, 4) + reshape, per row
        out = attn_s[...].reshape(kvh, width, group, head_dim)
        out = out.transpose(1, 0, 2, 3).reshape(width,
                                                kvh * group * head_dim)
        out = out.astype(compute_dtype)
        h = linear.apply({"w": wo_ref[0]}, out, quant, compute_dtype,
                         tp_on="in")
        x = x + h
        # the dense gated MLP tail (blocks._decode_tail with ffn "dense"):
        # same rmsnorm + ffn.apply calls on the loaded stacked blocks
        h = rmsnorm_apply({"scale": nf_ref[0]}, x, norm_eps)
        fparams = {"up": {"w": up_ref[0]}, "down": {"w": down_ref[0]}}
        if has_gate:
            fparams["gate"] = {"w": gate_ref[0]}
        h = ffn_mod.apply(fparams, h, quant, ffn_kind, compute_dtype)
        x = x + h
        x_s[rs, :] = x
        # the residual output block is (re)written at every layer; the
        # last flush (layer L-1) is what lands in HBM
        xo_ref[0] = x


def mx_megakernel_step(x0, norm_mixer, wq, wk, wv, wo, norm_ffn, gate, up,
                       down, ke_pool, ks_pool, ve_pool, vs_pool, page_table,
                       row_start, seq_lens, *, head_dim: int,
                       rope_theta: float, norm_eps: float, ffn_kind: str,
                       quant, fmt_name: str = "fp8_e4m3",
                       block_size: int = 32, softcap=None, window=None,
                       compute_dtype=jnp.bfloat16, page_fmts=None,
                       mixed_fmts=None, debug_visits: bool = False,
                       interpret: bool | None = None):
    """Run the whole decoder layer stack over a ragged row batch as ONE
    pallas_call. See the module docstring for layouts and semantics.

    ``gate`` is None for ffn_kind "gelu". ``quant`` is the model's
    ``QuantConfig`` (weight-only or disabled; activation quantization is
    rejected by the engine's fallback ladder). Pool layouts, the
    trash-page contract, and ``page_fmts``/``mixed_fmts`` match
    ``mx_attention_ragged_fused`` with a leading layer axis.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mixed = page_fmts is not None
    _check_fmt(ke_pool, fmt_name, mixed=mixed)
    if mixed:
        if mixed_fmts is None:
            mixed_fmts = MIXED_FMTS_DEFAULT
        mixed_fmts = tuple(mixed_fmts)
        if F.get_format(fmt_name).bits != 8:
            raise ValueError(
                "tiered megakernel steps write the window in the hot "
                f"format, which must be an fp8; got {fmt_name!r}")
    else:
        mixed_fmts = None
    if quant is not None and quant.enabled:
        if quant.quantize_acts:
            raise ValueError(
                "the megakernel runs weight-only or unquantized linears; "
                "activation quantization is rejected by the engine's "
                "fallback ladder")
        # Pre-fake-quantize the stacked weights OUTSIDE the kernel: the
        # per-layer oracle fake-quants each layer's weight at use
        # (linear.apply, axis 0 = the contraction dim), and blocking the
        # (L, d_in, d_out) stack along axis 1 is the same computation per
        # layer — bit-identical values. Hoisting it keeps the in-kernel
        # linears on the plain-matmul path, which (a) avoids re-deriving
        # the quantization grid in every grid cell and (b) keeps fp4/fp6
        # value-grid lookup tables out of the kernel trace (Pallas rejects
        # captured constant arrays).
        from repro.core import fake_quant

        def _prequant(ws):
            wq_ = fake_quant(ws.astype(jnp.float32), quant.fmt,
                             quant.block_size, 1)
            return wq_.astype(compute_dtype)

        wq, wk, wv, wo = (_prequant(t) for t in (wq, wk, wv, wo))
        up, down = _prequant(up), _prequant(down)
        if gate is not None:
            gate = _prequant(gate)
        quant = quant.replace(enabled=False)
    r, w, dm = x0.shape
    layers, npages, ps = ke_pool.shape[0], ke_pool.shape[1], ke_pool.shape[2]
    ed = ke_pool.shape[-1]
    nb = ks_pool.shape[-1]
    d = head_dim
    hd = wq.shape[-1]
    kvh = wk.shape[-1] // d
    g = (hd // d) // kvh
    rows = w * g
    pmax = page_table.shape[1]
    has_gate = gate is not None
    table = jnp.asarray(page_table, jnp.int32)
    table = jnp.where(table < 0, npages - 1,
                      jnp.clip(table, 0, npages - 1))
    start = jnp.asarray(row_start, jnp.int32)
    lens = jnp.clip(jnp.asarray(seq_lens, jnp.int32), start + 1, start + w)

    def pool_in_spec(width_):
        def imap(li, i, j, p, tbl, st, ln, *_fmts):
            valid = pl.cdiv(ln[i], ps)
            first = _first_window_page(st[i], window, ps)
            return (li, tbl[i, jnp.clip(p, first, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, 1, ps, 1, width_), imap)

    def pool_out_spec(width_):
        def imap(li, i, j, p, tbl, st, ln, *_fmts):
            w0 = st[i] // ps
            valid = pl.cdiv(ln[i], ps)
            return (li, tbl[i, jnp.clip(p, w0, valid - 1)], 0, j, 0)
        return pl.BlockSpec((1, 1, ps, 1, width_), imap)

    def wspec(shape, imap):
        return pl.BlockSpec(shape, imap)

    in_specs = [
        # x0: one (W, DM) slab per row, read once at layer 0
        wspec((1, w, dm), lambda li, i, j, p, *_: (i, 0, 0)),
        wspec((1, dm), lambda li, i, j, p, *_: (li, 0)),       # norm_mixer
        wspec((1, dm, g * d), lambda li, i, j, p, *_: (li, 0, j)),  # wq
        wspec((1, dm, d), lambda li, i, j, p, *_: (li, 0, j)),      # wk
        wspec((1, dm, d), lambda li, i, j, p, *_: (li, 0, j)),      # wv
        wspec((1, hd, dm), lambda li, i, j, p, *_: (li, 0, 0)),     # wo
        wspec((1, dm), lambda li, i, j, p, *_: (li, 0)),       # norm_ffn
    ]
    weight_ops = [x0, norm_mixer, wq, wk, wv, wo, norm_ffn]
    if has_gate:
        dff = gate.shape[-1]
        in_specs.append(
            wspec((1, dm, dff), lambda li, i, j, p, *_: (li, 0, 0)))
        weight_ops.append(gate)
    dff = up.shape[-1]
    in_specs += [
        wspec((1, dm, dff), lambda li, i, j, p, *_: (li, 0, 0)),    # up
        wspec((1, dff, dm), lambda li, i, j, p, *_: (li, 0, 0)),    # down
        pool_in_spec(ed), pool_in_spec(nb),
        pool_in_spec(ed), pool_in_spec(nb),
    ]
    weight_ops += [up, down]

    scalar_ops = [table, start, lens]
    if mixed:
        scalar_ops.append(jnp.asarray(page_fmts, jnp.int32))
    ns = len(scalar_ops)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=ns,
        grid=(layers, r, kvh, pmax),
        in_specs=in_specs,
        out_specs=[
            # final residual: one (W, DM) slab per row, flushed at every
            # layer boundary — the last flush (layer L-1) wins
            wspec((1, w, dm), lambda li, i, j, p, *_: (i, 0, 0)),
            pool_out_spec(ed), pool_out_spec(nb),
            pool_out_spec(ed), pool_out_spec(nb),
            wspec((1, 1, 1, 1), lambda li, i, j, p, *_: (li, i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # running max m
            pltpu.VMEM((rows, 1), jnp.float32),   # running denominator l
            pltpu.VMEM((rows, d), jnp.float32),   # rescaled partial output
            pltpu.VMEM((rows, d), compute_dtype),  # q (this cell's slice)
            pltpu.VMEM((w, d), compute_dtype),    # new K rows (RoPE'd)
            pltpu.VMEM((w, d), compute_dtype),    # new V rows
            pltpu.VMEM((kvh * rows, d), jnp.float32),  # per-head attn out
            pltpu.VMEM((r * w, dm), compute_dtype),    # residual carry
        ],
    )
    kernel = functools.partial(
        _mx_megakernel, page_size=ps, fmt_name=fmt_name,
        block_size=block_size, softcap=softcap, window=window, width=w,
        group=g, kvh=kvh, head_dim=d, d_model=dm, rope_theta=rope_theta,
        norm_eps=norm_eps, ffn_kind=ffn_kind, has_gate=has_gate,
        quant=quant, compute_dtype=compute_dtype, mixed_fmts=mixed_fmts)
    nin = len(weight_ops)  # operands between the scalars and the pools
    x_out, oke, oks, ove, ovs, visits = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, w, dm), x0.dtype),
            jax.ShapeDtypeStruct(ke_pool.shape, ke_pool.dtype),
            jax.ShapeDtypeStruct(ks_pool.shape, ks_pool.dtype),
            jax.ShapeDtypeStruct(ve_pool.shape, ve_pool.dtype),
            jax.ShapeDtypeStruct(vs_pool.shape, vs_pool.dtype),
            jax.ShapeDtypeStruct((layers, r, kvh, 1), jnp.int32),
        ],
        # stacked pools update in place (operand indices count the
        # scalar-prefetch operands, then x0 + weights, then the pools)
        input_output_aliases={ns + nin + k: 1 + k for k in range(4)},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(*scalar_ops, *weight_ops, ke_pool, ks_pool, ve_pool, vs_pool)
    pools = (oke, oks, ove, ovs)
    return ((x_out, pools, visits) if debug_visits else (x_out, pools))
