"""Pure-jnp oracles defining exact MX kernel semantics.

These implement Eq. (1)/(2) of the paper literally: per MX block, an f32 dot
product of decoded elements, multiplied by the product of the two E8M0 block
scales, summed over blocks (and accumulated into ``acc_dtype``). Every Pallas
kernel is validated against these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F


def decode_scaled(elems, scales, fmt, block_size):
    """Decode (..., K)-stored MX data to blocked f32: (..., KB, k) + scales."""
    vals = F.decode_elements(elems, fmt, jnp.float32)
    kb = scales.shape[-1]
    blocked = vals.reshape(*vals.shape[:-1], kb, block_size)
    return blocked, F.e8m0_to_scale(scales)


def mx_matmul_ref(
    a_elems,
    a_scales,
    b_elems,
    b_scales,
    *,
    fmt="fp8_e4m3",
    block_size: int = 32,
    acc_dtype=jnp.float32,
):
    """MX x MX matmul oracle (vector-vector variant, paper Eq. (2)).

    Layout contract (matches MXTensor with the blocked axis last):
      a_elems: (M, K) storage, a_scales: (M, KB)
      b_elems: (N, K) storage ("column-major B", §IV-D), b_scales: (N, KB)
    Returns C: (M, N) = sum_b sA[m,b] * sB[n,b] * <A[m,b,:], B[n,b,:]>.
    """
    A, sA = decode_scaled(a_elems, a_scales, fmt, block_size)  # (M,KB,k)
    B, sB = decode_scaled(b_elems, b_scales, fmt, block_size)  # (N,KB,k)
    partial = jnp.einsum("mbk,nbk->mnb", A, B, preferred_element_type=jnp.float32)
    scaled = partial * sA[:, None, :] * sB[None, :, :]
    return jnp.sum(scaled, axis=-1).astype(acc_dtype)


def mx_matmul_wo_ref(
    a,
    b_elems,
    b_scales,
    *,
    fmt="fp8_e4m3",
    block_size: int = 32,
    acc_dtype=jnp.float32,
):
    """Weight-only oracle (vector-scalar variant): wide A x MX B."""
    B, sB = decode_scaled(b_elems, b_scales, fmt, block_size)
    kb = sB.shape[-1]
    A = a.astype(jnp.float32).reshape(*a.shape[:-1], kb, block_size)
    partial = jnp.einsum("mbk,nbk->mnb", A, B, preferred_element_type=jnp.float32)
    return jnp.sum(partial * sB[None, :, :], axis=-1).astype(acc_dtype)


def mx_quantize_ref(x, *, fmt="fp8_e4m3", block_size: int = 32):
    """Block-quantization oracle: returns (elements_storage, e8m0_scales)."""
    fmt_i = F.get_format(fmt)
    k = x.shape[-1]
    blocked = x.astype(jnp.float32).reshape(*x.shape[:-1], k // block_size, block_size)
    amax = jnp.max(jnp.abs(blocked), axis=-1)
    e = F.e8m0_from_amax(amax, fmt_i)
    scale = F.e8m0_to_scale(e)[..., None]
    ratio = jnp.where(scale > 0, blocked / scale, 0.0).reshape(x.shape)
    return F.encode_elements(ratio, fmt_i), e


def mx_attention_decode_ref(q, k_elems, k_scales, v_elems, v_scales, kpos,
                            pos, *, fmt="fp8_e4m3", block_size: int = 32,
                            softcap=None):
    """Oracle for the MX-KV-cache decode attention kernel.

    q: (B, KVH, G, D); cache: (B, KVH, T, D) stored + (B, KVH, T, D//k)
    scales; kpos (T,), pos scalar. Returns (B, KVH, G, D) f32.
    """
    def deq(elems, scales):
        vals = F.decode_elements(elems, fmt, jnp.float32)
        nb = scales.shape[-1]
        k = vals.shape[-1] // nb
        blocked = vals.reshape(*vals.shape[:-1], nb, k)
        return (blocked * F.e8m0_to_scale(scales)[..., None]).reshape(
            vals.shape)

    k = deq(k_elems, k_scales)  # (B,KVH,T,D)
    v = deq(v_elems, v_scales)
    d = q.shape[-1]
    logits = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32), k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = (kpos <= pos) & (kpos >= 0)
    logits = jnp.where(mask[None, None, None, :], logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgt,bhtd->bhgd", p, v,
                      preferred_element_type=jnp.float32)
