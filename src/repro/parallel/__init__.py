"""Distribution layer: logical-axis sharding rules + collective helpers."""
from .sharding import (FSDP_AXES, PARAM_RULES, TP_AXIS, batch_shardings,
                       cache_shardings, constraint, make_abstract_mesh,
                       replicated, spec_for, tree_shardings)

__all__ = [
    "FSDP_AXES", "PARAM_RULES", "TP_AXIS", "batch_shardings",
    "cache_shardings", "constraint", "make_abstract_mesh",
    "replicated", "spec_for", "tree_shardings",
]
