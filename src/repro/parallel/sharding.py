"""Logical-axis sharding rules: DP/FSDP/TP/EP over the production mesh.

The mesh is (pod, data, model) — see ``launch/mesh.py``. Parameters carry
logical axis names (``nn.common``); the rules below map them to mesh axes
with divisibility-aware fallback:

  * TP  — vocab / d_ff / heads / kv_heads / expert / rnn dims shard over
    ``model`` (Megatron-style tensor parallelism; EP for expert dims),
  * FSDP — the d_model dim of weights shards over (``pod``, ``data``)
    (ZeRO-3-style: params + optimizer state fully sharded; XLA inserts the
    all-gathers and overlaps them with compute),
  * anything that does not divide evenly falls back to replication
    (e.g. MQA's kv_heads=1, mixtral's 8 experts on a 16-way model axis —
    the d_ff dim then picks up the model axis instead).

Activations are sharded via the input specs (batch over (pod, data)) and
XLA sharding propagation; `constraint` offers hand-placed overrides for the
perf iteration loop.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import common as C

FSDP_AXES = ("pod", "data")
TP_AXIS = "model"

# logical axis -> preferred mesh axes, in priority order per tensor dim
PARAM_RULES = {
    C.VOCAB: (TP_AXIS,),
    C.D_FF: (TP_AXIS,),
    C.HEADS: (TP_AXIS,),
    C.KV_HEADS: (TP_AXIS,),
    C.EXPERT: (TP_AXIS,),
    C.RNN: (TP_AXIS,),
    C.KV_LORA: (TP_AXIS,),
    C.D_MODEL: FSDP_AXES,
    C.LAYERS: (),
    C.CONV: (),
    C.STATE: (),
    C.HEAD_DIM: (),
    C.BATCH: ("pod", "data"),
    C.SEQ: (),
}


def make_abstract_mesh(shape, axis_names):
    """Device-free AbstractMesh across JAX versions.

    Newer JAX takes ``(shape, names)``; older JAX takes a single tuple of
    ``(name, size)`` pairs. Used by sharding-rule tests and dry-run tooling
    that reason about placement without 512 real devices.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))


def _mesh_axes_present(mesh: Mesh, axes):
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for(mesh: Mesh, dims, axes_names) -> P:
    """Build a PartitionSpec for one array given its logical axes."""
    used = set()
    entries = []
    for dim, name in zip(dims, axes_names):
        cand = _mesh_axes_present(mesh, PARAM_RULES.get(name, ()))
        cand = tuple(a for a in cand if a not in used)
        size = int(np.prod([mesh.shape[a] for a in cand])) if cand else 1
        if cand and dim % size == 0 and dim >= size:
            entries.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            entries.append(None)
    return P(*entries)


def tree_shardings(mesh: Mesh, params_or_shapes, axes_tree):
    """NamedSharding tree for a params tree (arrays or ShapeDtypeStructs)."""
    flat_p, treedef = jax.tree_util.tree_flatten(params_or_shapes)
    flat_a = treedef.flatten_up_to(axes_tree)
    out = [
        NamedSharding(mesh, spec_for(mesh, p.shape, a))
        for p, a in zip(flat_p, flat_a)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(mesh: Mesh, batch_shapes):
    """Input batch: leading batch dim over (pod, data), rest replicated."""
    axes = _mesh_axes_present(mesh, ("pod", "data"))

    def one(s):
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if s.shape and s.shape[0] % size == 0 and size > 1:
            return NamedSharding(
                mesh, P(axes if len(axes) > 1 else axes[0],
                        *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(s.shape))))

    return jax.tree_util.tree_map(one, batch_shapes)


def cache_shardings(mesh: Mesh, cache_shapes, batch_size: int):
    """KV-cache shardings: shard the *batch* dim over (pod, data).

    Stacked group caches carry a leading layers dim, so the batch dim is
    located by size (first dim == batch_size), not by position — sharding
    dim 0 blindly replicates the cache and forces an all-gather of the
    entire KV state every decode step (§Perf iteration 11, deepseek
    decode_32k: a 3.4 TB/step gather).
    """
    axes = _mesh_axes_present(mesh, ("pod", "data"))
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def one(s):
        entries = [None] * len(s.shape)
        if axes and size > 1:
            for i, d in enumerate(s.shape):
                if d == batch_size and d % size == 0:
                    entries[i] = axes if len(axes) > 1 else axes[0]
                    break
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map(one, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def serve_param_specs(params, axis: str = TP_AXIS):
    """PartitionSpec tree for the KV-head-sharded serve step's params.

    Attention projections are recognized structurally (a dict carrying
    all of wq/wk/wv/wo — ``attention.init``'s output, whether stacked
    under a scanned group or not): wq/wk/wv shard their *output* (heads)
    dim on ``axis`` — heads are laid out KV-major, so a contiguous
    column shard is exactly the device's KV-head slice — while ``wo``
    and every other parameter stay replicated.

    This deliberately deviates from ``PARAM_RULES`` (which would also
    shard ``wo``'s heads input dim): a row-sharded ``wo`` needs a psum
    that *splits* the f32 contraction across devices, and a split
    reduction is not bit-identical to the single-device matmul. The
    serve step instead all-gathers the (small) attention output over
    the KV-head axis and runs the replicated ``wo`` — the token-identity
    guarantee the engine tests pin down. Everything outside attention is
    replicated because it is already per-token work the engine runs in
    lockstep on each device.

    The megakernel's packed params (``model.pack_megakernel_params``)
    keep the ``wq/wk/wv/wo`` key structure with a leading stacked-layer
    axis, so this walk covers them too: head columns stay the last dim
    of each stacked leaf, the layer axis lands on a leading ``None``.
    ``megakernel_param_specs`` below pins that down for the sharded-
    megakernel ROADMAP rung.
    """
    def shard_last(a):
        return P(*([None] * (a.ndim - 1)), axis)

    def rep(node):
        return jax.tree_util.tree_map(lambda a: P(), node)

    def walk(node):
        if isinstance(node, dict):
            if {"wq", "wk", "wv", "wo"} <= set(node):
                return {name: (jax.tree_util.tree_map(shard_last, sub)
                               if name in ("wq", "wk", "wv") else rep(sub))
                        for name, sub in node.items()}
            return {key: walk(val) for key, val in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return rep(node)

    return walk(params)


def megakernel_param_specs(packed, axis: str = TP_AXIS):
    """PartitionSpec tree for a ``pack_megakernel_params`` tree.

    Groundwork for running the layer-fused megakernel under the serve
    engine's KV-head ``shard_map`` (ROADMAP rung — the engine currently
    falls back to the per-layer ragged step on a >1-way mesh): the
    stacked ``(L, d_in, heads*head_dim)`` q/k/v leaves shard their head
    columns on ``axis`` exactly like the per-layer specs, layer axis
    replicated, everything else replicated. Delegates to
    ``serve_param_specs``'s structural walk — the packed dict keeps the
    wq/wk/wv/wo keys precisely so that recognition still fires — and
    exists as a named entry point so tests can pin the stacked layout's
    placement independently of the per-layer one.
    """
    return serve_param_specs(packed, axis)


def constraint(x, mesh: Mesh, *spec_entries):
    """Hand-placed activation sharding constraint (perf-iteration hook)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec_entries)))
