"""Mesh context + activation sharding constraints (no-op off-mesh).

Model code calls ``maybe_constrain(x, "batch", None, "seq_model", ...)``
with *logical* entries; under an active mesh (set by the launchers) these
become ``with_sharding_constraint`` placements, filtered for axis presence
and divisibility. On CPU tests (no mesh) they are identity — the same model
code runs everywhere.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh", default=None)

# logical activation entries -> mesh axes
ACT_ENTRIES = {
    "batch": ("pod", "data"),
    "seq_model": ("model",),  # sequence parallelism over the TP axis
    "model": ("model",),
    "tokens_all": ("pod", "data", "model"),  # flat token dim, all axes
    None: (),
}


def current_mesh() -> Optional[Mesh]:
    return _CURRENT_MESH.get()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    token = _CURRENT_MESH.set(mesh)
    try:
        yield
    finally:
        _CURRENT_MESH.reset(token)


_SERVE_TP_AXIS: contextvars.ContextVar = contextvars.ContextVar(
    "repro_serve_tp_axis", default=None)


def serve_tp_axis() -> Optional[str]:
    """Mesh axis name the serve step is KV-head-sharded over, or None.

    Set only *inside* the body of the engine's ``shard_map``-wrapped step
    (a trace-time signal, not a runtime one): attention's fused apply
    paths read it to learn that their K/V pools and q/k/v projections
    carry only ``KVH / mesh.shape[axis]`` local heads and that the
    kernel output must be all-gathered over this axis before the
    (replicated) output projection. Everything outside the serve step —
    training, the single-device engine, the einsum oracles — sees None
    and runs unchanged.
    """
    return _SERVE_TP_AXIS.get()


@contextlib.contextmanager
def use_serve_tp(axis_name: Optional[str]):
    token = _SERVE_TP_AXIS.set(axis_name)
    try:
        yield
    finally:
        _SERVE_TP_AXIS.reset(token)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=None,
                     axis_names=None):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(check_vma=..., axis_names=...)``;
    older JAX has ``jax.experimental.shard_map.shard_map(check_rep=...,
    auto=...)`` where ``auto`` is the complement of the manual axis set.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def maybe_constrain(x, *entries):
    """Apply a logical sharding constraint if a mesh is active."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = []
    used = set()
    for dim, entry in zip(x.shape, entries):
        axes = tuple(a for a in ACT_ENTRIES.get(entry, ())
                     if a in mesh.axis_names and a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and dim % size == 0 and dim >= size:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            spec.append(None)
    # pad remaining dims
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
