"""Gated feed-forward blocks (SwiGLU / GeGLU / GELU) over MX linears."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantConfig

from . import common as C
from . import linear


def init(key, d_model: int, d_ff: int, kind: str = "swiglu"):
    ks = C.split_keys(key, 3)
    gate, ga = linear.init(ks[0], d_model, d_ff, (C.D_MODEL, C.D_FF))
    up, ua = linear.init(ks[1], d_model, d_ff, (C.D_MODEL, C.D_FF))
    down, da = linear.init(ks[2], d_ff, d_model, (C.D_FF, C.D_MODEL))
    params = {"gate": gate, "up": up, "down": down}
    axes = {"gate": ga, "up": ua, "down": da}
    if kind == "gelu":  # no gate branch
        params.pop("gate")
        axes.pop("gate")
    return params, axes


def apply(params, x, quant: QuantConfig, kind: str = "swiglu",
          compute_dtype=jnp.bfloat16):
    up = linear.apply(params["up"], x, quant, compute_dtype)
    if kind == "gelu":
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(compute_dtype)
    else:
        gate = linear.apply(params["gate"], x, quant, compute_dtype)
        g32 = gate.astype(jnp.float32)
        act = jax.nn.silu(g32) if kind == "swiglu" else jax.nn.gelu(g32, approximate=True)
        h = (act.astype(compute_dtype) * up)
    return linear.apply(params["down"], h, quant, compute_dtype, tp_on="in")
