"""Gated feed-forward blocks (SwiGLU / GeGLU / GELU) over MX linears."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantConfig

from . import common as C
from . import linear


def init(key, d_model: int, d_ff: int, kind: str = "swiglu"):
    ks = C.split_keys(key, 3)
    gate, ga = linear.init(ks[0], d_model, d_ff, (C.D_MODEL, C.D_FF))
    up, ua = linear.init(ks[1], d_model, d_ff, (C.D_MODEL, C.D_FF))
    down, da = linear.init(ks[2], d_ff, d_model, (C.D_FF, C.D_MODEL))
    params = {"gate": gate, "up": up, "down": down}
    axes = {"gate": ga, "up": ua, "down": da}
    if kind == "gelu":  # no gate branch
        params.pop("gate")
        axes.pop("gate")
    return params, axes


def apply(params, x, quant: QuantConfig, kind: str = "swiglu",
          compute_dtype=jnp.bfloat16):
    up = linear.apply(params["up"], x, quant, compute_dtype)
    # activation narrowings go through C.round_to, not bare astype: these
    # casts sit between elementwise ops, where XLA's excess-precision
    # fusion may skip the rounding — which would make the layer-fused
    # megakernel (one fused kernel jaxpr) round differently from the
    # per-layer step and break their bit-identity
    if kind == "gelu":
        h = C.round_to(jax.nn.gelu(up.astype(jnp.float32)), compute_dtype)
    else:
        gate = linear.apply(params["gate"], x, quant, compute_dtype)
        g32 = gate.astype(jnp.float32)
        act = jax.nn.silu(g32) if kind == "swiglu" else jax.nn.gelu(g32, approximate=True)
        # product of two compute-dtype values is exact in f32, so one
        # explicit rounding == true narrow-multiply semantics
        h = C.round_to(
            C.round_to(act, compute_dtype).astype(jnp.float32)
            * up.astype(jnp.float32),
            compute_dtype,
        )
    return linear.apply(params["down"], h, quant, compute_dtype, tp_on="in")
