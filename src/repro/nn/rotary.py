"""Rotary position embeddings (RoPE), with partial-dim support for MLA."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0):
    # built from iota rather than a jnp.arange constant so the SAME
    # function traces inside Pallas kernels (which reject captured array
    # constants) — the layer-fused megakernel applies RoPE in-kernel via
    # this exact code path, and identical ops keep it bit-identical to
    # the outside-the-kernel oracle
    exponent = 2.0 * jax.lax.broadcasted_iota(
        jnp.float32, (1, head_dim // 2), 1)[0] / head_dim
    return 1.0 / (theta**exponent)  # (head_dim // 2,)


def apply_rope(x, positions, theta: float = 10000.0):
    """Rotate ``x (..., S, H, D)`` by ``positions (..., S)``.

    Interleaved-pair convention (llama-style split halves).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    # sin/cos tables in f32 (position * freq must not round), applied in
    # the activation dtype: rotations are well-conditioned, and bf16
    # application halves the rope HBM traffic (§Perf iteration 3).
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
