"""Pure-JAX model zoo with first-class MX quantization."""
from . import (attention, blocks, common, config, embedding, ffn, linear,
               mla, model, moe, norms, rglru, rotary, ssd)
from .config import BlockDef, ModelConfig

__all__ = [
    "attention", "blocks", "common", "config", "embedding", "ffn", "linear",
    "mla", "model", "moe", "norms", "rglru", "rotary", "ssd",
    "BlockDef", "ModelConfig",
]
