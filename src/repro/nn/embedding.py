"""Token embedding + LM head (tied or untied), logit softcapping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as C


def init(key, vocab: int, d_model: int, tied: bool = True):
    k1, k2 = jax.random.split(key)
    params = {"embed": jax.random.normal(k1, (vocab, d_model)) * 0.01}
    axes = {"embed": (C.VOCAB, C.D_MODEL)}
    if not tied:
        params["head"] = C.truncated_normal_init(k2, (d_model, vocab), 1.0)
        axes["head"] = (C.D_MODEL, C.VOCAB)
    return params, axes


def embed(params, tokens, scale_by_sqrt_dim: bool, compute_dtype=jnp.bfloat16):
    d = params["embed"].shape[-1]
    x = params["embed"].astype(compute_dtype)[tokens]
    if scale_by_sqrt_dim:
        x = x * jnp.asarray(d, jnp.float32).astype(compute_dtype) ** 0.5
    return x


def logits(params, x, softcap=None, compute_dtype=jnp.bfloat16):
    """Project hidden states to vocab logits (tied embedding transpose)."""
    if "head" in params:
        w = params["head"].astype(compute_dtype)
    else:
        w = params["embed"].astype(compute_dtype).T
    out = jnp.einsum("...d,dv->...v", x.astype(compute_dtype), w)
    if softcap:
        out = jnp.tanh(out.astype(jnp.float32) / softcap) * softcap
        return out  # f32 for the loss
    return out.astype(jnp.float32)
