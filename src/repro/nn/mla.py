"""Multi-head Latent Attention (DeepSeek-V2), MX-quantized projections.

V2-Lite configuration: KV jointly compressed to a 512-dim latent plus a
64-dim decoupled RoPE key shared across heads; queries are full-rank
(V2-Lite skips q compression). The decode cache stores only the latent +
rope key — (kv_lora + rope_dim) per token instead of 2*H*D — which is the
arch's own KV compression; the MX-quantized-cache option stacks on top.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, quantize
from repro.core import formats as F

from . import common as C
from . import linear
from .attention import NEG_INF, _mask
from .norms import rmsnorm_apply, rmsnorm_init
from .rotary import apply_rope


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    num_heads: int
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    query_chunk: int = 1024


def init(key, cfg: MLAConfig):
    ks = C.split_keys(key, 6)
    h = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    wq, aq = linear.init(ks[0], cfg.d_model, h * qd, (C.D_MODEL, C.HEADS))
    # joint KV down-projection: latent + shared rope key
    wkv_a, akva = linear.init(
        ks[1], cfg.d_model, cfg.kv_lora + cfg.qk_rope_dim, (C.D_MODEL, C.KV_LORA)
    )
    wk_b, akb = linear.init(ks[2], cfg.kv_lora, h * cfg.qk_nope_dim,
                            (C.KV_LORA, C.HEADS))
    wv_b, avb = linear.init(ks[3], cfg.kv_lora, h * cfg.v_head_dim,
                            (C.KV_LORA, C.HEADS))
    wo, ao = linear.init(ks[4], h * cfg.v_head_dim, cfg.d_model,
                         (C.HEADS, C.D_MODEL))
    ln, lna = rmsnorm_init(ks[5], cfg.kv_lora)
    params = {"wq": wq, "wkv_a": wkv_a, "wk_b": wk_b, "wv_b": wv_b,
              "wo": wo, "kv_norm": ln}
    axes = {"wq": aq, "wkv_a": akva, "wk_b": akb, "wv_b": avb,
            "wo": ao, "kv_norm": lna}
    return params, axes


def _project_q(params, x, cfg, quant, dtype):
    b, s, _ = x.shape
    h = cfg.num_heads
    q = linear.apply(params["wq"], x, quant, dtype)
    q = q.reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def _latent(params, x, cfg, quant, dtype):
    kv = linear.apply(params["wkv_a"], x, quant, dtype)
    c_kv = kv[..., : cfg.kv_lora]
    k_rope = kv[..., cfg.kv_lora:]
    c_kv = rmsnorm_apply(params["kv_norm"], c_kv)
    return c_kv, k_rope


def _attend_mla(q_nope, q_rope, k_nope, k_rope, v, qpos, kpos, cfg, dtype):
    """Attention with decoupled rope/nope logits; k_rope shared per head."""
    d_total = cfg.qk_nope_dim + cfg.qk_rope_dim
    logits = (
        jnp.einsum("bshd,bthd->bhst", q_nope, k_nope,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * (d_total**-0.5)
    mask = _mask(qpos, kpos, None)
    while mask.ndim < logits.ndim:
        mask = mask[..., None, :, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def apply_train(params, x, positions, cfg: MLAConfig, quant: QuantConfig,
                compute_dtype=jnp.bfloat16):
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _project_q(params, x, cfg, quant, compute_dtype)
    c_kv, k_rope = _latent(params, x, cfg, quant, compute_dtype)
    k_nope = linear.apply(params["wk_b"], c_kv, quant, compute_dtype)
    k_nope = k_nope.reshape(b, s, h, cfg.qk_nope_dim)
    v = linear.apply(params["wv_b"], c_kv, quant, compute_dtype)
    v = v.reshape(b, s, h, cfg.v_head_dim)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]

    cs = cfg.query_chunk
    if s > cs and s % cs == 0:
        nc = s // cs

        def body(args):
            qn, qr, pi = args
            return _attend_mla(qn, qr, k_nope, k_rope, v, pi, positions,
                               cfg, compute_dtype)

        qn = q_nope.reshape(b, nc, cs, h, -1).swapaxes(0, 1)
        qr = q_rope.reshape(b, nc, cs, h, -1).swapaxes(0, 1)
        pc = positions.reshape(b, nc, cs).swapaxes(0, 1)
        out = jax.lax.map(body, (qn, qr, pc)).swapaxes(0, 1).reshape(b, s, h, -1)
    else:
        out = _attend_mla(q_nope, q_rope, k_nope, k_rope, v, positions,
                          positions, cfg, compute_dtype)
    return linear.apply(params["wo"], out.reshape(b, s, -1), quant,
                        compute_dtype, tp_on="in")


# -- latent cache -----------------------------------------------------------


def init_cache(batch: int, max_seq: int, cfg: MLAConfig, quant: QuantConfig):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora), jnp.bfloat16),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), jnp.bfloat16),
        "kpos": jnp.full((max_seq,), -1, jnp.int32),
    }


def apply_decode(params, x, cache, pos, cfg: MLAConfig, quant: QuantConfig,
                 compute_dtype=jnp.bfloat16):
    """Single-token decode in the *absorbed* MLA form (DeepSeek-V2 §2.1.2):

    Instead of re-expanding the whole latent cache through wk_b/wv_b every
    step (O(T * kv_lora * H * D) per layer), the per-step query is projected
    into latent space (q_eff = q_nope @ wk_b) and attention runs directly
    against the compressed cache; the value path un-absorbs afterwards.
    """
    b = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope = _project_q(params, x, cfg, quant, compute_dtype)
    c_new, kr_new = _latent(params, x, cfg, quant, compute_dtype)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, posv, cfg.rope_theta)
    kr_new = apply_rope(kr_new[..., None, :], posv, cfg.rope_theta)[..., 0, :]
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    cache["kpos"] = jax.lax.dynamic_update_slice(
        cache["kpos"], jnp.asarray(pos, jnp.int32)[None], (pos,))
    c_kv = cache["c_kv"].astype(compute_dtype)
    k_rope = cache["k_rope"].astype(compute_dtype)

    wk_b = params["wk_b"]["w"].astype(compute_dtype).reshape(
        cfg.kv_lora, h, cfg.qk_nope_dim)
    wv_b = params["wv_b"]["w"].astype(compute_dtype).reshape(
        cfg.kv_lora, h, cfg.v_head_dim)
    # absorb: query into latent space
    q_eff = jnp.einsum("bshd,lhd->bshl", q_nope, wk_b)
    d_total = cfg.qk_nope_dim + cfg.qk_rope_dim
    logits = (
        jnp.einsum("bshl,btl->bhst", q_eff, c_kv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * (d_total**-0.5)
    mask = _mask(posv, cache["kpos"][None], None)
    while mask.ndim < logits.ndim:
        mask = mask[..., None, :, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
    out_lat = jnp.einsum("bhst,btl->bshl", probs, c_kv)
    out = jnp.einsum("bshl,lhd->bshd", out_lat, wv_b)  # un-absorb values
    y = linear.apply(params["wo"], out.reshape(b, 1, -1), quant,
                     compute_dtype, tp_on="in")
    return y, cache


def prefill_cache(params, x, positions, cfg: MLAConfig, quant: QuantConfig,
                  max_seq: int, compute_dtype=jnp.bfloat16):
    b, s = positions.shape
    cache = init_cache(b, max_seq, cfg, quant)
    c_kv, k_rope = _latent(params, x, cfg, quant, compute_dtype)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    cache["c_kv"] = cache["c_kv"].at[:, :s].set(c_kv.astype(jnp.bfloat16))
    cache["k_rope"] = cache["k_rope"].at[:, :s].set(k_rope.astype(jnp.bfloat16))
    cache["kpos"] = cache["kpos"].at[:s].set(positions[0])
    return cache
