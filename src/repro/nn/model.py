"""LM assembly: embedding -> scanned block groups -> head; train & serve.

The repeated ``pattern`` runs under ``jax.lax.scan`` with rematerialization,
so compile time and HLO size are O(|pattern|) regardless of depth, and
activation memory is O(1 group) — both required for the 512-device dry-runs
of 56-layer models. Prologue/epilogue blocks (e.g. deepseek's first dense
layer) run unscanned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import blocks, common as C, embedding
from .config import BlockDef, ModelConfig
from .norms import rmsnorm_apply, rmsnorm_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig):
    ks = C.split_keys(key, 4 + len(cfg.prologue) + len(cfg.epilogue))
    params, axes = {}, {}
    p, a = embedding.init(ks[0], cfg.vocab_size * cfg.num_codebooks
                          if cfg.num_codebooks > 1 else cfg.vocab_size,
                          cfg.d_model, cfg.tied_embeddings)
    params["embedding"], axes["embedding"] = p, a

    def group_init(k):
        gp, ga = {}, {}
        for i, bd in enumerate(cfg.pattern):
            bp, ba = blocks.init(jax.random.fold_in(k, i), bd, cfg)
            gp[f"block{i}"] = bp
            ga[f"block{i}"] = ba
        return gp, ga

    stacked, gaxes = C.stack_inits(group_init, ks[1], cfg.num_groups)
    params["groups"], axes["groups"] = stacked, gaxes

    for j, bd in enumerate(cfg.prologue):
        p, a = blocks.init(ks[4 + j], bd, cfg)
        params[f"prologue{j}"], axes[f"prologue{j}"] = p, a
    for j, bd in enumerate(cfg.epilogue):
        p, a = blocks.init(ks[4 + len(cfg.prologue) + j], bd, cfg)
        params[f"epilogue{j}"], axes[f"epilogue{j}"] = p, a

    p, a = rmsnorm_init(ks[2], cfg.d_model)
    params["final_norm"], axes["final_norm"] = p, a
    return params, axes


# ---------------------------------------------------------------------------
# layer enumeration (shared by every per-layer walk and the megakernel)
# ---------------------------------------------------------------------------


def iter_layer_blocks(cfg: ModelConfig):
    """Yield ``(param_key, group_index, bd)`` for every decoder block in
    execution order: prologue, then ``num_groups`` repetitions of the
    pattern, then epilogue (``group_index`` is None for unscanned blocks).

    This is THE layer enumeration: the per-layer step functions walk it
    through :func:`_walk_blocks`, and the megakernel's stacked-weight
    packing (:func:`pack_megakernel_params`) and stacked-pool cache
    (:func:`init_megakernel_cache`) consume the same order — so layer
    ``l`` of the megakernel grid and step ``l`` of the per-layer oracle
    can never disagree about which weights they mean.
    """
    for j, bd in enumerate(cfg.prologue):
        yield f"prologue{j}", None, bd
    for g in range(cfg.num_groups):
        for i, bd in enumerate(cfg.pattern):
            yield f"block{i}", g, bd
    for j, bd in enumerate(cfg.epilogue):
        yield f"epilogue{j}", None, bd


def layer_params(params, key: str, group_index):
    """One layer's parameter subtree for an :func:`iter_layer_blocks` entry."""
    if group_index is None:
        return params[key]
    return jax.tree_util.tree_map(lambda leaf: leaf[group_index],
                                  params["groups"][key])


def _walk_blocks(apply_fn, params, cfg: ModelConfig, x, cache):
    """Shared prologue -> ``lax.scan`` (groups) -> epilogue traversal.

    ``apply_fn(block_params, x, block_cache, bd) -> (x, new_block_cache)``
    is applied to every block in :func:`iter_layer_blocks` order; the
    repeated pattern runs under ``jax.lax.scan`` exactly as before (one
    trace of the pattern regardless of depth). Factoring the six
    near-identical per-step walks here keeps the residual threading — and
    therefore the layer order the megakernel must reproduce — defined in
    one place.
    """
    cache = dict(cache)
    for j, bd in enumerate(cfg.prologue):
        key = f"prologue{j}"
        x, cache[key] = apply_fn(params[key], x, cache[key], bd)

    def scan_fn(x, inputs):
        gparams, gcache = inputs
        new = []
        for i, bd in enumerate(cfg.pattern):
            x, c = apply_fn(gparams[f"block{i}"], x, gcache[i], bd)
            new.append(c)
        return x, tuple(new)

    x, gcaches = jax.lax.scan(scan_fn, x, (params["groups"], cache["groups"]))
    cache["groups"] = gcaches
    for j, bd in enumerate(cfg.epilogue):
        key = f"epilogue{j}"
        x, cache[key] = apply_fn(params[key], x, cache[key], bd)
    return x, cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is not None:  # vlm/audio stub: precomputed frontend embeddings
        return embeds.astype(cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        # musicgen: tokens (B, S, CB); codebook c uses vocab slice c
        offsets = jnp.arange(cfg.num_codebooks, dtype=tokens.dtype) * cfg.vocab_size
        x = embedding.embed(params["embedding"], tokens + offsets,
                            cfg.scale_embeds_by_sqrt_dim, cfg.compute_dtype)
        return x.sum(axis=2)
    return embedding.embed(params["embedding"], tokens,
                           cfg.scale_embeds_by_sqrt_dim, cfg.compute_dtype)


def _group_fwd(cfg: ModelConfig, gparams, x, positions):
    from repro.parallel.ctx import maybe_constrain

    aux = jnp.zeros((), jnp.float32)
    for i, bd in enumerate(cfg.pattern):
        # Sequence-parallel residual stream (Megatron-SP): the TP-boundary
        # all-reduce of each block's output becomes reduce-scatter (+ a
        # bf16 all-gather at the next matmul) — 25% less collective
        # traffic and 1/TP the norm HBM traffic (§Perf iteration 4).
        x = maybe_constrain(x, "batch", "seq_model", None)
        x, a = blocks.apply_train(gparams[f"block{i}"], x, positions, bd, cfg)
        aux = aux + a
    return x, aux


def forward(params, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.zeros((), jnp.float32)
    for j, bd in enumerate(cfg.prologue):
        x, a = blocks.apply_train(params[f"prologue{j}"], x, positions, bd, cfg)
        aux = aux + a

    body = functools.partial(_group_fwd, cfg)
    if cfg.remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(carry, gparams):
        x, aux = carry
        x, a = body(gparams, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, aux), params["groups"])

    for j, bd in enumerate(cfg.epilogue):
        x, a = blocks.apply_train(params[f"epilogue{j}"], x, positions, bd, cfg)
        aux = aux + a
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, s, cfg.num_codebooks, cfg.vocab_size)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch):
    """Cross-entropy LM loss (+ MoE aux). batch: {tokens|embeds, labels}."""
    logits, aux = forward(params, cfg, batch.get("tokens"), batch.get("embeds"))
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    # z-loss keeps softmax normalizers bounded (large-scale stability)
    z = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    zloss = 1e-4 * ((z**2) * mask).sum() / denom
    total = ce + zloss + cfg.aux_loss_weight * aux
    return total, {"ce": ce, "zloss": zloss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    cache = {}
    for j, bd in enumerate(cfg.prologue):
        cache[f"prologue{j}"] = blocks.init_cache(batch, max_seq, bd, cfg)
    group = tuple(
        blocks.init_cache(batch, max_seq, bd, cfg) for bd in cfg.pattern
    )
    cache["groups"] = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.num_groups, *x.shape)).copy(), group
    )
    for j, bd in enumerate(cfg.epilogue):
        cache[f"epilogue{j}"] = blocks.init_cache(batch, max_seq, bd, cfg)
    return cache


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     page_size: int, tiered: bool = False):
    """Paged serving cache: per-layer page pools (attention) + per-slot
    state rows (recurrent mixers). The page table that assigns pool pages
    to sequences is host-side scheduler state (``serve/kv_cache.py``) and
    is shared by every layer — same allocation for all of them.

    ``tiered`` allocates the mixed-format uint8 pool layout instead of
    the single-format one: full-width byte rows that narrower formats
    occupy as a prefix, so the tiering engine can repack pages down the
    format ladder in place (see ``attention.init_paged_pool``)."""
    cache = {}
    for j, bd in enumerate(cfg.prologue):
        cache[f"prologue{j}"] = blocks.init_paged_cache(
            num_slots, num_pages, page_size, bd, cfg, tiered=tiered)
    group = tuple(
        blocks.init_paged_cache(num_slots, num_pages, page_size, bd, cfg,
                                tiered=tiered)
        for bd in cfg.pattern
    )
    cache["groups"] = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.num_groups, *x.shape)).copy(), group
    )
    for j, bd in enumerate(cfg.epilogue):
        cache[f"epilogue{j}"] = blocks.init_paged_cache(
            num_slots, num_pages, page_size, bd, cfg, tiered=tiered)
    return cache


def decode_step_paged(params, cfg: ModelConfig, cache, tokens, page_rows,
                      pos, page_fmts=None, mixed_fmts=None):
    """Continuous-batching decode: tokens (B, 1), page_rows (B, P) int32
    page ids per slot (-1 = unallocated), pos (B,) per-slot positions.

    Returns (logits (B, 1, V), new_cache). Inactive slots (page_rows all
    -1) compute garbage that never lands: their KV writes are dropped and
    the host ignores their logits. Attention runs the path named by
    ``cfg.decode_kernel`` ("einsum" reference gather, or the single-pass
    "fused" Pallas flash-decode kernel the serve engine defaults to).

    ``page_fmts`` (NP,) i32 per-page format ids enables the tiered
    mixed-format pool path (fused kernel only); all layers share the one
    array, like the page table. ``mixed_fmts`` optionally restricts the
    candidate-format set compiled into the kernel.
    """
    x = _embed_inputs(params, cfg, tokens)
    b = x.shape[0]
    x, cache = _walk_blocks(
        lambda bp, x, bc, bd: blocks.apply_decode_paged(
            bp, x, bc, page_rows, pos, bd, cfg, page_fmts=page_fmts,
            mixed_fmts=mixed_fmts),
        params, cfg, x, cache)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, 1, cfg.num_codebooks, cfg.vocab_size)
    return logits, cache


def verify_step_paged(params, cfg: ModelConfig, cache, tokens, page_rows,
                      pos, page_fmts=None, mixed_fmts=None):
    """Speculative-decoding verify: tokens (B, Tq), page_rows (B, P),
    pos (B,) per-slot position of each row's *first* token.

    Feeds each slot's pending sampled token plus its Tq - 1 drafts in
    one batched pass: K/V for all Tq tokens land in the slot's pages
    (positions pos .. pos + Tq - 1 — the host guarantees those pages
    exist and are exclusively owned), and per-row causal masking keeps
    every token's logits exactly what one-at-a-time decode would
    produce. Returns (logits (B, Tq, V), new_cache); the host accepts a
    prefix of the drafts by comparing greedy argmaxes and rolls back the
    rest by simply not advancing the sequence position (rejected rows
    are dead by masking — nothing is zeroed or copied).

    Tq == 1 is :func:`decode_step_paged`'s dataflow; attention-only
    models only (see ``blocks.apply_verify_paged``).
    """
    x = _embed_inputs(params, cfg, tokens)
    b = x.shape[0]
    x, cache = _walk_blocks(
        lambda bp, x, bc, bd: blocks.apply_verify_paged(
            bp, x, bc, page_rows, pos, bd, cfg, page_fmts=page_fmts,
            mixed_fmts=mixed_fmts),
        params, cfg, x, cache)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, x.shape[1], cfg.num_codebooks,
                                cfg.vocab_size)
    return logits, cache


def prefill_chunk_paged(params, cfg: ModelConfig, cache, tokens, page_rows,
                        pos, num_valid, logit_idx, page_fmts=None,
                        mixed_fmts=None):
    """One fixed-size chunk of paged prefill: tokens (B, C), page_rows
    (B, P), pos (B,) chunk start positions, num_valid (B,) real tokens in
    the chunk, logit_idx (B,) which chunk row's logits to return.

    The chunked-prefill analogue of :func:`verify_step_paged`: each slot
    feeds ``C`` prompt tokens at absolute positions ``pos .. pos + C - 1``
    straight against the paged MX cache — the chunk's K/V is quantized
    into its pages (inside the fused kernel on the default path) and
    every chunk query attends over the pages written so far plus the
    chunk itself under per-row causal masking. Because ``C``, ``P`` and
    the scalar shapes are fixed, a serve engine needs exactly ONE jitted
    trace of this function for every prompt length and prefix-hit
    combination — admission latency is O(chunk) and the trace population
    is O(1), versus the monolithic path's O(distinct prompt lengths x
    prefix pages).

    Returns (logits (B, 1, V) of row ``logit_idx`` per slot, new cache).
    Mid-prompt chunks pass a throwaway index (their logits are unused);
    the final chunk passes its last real token's row, whose logits sample
    the first generated token. Attention-only models (see
    ``blocks.apply_prefill_chunked``).
    """
    x = _embed_inputs(params, cfg, tokens)
    b = x.shape[0]
    x, cache = _walk_blocks(
        lambda bp, x, bc, bd: blocks.apply_prefill_chunked(
            bp, x, bc, page_rows, pos, num_valid, bd, cfg,
            page_fmts=page_fmts, mixed_fmts=mixed_fmts),
        params, cfg, x, cache)
    # slice the requested row BEFORE the final norm + lm head: every op is
    # row-independent, so this matches the monolithic prefill's last-token
    # logits bit-for-bit while paying the vocab matmul for one row only
    idx = jnp.asarray(logit_idx, jnp.int32)[:, None, None]
    x = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, 1, cfg.num_codebooks, cfg.vocab_size)
    return logits, cache


def ragged_step_paged(params, cfg: ModelConfig, cache, tokens, page_rows,
                      row_start, seq_lens, logit_idx, num_logits: int = 1,
                      page_fmts=None, mixed_fmts=None):
    """One-dispatch ragged engine step: tokens (R, W), page_rows (R, P),
    row_start (R,) first new-token position per row, seq_lens (R,) =
    row_start + n_new, logit_idx (R,) first row whose logits to return,
    num_logits static count of logit rows gathered per row.

    The single entry point behind ``ServeConfig.step_mode="ragged"``:
    decode rows (n_new == 1), verify windows (n_new == 1 + K) and
    prefill chunks (n_new up to W) coexist in one batch, so a steady
    mixed step issues ONE device dispatch per layer-stack traversal
    instead of decode + verify + prefill + K/V-write calls. Each row's
    new K/V is quantize-written into its pages inside the fused kernel
    (``kernels.mx_attention_ragged_fused``) — no ``.at[].set`` HBM
    round-trip anywhere on this path. Rows shorter than W clamp their
    padding queries onto the last real position; their outputs are
    garbage duplicates the host never reads. Inactive rows
    (row_start 0, seq_len 1, page_rows all -1) write only the pool's
    reserved trash page.

    Returns (logits (R, num_logits, V), new_cache). Logit rows are
    gathered pre-final-norm at ``logit_idx .. logit_idx + num_logits - 1``
    clamped to the last real row — decode/prefill-final rows use row 0 /
    the last prompt row, verify rows all 1 + K draft rows. Shapes are
    fixed by (R, W, P, num_logits), so one jitted trace covers every
    batch composition. Attention-only models (see
    ``blocks.apply_ragged_step``).
    """
    x = _embed_inputs(params, cfg, tokens)
    r = x.shape[0]
    x, cache = _walk_blocks(
        lambda bp, x, bc, bd: blocks.apply_ragged_step(
            bp, x, bc, page_rows, row_start, seq_lens, bd, cfg,
            page_fmts=page_fmts, mixed_fmts=mixed_fmts),
        params, cfg, x, cache)
    # gather the requested rows BEFORE the final norm + lm head (both are
    # row-independent, so this is bit-identical to slicing afterwards);
    # out-of-range gather rows clamp onto the row's last real token, whose
    # duplicate logits the host ignores
    last = jnp.maximum(seq_lens - row_start - 1, 0)[:, None]
    idx = jnp.clip(jnp.asarray(logit_idx, jnp.int32)[:, None]
                   + jnp.arange(num_logits, dtype=jnp.int32)[None, :],
                   0, last)
    x = jnp.take_along_axis(
        x, jnp.broadcast_to(idx[:, :, None], (r, num_logits, x.shape[-1])),
        axis=1)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(r, num_logits, cfg.num_codebooks,
                                cfg.vocab_size)
    return logits, cache


# ---------------------------------------------------------------------------
# megakernel step: the whole layer stack as ONE pallas_call
# ---------------------------------------------------------------------------


def init_megakernel_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                          page_size: int, tiered: bool = False):
    """Stacked-layer paged cache for the megakernel step.

    ONE grouped pool whose leaves carry a leading ``L = cfg.num_layers``
    axis (layer order = :func:`iter_layer_blocks`), wrapped as
    ``{"groups": (pool,)}`` so every ``serve.kv_cache`` structural walk —
    copy_page, extract/restore, ``pool_specs`` (KV heads stay at
    ``ndim - 2``), repack — treats the layer axis exactly like the
    per-layer cache's group axis. For an attention-only config with
    ``pattern == (bd,)`` and ``num_groups == L`` this is bit-for-bit the
    same pytree layout as :func:`init_paged_cache`, which is what lets
    the megakernel tests compare written pool bytes directly against the
    per-layer ragged oracle.
    """
    bd0 = cfg.all_blocks()[0]
    pool = blocks.init_paged_cache(num_slots, num_pages, page_size, bd0,
                                   cfg, tiered=tiered)
    layers = cfg.num_layers
    return {"groups": (jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (layers, *x.shape)).copy(), pool),)}


def pack_megakernel_params(params, cfg: ModelConfig):
    """Stack per-layer weights along a leading L axis for the megakernel.

    Consumes the SAME layer enumeration as the per-layer oracle
    (:func:`iter_layer_blocks`), so megakernel grid coordinate ``l``
    indexes exactly the weights the oracle's step ``l`` applies. The
    packed dict keeps the ``wq/wk/wv/wo`` key structure, so
    ``parallel.sharding.serve_param_specs`` still finds the attention
    projection group and shards the head columns (the KV-head slice)
    exactly as on the per-layer path. Embedding and final norm stay
    unstacked — they run outside the kernel.
    """
    layers = [layer_params(params, key, g)
              for key, g, _ in iter_layer_blocks(cfg)]

    def stack(pick):
        return jnp.stack([pick(bp) for bp in layers], axis=0)

    packed = {
        "norm_mixer": {"scale": stack(lambda bp: bp["norm_mixer"]["scale"])},
        "wq": {"w": stack(lambda bp: bp["mixer"]["wq"]["w"])},
        "wk": {"w": stack(lambda bp: bp["mixer"]["wk"]["w"])},
        "wv": {"w": stack(lambda bp: bp["mixer"]["wv"]["w"])},
        "wo": {"w": stack(lambda bp: bp["mixer"]["wo"]["w"])},
        "norm_ffn": {"scale": stack(lambda bp: bp["norm_ffn"]["scale"])},
        "up": {"w": stack(lambda bp: bp["ffn"]["up"]["w"])},
        "down": {"w": stack(lambda bp: bp["ffn"]["down"]["w"])},
    }
    if cfg.ffn_kind != "gelu":
        packed["gate"] = {"w": stack(lambda bp: bp["ffn"]["gate"]["w"])}
    return {"embedding": params["embedding"],
            "final_norm": params["final_norm"], "layers": packed}


def megakernel_step_paged(params, cfg: ModelConfig, cache, tokens, page_rows,
                          row_start, seq_lens, logit_idx, num_logits: int = 1,
                          page_fmts=None, mixed_fmts=None):
    """:func:`ragged_step_paged` with the whole layer stack fused into ONE
    ``pallas_call`` (``kernels.mx_megakernel_step``).

    ``params`` is a :func:`pack_megakernel_params` dict and ``cache`` an
    :func:`init_megakernel_cache` stacked pool; everything else —
    ragged row metadata, trash-page contract, tiered ``page_fmts``,
    logit-row gather — matches the per-layer oracle argument-for-argument.
    Embedding, the pre-head logit-row gather, final norm, and the LM head
    run outside the kernel exactly as written in :func:`ragged_step_paged`,
    so the returned logits are bit-identical to the oracle's whenever the
    kernel's per-layer math is (which the megakernel guarantees by reusing
    the oracle's own jnp helpers and fused-kernel primitives).

    Only configs accepted by ``blocks.megakernel_reject_reason`` may come
    here; the serve engine enforces that and falls back to
    ``step_mode="ragged"`` otherwise.
    """
    from repro.kernels import mx_megakernel_step

    x = _embed_inputs(params, cfg, tokens)
    r = x.shape[0]
    lay = params["layers"]
    pool = cache["groups"][0]
    bd0 = cfg.all_blocks()[0]
    d = cfg.head_dim
    x, pools = mx_megakernel_step(
        x, lay["norm_mixer"]["scale"], lay["wq"]["w"], lay["wk"]["w"],
        lay["wv"]["w"], lay["wo"]["w"], lay["norm_ffn"]["scale"],
        lay["gate"]["w"] if "gate" in lay else None,
        lay["up"]["w"], lay["down"]["w"],
        pool["k_elems"], pool["k_scales"], pool["v_elems"],
        pool["v_scales"], page_rows, row_start, seq_lens,
        head_dim=d, rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
        ffn_kind=cfg.ffn_kind, quant=cfg.quant, fmt_name=cfg.quant.fmt,
        block_size=min(cfg.quant.block_size, d), softcap=cfg.attn_softcap,
        window=bd0.window, compute_dtype=cfg.compute_dtype,
        page_fmts=page_fmts, mixed_fmts=mixed_fmts)
    ke, ks, ve, vs = pools
    cache = {"groups": (dict(pool, k_elems=ke, k_scales=ks, v_elems=ve,
                             v_scales=vs),)}
    # logit-row gather + head: verbatim the per-layer oracle's tail
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    row_start = jnp.asarray(row_start, jnp.int32)
    last = jnp.maximum(seq_lens - row_start - 1, 0)[:, None]
    idx = jnp.clip(jnp.asarray(logit_idx, jnp.int32)[:, None]
                   + jnp.arange(num_logits, dtype=jnp.int32)[None, :],
                   0, last)
    x = jnp.take_along_axis(
        x, jnp.broadcast_to(idx[:, :, None], (r, num_logits, x.shape[-1])),
        axis=1)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(r, num_logits, cfg.num_codebooks,
                                cfg.vocab_size)
    return logits, cache


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            max_seq: Optional[int] = None):
    """Process the prompt, build caches. Returns (last-token logits, cache)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s = x.shape[:2]
    max_seq = max_seq or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = {}
    for j, bd in enumerate(cfg.prologue):
        x, cache[f"prologue{j}"] = blocks.prefill_block(
            params[f"prologue{j}"], x, positions, bd, cfg, max_seq)

    def scan_fn(x, gparams):
        from repro.parallel.ctx import maybe_constrain

        caches = []
        for i, bd in enumerate(cfg.pattern):
            x = maybe_constrain(x, "batch", "seq_model", None)
            x, c = blocks.prefill_block(gparams[f"block{i}"], x, positions,
                                        bd, cfg, max_seq)
            caches.append(c)
        return x, tuple(caches)

    x, gcaches = jax.lax.scan(scan_fn, x, params["groups"])
    cache["groups"] = gcaches
    for j, bd in enumerate(cfg.epilogue):
        x, cache[f"epilogue{j}"] = blocks.prefill_block(
            params[f"epilogue{j}"], x, positions, bd, cfg, max_seq)
    x = rmsnorm_apply(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, 1, cfg.num_codebooks, cfg.vocab_size)
    return logits, cache


def prefill_with_prefix(params, cfg: ModelConfig, cache, tokens,
                        prefix_pages, pos0: int, max_seq: int):
    """Prefill the uncached tail of a prompt against shared prefix pages.

    The prefix-cache fast path: a request whose prompt head is already
    resident in the paged cache prefills only ``tokens`` (1, S_tail), its
    uncached tail. ``prefix_pages`` (P0,) are the page ids holding the
    cached head's ``pos0`` tokens (``P0 == ceil(pos0 / page_size)`` —
    ``pos0`` need not be a page multiple: a partial-page hit ends
    mid-page and the last page's rows past ``pos0`` are masked out of
    the attend), gathered read-only from ``cache``; positions are offset
    by ``pos0`` so RoPE stays absolute. Requires an attention-only model (recurrent mixers would
    need per-prefix state snapshots — see ROADMAP).

    Returns (last-token logits, tail cache): the tail cache covers only
    the new tokens at relative slots 0.. and installs into the sequence's
    tail pages with ``kv_cache.install_prefill``, exactly like a full
    prefill cache.
    """
    x = _embed_inputs(params, cfg, tokens)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(
        pos0 + jnp.arange(s, dtype=jnp.int32), (b, s))
    # the walk threads the read-only prefix pool in and the tail cache out:
    # every block's returned cache entry replaces its input entry, so the
    # result dict holds exactly the new-token tail caches
    x, out_cache = _walk_blocks(
        lambda bp, x, bc, bd: blocks.prefill_block_tail(
            bp, x, positions, bc, prefix_pages, bd, cfg, max_seq),
        params, cfg, x, cache)
    x = rmsnorm_apply(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, 1, cfg.num_codebooks, cfg.vocab_size)
    return logits, out_cache


def decode_step(params, cfg: ModelConfig, cache, tokens=None, embeds=None,
                pos=None):
    """One-token decode. tokens: (B, 1) (or (B,1,CB)); pos: scalar int32.

    Returns (logits (B, 1, V), new_cache).
    """
    x = _embed_inputs(params, cfg, tokens, embeds)
    b = x.shape[0]
    x, cache = _walk_blocks(
        lambda bp, x, bc, bd: blocks.apply_decode(bp, x, bc, pos, bd, cfg),
        params, cfg, x, cache)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = embedding.logits(params["embedding"], x, cfg.logit_softcap,
                              cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(b, 1, cfg.num_codebooks, cfg.vocab_size)
    return logits, cache
