"""Normalization layers (RMSNorm with gemma-style (1+w) option)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as C


def rmsnorm_init(key, dim: int):
    del key
    return {"scale": jnp.zeros((dim,), jnp.float32)}, {"scale": (C.D_MODEL,)}


def rmsnorm_apply(params, x, eps: float = 1e-6, plus_one: bool = True):
    """RMSNorm in f32 (norm stats must not be quantized — paper keeps
    normalization wide; only matmuls go through MX)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    norm = x32 * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    w = (1.0 + scale) if plus_one else scale
    return (norm * w).astype(dtype)
