"""Decoder block wiring: mixer (attn/mla/rglru/ssd) + channel mixer (ffn/moe).

Pre-norm residual blocks, with optional gemma2-style post-norms. All mixers
and FFNs inherit the MX quantization policy through ``linear.apply``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantConfig

from . import attention, common as C, ffn, linear, mla, moe, rglru, ssd
from .config import BlockDef, ModelConfig
from .norms import rmsnorm_apply, rmsnorm_init


def _attn_cfg(cfg: ModelConfig, bd: BlockDef) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta,
        window=bd.window,
        softcap=cfg.attn_softcap,
        query_chunk=cfg.query_chunk,
        no_ring=cfg.serve_full_cache,
        decode_kernel=cfg.decode_kernel,
    )


def _mla_cfg(cfg: ModelConfig) -> mla.MLAConfig:
    return mla.MLAConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        kv_lora=cfg.kv_lora,
        qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim,
        rope_theta=cfg.rope_theta,
        query_chunk=cfg.query_chunk,
    )


def _rglru_cfg(cfg: ModelConfig) -> rglru.RGLRUConfig:
    return rglru.RGLRUConfig(
        d_model=cfg.d_model, width=cfg.rnn_width or cfg.d_model,
        conv_width=cfg.conv_width,
    )


def _ssd_cfg(cfg: ModelConfig) -> ssd.SSDConfig:
    return ssd.SSDConfig(
        d_model=cfg.d_model, d_inner=cfg.d_inner, headdim=cfg.headdim,
        d_state=cfg.d_state, ngroups=cfg.ngroups, conv_width=cfg.conv_width,
        chunk=cfg.ssd_chunk,
    )


def _moe_cfg(cfg: ModelConfig) -> moe.MoEConfig:
    return moe.MoEConfig(
        d_model=cfg.d_model, d_ff_expert=cfg.d_ff_expert,
        num_experts=cfg.num_experts, top_k=cfg.top_k,
        num_shared=cfg.num_shared,
        d_ff_shared=cfg.num_shared * cfg.d_ff_expert,
        ffn_kind=cfg.ffn_kind, aux_loss_weight=cfg.aux_loss_weight,
        dispatch=cfg.moe_dispatch,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(key, bd: BlockDef, cfg: ModelConfig):
    ks = C.split_keys(key, 4)
    params, axes = {}, {}
    p, a = rmsnorm_init(ks[0], cfg.d_model)
    params["norm_mixer"], axes["norm_mixer"] = p, a
    if bd.mixer == "attn":
        p, a = attention.init(ks[1], _attn_cfg(cfg, bd))
    elif bd.mixer == "mla":
        p, a = mla.init(ks[1], _mla_cfg(cfg))
    elif bd.mixer == "rglru":
        p, a = rglru.init(ks[1], _rglru_cfg(cfg))
    elif bd.mixer == "ssd":
        p, a = ssd.init(ks[1], _ssd_cfg(cfg))
    else:
        raise ValueError(bd.mixer)
    params["mixer"], axes["mixer"] = p, a

    if bd.ffn != "none":
        p, a = rmsnorm_init(ks[2], cfg.d_model)
        params["norm_ffn"], axes["norm_ffn"] = p, a
        if bd.ffn == "moe":
            p, a = moe.init(ks[3], _moe_cfg(cfg))
        else:
            p, a = ffn.init(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
        params["ffn"], axes["ffn"] = p, a
    if cfg.post_norms:
        p, a = rmsnorm_init(ks[0], cfg.d_model)
        params["postnorm_mixer"], axes["postnorm_mixer"] = p, a
        if bd.ffn != "none":
            p, a = rmsnorm_init(ks[2], cfg.d_model)
            params["postnorm_ffn"], axes["postnorm_ffn"] = p, a
    return params, axes


# ---------------------------------------------------------------------------
# forward (train / prefill-compute)
# ---------------------------------------------------------------------------


def _sp(h):
    """Pin norm outputs to the sequence-parallel layout: the TP all-gather
    then moves the bf16 output, not the norm's f32 internals (§Perf iter 6).
    """
    from repro.parallel.ctx import maybe_constrain

    return maybe_constrain(h, "batch", "seq_model", None)


def apply_train(params, x, positions, bd: BlockDef, cfg: ModelConfig):
    quant, dt = cfg.quant, cfg.compute_dtype
    h = _sp(rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps))
    if bd.mixer == "attn":
        h = attention.apply_train(params["mixer"], h, positions,
                                  _attn_cfg(cfg, bd), quant, dt)
    elif bd.mixer == "mla":
        h = mla.apply_train(params["mixer"], h, positions, _mla_cfg(cfg),
                            quant, dt)
    elif bd.mixer == "rglru":
        h = rglru.apply_train(params["mixer"], h, _rglru_cfg(cfg), quant, dt)
    else:
        h = ssd.apply_train(params["mixer"], h, _ssd_cfg(cfg), quant, dt)
    if cfg.post_norms:
        h = rmsnorm_apply(params["postnorm_mixer"], h, cfg.norm_eps)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if bd.ffn != "none":
        h = _sp(rmsnorm_apply(params["norm_ffn"], x, cfg.norm_eps))
        if bd.ffn == "moe":
            h, aux = moe.apply(params["ffn"], h, _moe_cfg(cfg), quant, dt)
        else:
            h = ffn.apply(params["ffn"], h, quant, cfg.ffn_kind, dt)
        if cfg.post_norms:
            h = rmsnorm_apply(params["postnorm_ffn"], h, cfg.norm_eps)
        x = x + h
    return x, aux


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------


def init_cache(batch: int, max_seq: int, bd: BlockDef, cfg: ModelConfig):
    if bd.mixer == "attn":
        return attention.init_cache(batch, max_seq, _attn_cfg(cfg, bd), cfg.quant)
    if bd.mixer == "mla":
        return mla.init_cache(batch, max_seq, _mla_cfg(cfg), cfg.quant)
    if bd.mixer == "rglru":
        return rglru.init_state(batch, _rglru_cfg(cfg))
    return ssd.init_state(batch, _ssd_cfg(cfg))


def _decode_tail(params, x, h, bd: BlockDef, cfg: ModelConfig):
    """Shared decode epilogue: residual add + channel mixer (+ post-norms)."""
    quant, dt = cfg.quant, cfg.compute_dtype
    if cfg.post_norms:
        h = rmsnorm_apply(params["postnorm_mixer"], h, cfg.norm_eps)
    x = x + h
    if bd.ffn != "none":
        h = rmsnorm_apply(params["norm_ffn"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            h, _ = moe.apply(params["ffn"], h, _moe_cfg(cfg), quant, dt)
        else:
            h = ffn.apply(params["ffn"], h, quant, cfg.ffn_kind, dt)
        if cfg.post_norms:
            h = rmsnorm_apply(params["postnorm_ffn"], h, cfg.norm_eps)
        x = x + h
    return x


def apply_decode(params, x, cache, pos, bd: BlockDef, cfg: ModelConfig):
    quant, dt = cfg.quant, cfg.compute_dtype
    h = rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps)
    if bd.mixer == "attn":
        h, cache = attention.apply_decode(params["mixer"], h, cache, pos,
                                          _attn_cfg(cfg, bd), quant, dt)
    elif bd.mixer == "mla":
        h, cache = mla.apply_decode(params["mixer"], h, cache, pos,
                                    _mla_cfg(cfg), quant, dt)
    elif bd.mixer == "rglru":
        h, cache = rglru.apply_decode(params["mixer"], h, cache,
                                      _rglru_cfg(cfg), quant, dt)
    else:
        h, cache = ssd.apply_decode(params["mixer"], h, cache,
                                    _ssd_cfg(cfg), quant, dt)
    return _decode_tail(params, x, h, bd, cfg), cache


def init_paged_cache(num_slots: int, num_pages: int, page_size: int,
                     bd: BlockDef, cfg: ModelConfig, tiered: bool = False):
    """Paged serving cache for one block: attention layers get a global
    page pool; recurrent mixers keep per-slot state rows (their state is
    O(1) per sequence — paging buys nothing). ``tiered`` selects the
    mixed-format uint8 pool layout (per-page element formats)."""
    if bd.mixer == "attn":
        return attention.init_paged_pool(num_pages, page_size,
                                         _attn_cfg(cfg, bd), cfg.quant,
                                         tiered=tiered)
    if tiered:
        raise NotImplementedError(
            f"tiered KV pools require attention mixers, got {bd.mixer!r}")
    if bd.mixer == "rglru":
        return rglru.init_state(num_slots, _rglru_cfg(cfg))
    if bd.mixer == "ssd":
        return ssd.init_state(num_slots, _ssd_cfg(cfg))
    raise NotImplementedError(
        f"paged serving does not support mixer {bd.mixer!r} yet (MLA "
        "latent caches need their own pool layout — see ROADMAP)")


def apply_decode_paged(params, x, cache, page_rows, pos, bd: BlockDef,
                       cfg: ModelConfig, page_fmts=None, mixed_fmts=None):
    """Per-slot decode: x (B, 1, d_model), page_rows (B, P), pos (B,)."""
    quant, dt = cfg.quant, cfg.compute_dtype
    h = rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps)
    if bd.mixer == "attn":
        h, cache = attention.apply_decode_paged(
            params["mixer"], h, cache, page_rows, pos, _attn_cfg(cfg, bd),
            quant, dt, page_fmts=page_fmts, mixed_fmts=mixed_fmts)
    elif bd.mixer == "rglru":
        h, cache = rglru.apply_decode(params["mixer"], h, cache,
                                      _rglru_cfg(cfg), quant, dt)
    elif bd.mixer == "ssd":
        h, cache = ssd.apply_decode(params["mixer"], h, cache,
                                    _ssd_cfg(cfg), quant, dt)
    else:
        raise NotImplementedError(f"paged decode for mixer {bd.mixer!r}")
    return _decode_tail(params, x, h, bd, cfg), cache


def apply_verify_paged(params, x, cache, page_rows, pos, bd: BlockDef,
                       cfg: ModelConfig, page_fmts=None, mixed_fmts=None):
    """Speculative multi-token verify: x (B, Tq, d_model), pos (B,).

    Attention-only: a rejected draft's K/V rows are dead by position
    masking (page-exact rollback), but recurrent state has no position
    axis to mask — rolling it back would need per-step state snapshots,
    so the engine gates speculation to attention-only models.
    """
    if bd.mixer != "attn":
        raise NotImplementedError(
            f"speculative verify requires attention mixers, got "
            f"{bd.mixer!r} (recurrent state cannot be rolled back "
            "page-exactly — it has no position axis to truncate)")
    quant, dt = cfg.quant, cfg.compute_dtype
    h = rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps)
    h, cache = attention.apply_verify_paged(
        params["mixer"], h, cache, page_rows, pos, _attn_cfg(cfg, bd),
        quant, dt, page_fmts=page_fmts, mixed_fmts=mixed_fmts)
    return _decode_tail(params, x, h, bd, cfg), cache


def apply_prefill_chunked(params, x, cache, page_rows, pos, num_valid,
                          bd: BlockDef, cfg: ModelConfig, page_fmts=None,
                          mixed_fmts=None):
    """One chunk of paged prefill: x (B, C, d_model), pos (B,) chunk
    starts, num_valid (B,) real tokens in the chunk.

    Attention-only, like the verify path it generalizes: a recurrent
    mixer's state is not paged, so chunk-at-a-time prefill against pages
    has nothing to resume from (the engine falls back to monolithic
    prefill for such models).
    """
    if bd.mixer != "attn":
        raise NotImplementedError(
            f"chunked paged prefill requires attention mixers, got "
            f"{bd.mixer!r} (recurrent state is per-slot, not paged — "
            "chunk-at-a-time prefill has no pages to resume from)")
    quant, dt = cfg.quant, cfg.compute_dtype
    h = rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps)
    h, cache = attention.apply_prefill_chunked(
        params["mixer"], h, cache, page_rows, pos, num_valid,
        _attn_cfg(cfg, bd), quant, dt, page_fmts=page_fmts,
        mixed_fmts=mixed_fmts)
    return _decode_tail(params, x, h, bd, cfg), cache


def apply_ragged_step(params, x, cache, page_rows, row_start, seq_lens,
                      bd: BlockDef, cfg: ModelConfig, page_fmts=None,
                      mixed_fmts=None):
    """One ragged engine step: x (R, W, d_model), row_start/seq_lens (R,).

    Decode rows, speculative verify windows, and in-flight prefill
    chunks share ONE fused dispatch (see ``attention.apply_ragged``).
    Attention-only, for the union of the reasons the verify and chunked
    paths it subsumes are: recurrent state has neither a position axis
    to roll rejected drafts back through nor pages for a chunk to
    resume from.
    """
    if bd.mixer != "attn":
        raise NotImplementedError(
            f"the ragged engine step requires attention mixers, got "
            f"{bd.mixer!r} (the engine falls back to step_mode='split')")
    quant, dt = cfg.quant, cfg.compute_dtype
    h = rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps)
    h, cache = attention.apply_ragged(
        params["mixer"], h, cache, page_rows, row_start, seq_lens,
        _attn_cfg(cfg, bd), quant, dt, page_fmts=page_fmts,
        mixed_fmts=mixed_fmts)
    return _decode_tail(params, x, h, bd, cfg), cache


def megakernel_reject_reason(cfg: ModelConfig):
    """Why the layer-fused megakernel cannot serve ``cfg`` (None = it can).

    The static half of the serve engine's fallback ladder for
    ``step_mode="megakernel"`` (the engine adds runtime conditions on
    top: ragged prerequisites, unsharded mesh, wide weights). One string
    per rung so tests can pin the ladder and the serve log can name the
    reason it fell back to the per-layer ragged path.
    """
    all_blocks = cfg.all_blocks()
    if not all_blocks:
        return "empty layer stack"
    if any(bd.mixer != "attn" for bd in all_blocks):
        mixers = sorted({bd.mixer for bd in all_blocks if bd.mixer != "attn"})
        return f"non-attention mixers {mixers} (MoE/recurrent hybrids)"
    if any(bd != all_blocks[0] for bd in all_blocks):
        return ("non-uniform block pattern (per-layer windows or channel "
                "mixers need per-layer kernel specialization)")
    if cfg.prologue or cfg.epilogue or len(cfg.pattern) != 1:
        # with one scanned pattern slot and no unscanned blocks, the
        # per-layer cache ({"groups": (pool,)} stacked over num_groups)
        # and the megakernel cache (leading L axis) are the SAME pytree —
        # the engine's page/snapshot/repack helpers then apply unchanged
        return ("non-trivial stack layout (prologue/epilogue blocks or a "
                "multi-block pattern break the stacked-cache coincidence "
                "with the per-layer scan)")
    if all_blocks[0].ffn != "dense":
        return (f"ffn kind {all_blocks[0].ffn!r} (the fused layer tail "
                "implements the dense gated MLP only)")
    if cfg.post_norms:
        return "sandwich post-norms (not folded into the fused layer tail)"
    if cfg.quant.enabled and cfg.quant.quantize_acts:
        return ("activation quantization (qat_matmul's custom-vjp pallas "
                "path cannot nest inside the megakernel)")
    if not (cfg.quant.enabled and cfg.quant.quantize_kv_cache):
        return "wide bf16 KV pool (no MX page walk to fuse over)"
    return None


def _attn_prefill_qkv(mixer_params, h, positions, acfg, quant, dt):
    """Shared prefill prologue: QKV projection + RoPE at ``positions``.

    Single-sourced for the full and prefix-cached tail prefill paths —
    any change here (rope variant, qk-norm, ...) must hit both, or the
    token-identical guarantee the prefix cache depends on breaks.
    """
    b, s, _ = h.shape
    hh, kvh, d = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    q = linear.apply(mixer_params["wq"], h, quant, dt).reshape(b, s, hh, d)
    k = linear.apply(mixer_params["wk"], h, quant, dt).reshape(b, s, kvh, d)
    v = linear.apply(mixer_params["wv"], h, quant, dt).reshape(b, s, kvh, d)
    from .rotary import apply_rope

    q = apply_rope(q, positions, acfg.rope_theta)
    k = apply_rope(k, positions, acfg.rope_theta)
    return q, k, v


def prefill_block_tail(params, x, positions, pool, prefix_pages,
                       bd: BlockDef, cfg: ModelConfig, max_seq: int):
    """Prefill the uncached tail of a prompt against cached prefix pages.

    ``x`` (1, S_tail, d_model) is the tail's embeddings, ``positions``
    (1, S_tail) its *absolute* positions (RoPE stays exact), ``pool`` the
    block's live page pool, and ``prefix_pages`` (P0,) the page ids of the
    shared prefix: ``ceil(positions[0, 0] / page_size)`` pages — the hit
    may end mid-page (a partial-page prefix hit), in which case the last
    page's rows past the hit are masked out below. Queries attend over
    the dequantized prefix gathered from the pool plus the tail's own K/V
    in cache representation — the exact values full prefill attends over
    (``cache_kv_view``), which keeps prefix-cached generation
    token-identical. Returns (x, tail cache) where the cache covers only
    the tail at relative slots 0.. for page install.
    """
    if bd.mixer != "attn":
        raise NotImplementedError(
            f"prefix-cached prefill requires attention mixers, got "
            f"{bd.mixer!r} (recurrent state would need per-node snapshots)")
    quant, dt = cfg.quant, cfg.compute_dtype
    h = _sp(rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps))
    acfg = _attn_cfg(cfg, bd)
    b, s, _ = h.shape
    hh, d = acfg.num_heads, acfg.head_dim
    q, k, v = _attn_prefill_qkv(params["mixer"], h, positions, acfg,
                                quant, dt)
    kp, vp = attention.gather_page_kv(pool, prefix_pages, acfg, quant, dt)
    ks, vs = attention.cache_kv_view(k, v, acfg, quant)
    kcat = jnp.concatenate([kp, ks], axis=1)  # b == 1 (one request)
    vcat = jnp.concatenate([vp, vs], axis=1)
    # gathered prefix rows sit at absolute positions 0..pos0-1; with a
    # partial-page hit the gather still pulls whole pages, so rows past
    # pos0 (= positions[0, 0], not necessarily a page multiple) are
    # garbage — give them kpos -1, which the attention mask kills
    # unconditionally (kpos >= 0). The tail follows at its absolute
    # positions, overlapping the partial page's dead rows.
    pos0 = positions[0, 0]
    pref_pos = jnp.arange(kp.shape[1], dtype=jnp.int32)
    kpos = jnp.concatenate(
        [jnp.where(pref_pos < pos0, pref_pos, -1), positions[0]])
    out = attention._attend_chunked(q, kcat, vcat, positions, kpos, acfg)
    h2 = linear.apply(params["mixer"]["wo"], out.reshape(b, s, hh * d),
                      quant, dt)
    # tail cache at *relative* slots (0-based) so it reshapes 1:1 into the
    # sequence's tail pages; RoPE above already used absolute positions
    rel = positions - positions[:, :1]
    cache = attention.prefill_cache(params["mixer"], h, rel, acfg, quant,
                                    k, v, max_seq)
    return _decode_tail(params, x, h2, bd, cfg), cache


def prefill_block(params, x, positions, bd: BlockDef, cfg: ModelConfig,
                  max_seq: int):
    """Forward pass that also builds the block's cache. Returns (x, cache)."""
    quant, dt = cfg.quant, cfg.compute_dtype
    h = _sp(rmsnorm_apply(params["norm_mixer"], x, cfg.norm_eps))
    if bd.mixer == "attn":
        acfg = _attn_cfg(cfg, bd)
        b, s, _ = h.shape
        hh, d = acfg.num_heads, acfg.head_dim
        q, k, v = _attn_prefill_qkv(params["mixer"], h, positions, acfg,
                                    quant, dt)
        # attend over the cache representation of K/V (identity for bf16,
        # quantize->dequantize snap for MX): decode and prefix-cached tail
        # prefill both read K/V back out of the cache, so full prefill must
        # see the same values for the three paths to agree token-for-token
        ks, vs = attention.cache_kv_view(k, v, acfg, quant)
        out = attention._attend_chunked(q, ks, vs, positions, positions, acfg)
        h2 = linear.apply(params["mixer"]["wo"], out.reshape(b, s, hh * d),
                          quant, dt)
        cache = attention.prefill_cache(params["mixer"], h, positions, acfg,
                                        quant, k, v, max_seq)
    elif bd.mixer == "mla":
        h2 = mla.apply_train(params["mixer"], h, positions, _mla_cfg(cfg),
                             quant, dt)
        cache = mla.prefill_cache(params["mixer"], h, positions, _mla_cfg(cfg),
                                  quant, max_seq, dt)
    elif bd.mixer == "rglru":
        h2 = rglru.apply_train(params["mixer"], h, _rglru_cfg(cfg), quant, dt)
        cache = rglru.prefill_state(params["mixer"], h, _rglru_cfg(cfg), quant, dt)
    else:
        h2, cache = ssd.prefill_state(params["mixer"], h, _ssd_cfg(cfg), quant, dt)
    if cfg.post_norms:
        h2 = rmsnorm_apply(params["postnorm_mixer"], h2, cfg.norm_eps)
    x = x + h2
    if bd.ffn != "none":
        h = _sp(rmsnorm_apply(params["norm_ffn"], x, cfg.norm_eps))
        if bd.ffn == "moe":
            h, _ = moe.apply(params["ffn"], h, _moe_cfg(cfg), quant, dt)
        else:
            h = ffn.apply(params["ffn"], h, quant, cfg.ffn_kind, dt)
        if cfg.post_norms:
            h = rmsnorm_apply(params["postnorm_ffn"], h, cfg.norm_eps)
        x = x + h
    return x, cache
