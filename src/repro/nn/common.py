"""Shared building blocks for the pure-JAX model zoo.

Parameters are plain nested dicts of jnp arrays (f32 masters). Every init
function returns ``(params, axes)`` where ``axes`` mirrors the params pytree
with tuples of *logical axis names* — the sharding layer
(``repro.parallel.sharding``) maps logical axes to mesh axes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# Logical axis vocabulary (see parallel/sharding.py for the mesh mapping).
BATCH = "batch"
SEQ = "seq"
LAYERS = "layers"  # scan-stacked layer axis: never sharded
D_MODEL = "d_model"
D_FF = "d_ff"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
VOCAB = "vocab"
EXPERT = "expert"
KV_LORA = "kv_lora"
STATE = "state"
RNN = "rnn"
CONV = "conv"
UNSHARDED = None


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    """Truncated-normal init with fan-in scaling (lecun-style)."""
    stddev = scale / math.sqrt(max(shape[0], 1))
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in, d_out, axes, scale=1.0):
    """A single projection weight + its logical axes."""
    w = truncated_normal_init(key, (d_in, d_out), scale)
    return {"w": w}, {"w": axes}


def split_keys(key, n):
    return list(jax.random.split(key, n))


def cast_compute(x, dtype):
    """Cast params/activations to the compute dtype (bf16 on TPU)."""
    if x.dtype in (jnp.float32, jnp.bfloat16, jnp.float16) and x.dtype != dtype:
        return x.astype(dtype)
    return x


def round_to(x, dtype):
    """Round ``x`` to ``dtype`` precision through an op XLA cannot elide.

    ``astype`` narrowing inside a fused elementwise chain may be skipped
    under XLA's default excess-precision rules (the value stays f32 in
    registers), so two structurally different programs — e.g. the
    per-layer engine step and the layer-fused megakernel, whose whole
    body is one fused kernel jaxpr — can round the SAME chain at
    different points and drift by 1 ulp. ``lax.reduce_precision`` is the
    HLO op defined to defeat exactly that, making the rounding part of
    the program's semantics rather than a fusion accident. Used at the
    narrowing points that sit between two elementwise ops.

    Applying it with ``dtype == x.dtype`` is NOT a no-op: it snaps a
    value whose jaxpr dtype is already narrow but whose runtime carrier
    may be wide (e.g. a bf16 elementwise result feeding an f32-preferred
    dot) back onto the representable grid.
    """
    fi = jnp.finfo(dtype)
    x = jax.lax.reduce_precision(x, fi.nexp, fi.nmant)
    return x if x.dtype == dtype else x.astype(dtype)


def stack_inits(init_fn, key, n):
    """vmap ``init_fn(key) -> (params, axes)`` over ``n`` stacked copies.

    Returns (stacked_params, axes) where params carry a leading ``layers``
    axis and the axes pytree has LAYERS prepended to every entry.
    """
    keys = jnp.stack(split_keys(key, n))
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)  # structure only; throwaway values
    return params, prepend_axis(axes)


def prepend_axis(axes_tree, name=LAYERS):
    """Prepend a logical axis name to every tuple in an axes pytree."""
    return jax.tree_util.tree_map(
        lambda t: (name, *t), axes_tree, is_leaf=lambda t: isinstance(t, tuple)
    )


def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
