"""Model configuration schema shared by all 10 assigned architectures.

A model is: embedding -> [prologue blocks] -> num_groups x pattern (scanned)
-> [epilogue blocks] -> final norm -> LM head. Heterogeneous layer stacks
(gemma2 local/global alternation, recurrentgemma 2:1 recurrent:attention,
deepseek dense-then-MoE) are expressed as a repeating ``pattern`` of
BlockDefs plus optional unscanned prologue/epilogue — the scan keeps compile
time O(pattern), not O(num_layers), which is what makes 56-layer dry-runs
tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import QuantConfig


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One decoder block: a sequence mixer + a channel mixer."""

    mixer: str  # "attn" | "mla" | "rglru" | "ssd"
    window: Optional[int] = None  # sliding window for attn mixers
    ffn: str = "dense"  # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    vocab_size: int
    # layer stack
    pattern: Tuple[BlockDef, ...]
    num_groups: int
    prologue: Tuple[BlockDef, ...] = ()
    epilogue: Tuple[BlockDef, ...] = ()
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    query_chunk: int = 1024
    # ffn
    d_ff: int = 0
    ffn_kind: str = "swiglu"
    # moe
    num_experts: int = 0
    top_k: int = 0
    num_shared: int = 0
    d_ff_expert: int = 0
    aux_loss_weight: float = 0.01
    moe_dispatch: str = "dense"  # "dense" | "sorted" (ragged_dot dropless)
    train_microbatches: int = 1  # gradient-accumulation microbatches
    # mla
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # rglru
    rnn_width: int = 0
    conv_width: int = 4
    # ssd
    d_inner: int = 0
    headdim: int = 64
    d_state: int = 128
    ngroups: int = 1
    ssd_chunk: int = 256
    # embedding / head
    tied_embeddings: bool = True
    scale_embeds_by_sqrt_dim: bool = False
    logit_softcap: Optional[float] = None
    num_codebooks: int = 1  # musicgen: parallel codebook heads
    post_norms: bool = False  # gemma2 sandwich norms
    norm_eps: float = 1e-6
    # numerics / policy
    quant: QuantConfig = QuantConfig()
    compute_dtype: object = jnp.bfloat16
    remat: str = "full"  # "full" | "none"
    # paged serving: allocate full-length (non-ring) KV caches so prefill
    # caches transfer 1:1 into page pools (window masking still applies)
    serve_full_cache: bool = False
    # paged decode attention path: "einsum" (gather + dequantize the padded
    # table in HBM — the reference oracle) or "fused" (single-pass Pallas
    # flash-decode over the page table; work scales with resident tokens).
    # The serve engine flips this to "fused" by default (ServeConfig).
    decode_kernel: str = "einsum"
    # bookkeeping for the assignment sheet
    source: str = ""
    sub_quadratic: bool = False  # eligible for long_500k

    @property
    def num_layers(self) -> int:
        return (
            len(self.prologue)
            + self.num_groups * len(self.pattern)
            + len(self.epilogue)
        )

    def all_blocks(self) -> Tuple[BlockDef, ...]:
        return (
            *self.prologue,
            *(self.pattern * self.num_groups),
            *self.epilogue,
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
