"""Grouped-query attention with sliding windows, softcap, and KV caches.

Features used across the assigned archs:
  * GQA / MQA / MHA via ``num_kv_heads`` (no materialized head repeat —
    grouped einsum keeps HLO bytes honest for the roofline),
  * sliding-window masking (mixtral SWA, gemma2 local, recurrentgemma local),
  * attention logit softcapping (gemma2),
  * query-chunked computation for long prefill (bounds the live logits
    buffer; flash-style full kernels are a TPU-runtime concern, the chunk
    loop gives the same asymptotic memory on the dry-run),
  * ring-buffer KV cache bounded by the window for local layers — this is
    what makes 500k-token decode feasible for SWA archs,
  * optional MX-quantized KV cache (beyond-paper: block-scaled cache storage
    cuts decode HBM traffic, the dominant roofline term at long context).

Projections go through ``linear.apply`` and therefore inherit the MX policy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, quantize
from repro.core import formats as F

from . import common as C
from . import linear
from .rotary import apply_rope

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding window (None = full causal)
    softcap: Optional[float] = None
    query_chunk: int = 1024
    cache_dtype: object = jnp.bfloat16
    # paged serving: don't clamp the cache to the window (no ring wraparound;
    # decode slot == absolute position, so caches map 1:1 onto page pools)
    no_ring: bool = False
    # paged decode path: "einsum" gathers + dequantizes the padded table in
    # HBM (reference oracle); "fused" runs the single-pass Pallas
    # flash-decode kernel over the page table (MX pools; wide bf16 pools
    # fall back to the einsum gather — there is nothing to dequantize)
    decode_kernel: str = "einsum"


def init(key, cfg: AttnConfig):
    ks = C.split_keys(key, 4)
    h, kvh, d, dm = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    wq, aq = linear.init(ks[0], dm, h * d, (C.D_MODEL, C.HEADS))
    wk, ak = linear.init(ks[1], dm, kvh * d, (C.D_MODEL, C.KV_HEADS))
    wv, av = linear.init(ks[2], dm, kvh * d, (C.D_MODEL, C.KV_HEADS))
    wo, ao = linear.init(ks[3], h * d, dm, (C.HEADS, C.D_MODEL))
    return (
        {"wq": wq, "wk": wk, "wv": wv, "wo": wo},
        {"wq": aq, "wk": ak, "wv": av, "wo": ao},
    )


def _mask(qpos, kpos, window):
    """Causal + window + validity mask: (..., S_q, S_k) boolean."""
    m = kpos[..., None, :] <= qpos[..., :, None]
    if window is not None:
        m &= kpos[..., None, :] > (qpos[..., :, None] - window)
    m &= kpos[..., None, :] >= 0
    return m


def _attend(q, k, v, qpos, kpos, cfg: AttnConfig):
    """Grouped attention core. q: (B,S,H,D), k/v: (B,T,KVH,D). f32 softmax.

    Under a mesh, query rows are sequence-sharded over the TP axis
    (``seq_model``) so the (S, T) logits temp shards 16-way regardless of
    head count — GQA head counts (8, 10) often don't divide the TP axis,
    so head-sharding alone cannot bound this buffer.
    """
    from repro.parallel.ctx import maybe_constrain

    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    qg = maybe_constrain(qg, "batch", "seq_model", None, None, None)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    logits = maybe_constrain(logits, "batch", None, None, "seq_model", None)
    logits = logits * (d**-0.5)
    if cfg.softcap:
        logits = jnp.tanh(logits / cfg.softcap) * cfg.softcap
    mask = _mask(qpos, kpos, cfg.window)  # (B, S, T) or (S, T)
    while mask.ndim < logits.ndim:
        mask = mask[..., None, :, :] if mask.ndim >= 3 else mask[None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    # Constrain the output like the query: without this, the BACKWARD of
    # this einsum sees inconsistent shardings and SPMD falls back to full
    # rematerialization (an all-gather of the f32 logits over the batch
    # axis — measured 1.2e13 B/device on phi4 train_4k; §Perf iteration 1).
    out = maybe_constrain(out, "batch", "seq_model", None, None, None)
    return out.reshape(b, s, h, d)


def _attend_chunked(q, k, v, qpos, kpos, cfg: AttnConfig):
    """Query-chunked attention: bounds live logits to (B,H,chunk,T)."""
    b, s, h, d = q.shape
    cs = cfg.query_chunk
    if s <= cs or s % cs != 0:
        return _attend(q, k, v, qpos, kpos, cfg)
    nc = s // cs
    qc = q.reshape(b, nc, cs, h, d).swapaxes(0, 1)  # (nc, B, cs, H, D)
    pc = qpos.reshape(b, nc, cs).swapaxes(0, 1) if qpos.ndim == 2 else qpos.reshape(nc, cs)

    def body(args):
        qi, pi = args
        return _attend(qi, k, v, pi, kpos, cfg)

    out = jax.lax.map(body, (qc, pc))  # (nc, B, cs, H, D)
    return out.swapaxes(0, 1).reshape(b, s, h, d)


def apply_train(params, x, positions, cfg: AttnConfig, quant: QuantConfig,
                compute_dtype=jnp.bfloat16):
    """Full-sequence causal self-attention (training / prefill compute)."""
    b, s, _ = x.shape
    h, kvh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear.apply(params["wq"], x, quant, compute_dtype).reshape(b, s, h, d)
    k = linear.apply(params["wk"], x, quant, compute_dtype).reshape(b, s, kvh, d)
    v = linear.apply(params["wv"], x, quant, compute_dtype).reshape(b, s, kvh, d)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = _attend_chunked(q, k, v, positions, positions, cfg)
    return linear.apply(params["wo"], out.reshape(b, s, h * d), quant,
                        compute_dtype, tp_on="in")


# ---------------------------------------------------------------------------
# KV cache (ring buffer, optionally MX-quantized)
# ---------------------------------------------------------------------------


def cache_len(cfg: AttnConfig, max_seq: int) -> int:
    if cfg.no_ring:
        return max_seq
    return min(cfg.window, max_seq) if cfg.window else max_seq


def _cache_arrays(lead, cfg: AttnConfig, quant: QuantConfig):
    """Zero cache leaves with leading dims ``lead`` + (KVH, ·) storage.

    Single source of truth for the MX-vs-wide storage layout: the
    contiguous per-slot caches and the paged pools must agree exactly,
    since prefill caches reshape 1:1 into pool pages.
    """
    kvh, d = cfg.num_kv_heads, cfg.head_dim
    if quant.quantize_kv_cache and quant.enabled:
        bs = min(quant.block_size, d)
        fmt = F.get_format(quant.fmt)
        ed = d // 2 if fmt.packed else d
        zeros_e = jnp.zeros((*lead, kvh, ed), fmt.storage_dtype)
        zeros_s = jnp.zeros((*lead, kvh, d // bs), jnp.uint8)
        return {
            "k_elems": zeros_e, "k_scales": zeros_s,
            "v_elems": zeros_e, "v_scales": zeros_s,
        }
    z = jnp.zeros((*lead, kvh, d), cfg.cache_dtype)
    return {"k": z, "v": z}


def _quantize_kv_token(k_new, v_new, cfg: AttnConfig, quant: QuantConfig):
    """The MX cache-write quantization, shared by every write path."""
    bs = min(quant.block_size, cfg.head_dim)
    return (quantize(k_new.astype(jnp.float32), quant.fmt, bs),
            quantize(v_new.astype(jnp.float32), quant.fmt, bs))


def init_cache(batch: int, max_seq: int, cfg: AttnConfig,
               quant: QuantConfig):
    """Allocate an empty ring-buffer cache. ``kpos`` tracks absolute key
    positions (-1 = empty slot) so windowed wraparound masking is exact."""
    t = cache_len(cfg, max_seq)
    cache = _cache_arrays((batch, t), cfg, quant)
    cache["kpos"] = jnp.full((t,), -1, jnp.int32)
    return cache


def _write_cache(cache, k_new, v_new, slot, pos, quant: QuantConfig, cfg):
    """Write one token's k/v at ring slot (dynamic_update_slice)."""
    if "k" in cache:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
    else:
        kq, vq = _quantize_kv_token(k_new, v_new, cfg, quant)
        cache = dict(cache)
        cache["k_elems"] = jax.lax.dynamic_update_slice(
            cache["k_elems"], kq.elements, (0, slot, 0, 0))
        cache["k_scales"] = jax.lax.dynamic_update_slice(
            cache["k_scales"], kq.scales, (0, slot, 0, 0))
        cache["v_elems"] = jax.lax.dynamic_update_slice(
            cache["v_elems"], vq.elements, (0, slot, 0, 0))
        cache["v_scales"] = jax.lax.dynamic_update_slice(
            cache["v_scales"], vq.scales, (0, slot, 0, 0))
    cache["kpos"] = jax.lax.dynamic_update_slice(
        cache["kpos"], pos[None].astype(jnp.int32), (slot,)
    )
    return cache


def _read_cache(cache, quant: QuantConfig, cfg, dtype):
    if "k" in cache:
        return cache["k"].astype(dtype), cache["v"].astype(dtype)
    bs = min(quant.block_size, cfg.head_dim)
    fmt = F.get_format(quant.fmt)

    def deq(elems, scales):
        vals = F.decode_elements(elems, fmt, jnp.float32)
        blocked = vals.reshape(*vals.shape[:-1], scales.shape[-1], bs)
        wide = blocked * F.e8m0_to_scale(scales)[..., None]
        return wide.reshape(vals.shape).astype(dtype)

    return (deq(cache["k_elems"], cache["k_scales"]),
            deq(cache["v_elems"], cache["v_scales"]))


def cache_kv_view(k, v, cfg: AttnConfig, quant: QuantConfig):
    """K/V exactly as the cache will hold them.

    bf16 caches store K/V verbatim, so this is the identity. MX caches
    store quantized elements+scales, so prefill attention must see the
    quantize->dequantize snap — the same values decode reads back and the
    same values a prefix-cache tail prefill gathers from shared pages.
    Routing through ``_quantize_kv_token`` + ``_read_cache`` (the cache's
    own write/read pair) is what makes full prefill, tail prefill over
    cached pages, and decode agree bit-for-bit.
    """
    if not (quant.quantize_kv_cache and quant.enabled):
        return k, v
    kq, vq = _quantize_kv_token(k, v, cfg, quant)
    view = {"k_elems": kq.elements, "k_scales": kq.scales,
            "v_elems": vq.elements, "v_scales": vq.scales}
    return _read_cache(view, quant, cfg, k.dtype)


def gather_page_kv(pool, page_ids, cfg: AttnConfig, quant: QuantConfig,
                   dtype=jnp.bfloat16):
    """Dequantized K/V of ``page_ids`` pool pages, as (1, n*PS, KVH, D).

    The prefix-cache read path for tail prefill: pages are gathered in
    page-table order, so row ``t`` is absolute position ``t`` of the
    cached prefix.
    """
    view = {key: leaf[page_ids].reshape(1, -1, *leaf.shape[2:])
            for key, leaf in pool.items()}
    return _read_cache(view, quant, cfg, dtype)


def _project_decode_qkv(params, x, posv, cfg: AttnConfig,
                        quant: QuantConfig, compute_dtype):
    """Decode prologue shared by the fixed-slot, paged, and speculative
    verify paths: QKV projection + RoPE at per-token positions posv
    (B, S) for x (B, S, d_model) — S == 1 for one-token decode, S == Tq
    for a verify chunk. Every op is token-row independent, and keeping
    this (and ``_quantize_kv_token`` / ``_read_cache``) single-sourced is
    what makes continuous-batching and speculative outputs
    token-identical to the fixed-slot path.

    Head counts are inferred from the projection widths, not the config:
    inside the sharded serve step (``parallel.ctx.serve_tp_axis``) the
    wq/wk/wv shards carry only the device's KV-head slice, so the
    reshape must follow the local width."""
    b, s = x.shape[:2]
    d = cfg.head_dim
    q = linear.apply(params["wq"], x, quant, compute_dtype).reshape(b, s, -1, d)
    k = linear.apply(params["wk"], x, quant, compute_dtype).reshape(b, s, -1, d)
    v = linear.apply(params["wv"], x, quant, compute_dtype).reshape(b, s, -1, d)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    return q, k, v


def apply_decode(params, x, cache, pos, cfg: AttnConfig, quant: QuantConfig,
                 compute_dtype=jnp.bfloat16):
    """Single-token decode: x (B, 1, d_model), pos scalar int32."""
    b = x.shape[0]
    h, d = cfg.num_heads, cfg.head_dim
    posv = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_decode_qkv(params, x, posv, cfg, quant, compute_dtype)
    t = cache["kpos"].shape[0]
    slot = jnp.asarray(pos % t, jnp.int32)
    cache = _write_cache(cache, k, v, slot, jnp.asarray(pos, jnp.int32), quant, cfg)
    kc, vc = _read_cache(cache, quant, cfg, compute_dtype)
    out = _attend(q, kc, vc, posv, cache["kpos"][None], cfg)
    y = linear.apply(params["wo"], out.reshape(b, 1, h * d), quant,
                     compute_dtype, tp_on="in")
    return y, cache


# ---------------------------------------------------------------------------
# paged KV cache (continuous batching: global page pool + per-slot tables)
# ---------------------------------------------------------------------------


def init_paged_pool(num_pages: int, page_size: int, cfg: AttnConfig,
                    quant: QuantConfig, tiered: bool = False):
    """Allocate a layer's global KV page pool (no per-sequence dimension).

    Layout matches the paged Pallas kernels: (NP, PS, KVH, ·), with the
    same storage leaves as the contiguous cache (``_cache_arrays``).
    Ownership (which page belongs to which sequence at which position)
    lives in the host-side page table, not in the arrays.

    ``tiered=True`` allocates the mixed-format layout instead: element
    leaves are raw uint8 rows of the *full* head_dim width regardless of
    element format — a narrower format's codes occupy the row prefix
    (fp8 = D bytes, fp6 = 3D/4, fp4 = D/2) and which format a page
    currently holds lives in the engine's per-page format array, not in
    the pool. Requires an MX-quantized cache with an 8-bit hot format
    (fresh writes are always fp8; the repack ladder narrows them later).
    """
    if not tiered:
        return _cache_arrays((num_pages, page_size), cfg, quant)
    if not (quant.quantize_kv_cache and quant.enabled):
        raise ValueError("tiered KV pools require an MX-quantized cache")
    if F.get_format(quant.fmt).bits != 8:
        raise ValueError(
            "tiered KV pools write new pages in the hot format, which "
            f"must be an fp8; got {quant.fmt!r}")
    kvh, d = cfg.num_kv_heads, cfg.head_dim
    bs = min(quant.block_size, d)
    zeros_e = jnp.zeros((num_pages, page_size, kvh, d), jnp.uint8)
    zeros_s = jnp.zeros((num_pages, page_size, kvh, d // bs), jnp.uint8)
    return {"k_elems": zeros_e, "k_scales": zeros_s,
            "v_elems": zeros_e, "v_scales": zeros_s}


def apply_decode_paged(params, x, pool, page_rows, pos, cfg: AttnConfig,
                       quant: QuantConfig, compute_dtype=jnp.bfloat16,
                       page_fmts=None, mixed_fmts=None):
    """Per-slot decode through a page table: x (B, 1, d_model), pos (B,).

    ``page_rows`` (B, P) holds each slot's page ids (-1 = unallocated).
    Each slot writes its new token's K/V at page ``pos // PS`` slot
    ``pos % PS`` (inactive slots route to an out-of-bounds page and are
    dropped), then attends over its pages. Write-then-read order,
    quantization, and dequantization are shared with the fixed-slot path,
    which is what keeps continuous-batching outputs token-identical.

    Two attention paths, selected by ``cfg.decode_kernel``:

      * ``"einsum"`` — gather the *entire padded* table out of the pool,
        dequantize it to wide ``compute_dtype`` in HBM, and run the masked
        einsum attention. Cost scales with the table width (max_pages),
        not the tokens actually resident; kept as the reference oracle.
      * ``"fused"`` — single Pallas kernel (`mx_attention_decode_fused`):
        walk the page table via scalar prefetch, dequantize each compact
        page tile in-register, accumulate the softmax online. No gathered
        copy (wide or compact) is ever materialized and pages past
        ``ceil(seq_len / page_size)`` are skipped. Wide bf16 pools fall
        back to the einsum gather (there is nothing to dequantize).

    Implemented as the Tq == 1 case of :func:`apply_verify_paged` (one
    shared body, exactly as the kernel layer delegates decode to the
    verify kernel) — a fix to either path cannot miss the other, which
    the spec-vs-plain token-identity guarantee depends on.
    """
    return apply_verify_paged(params, x, pool, page_rows, pos, cfg, quant,
                              compute_dtype, page_fmts=page_fmts,
                              mixed_fmts=mixed_fmts)


def apply_verify_paged(params, x, pool, page_rows, pos, cfg: AttnConfig,
                       quant: QuantConfig, compute_dtype=jnp.bfloat16,
                       page_fmts=None, mixed_fmts=None):
    """Multi-token paged verify: x (B, Tq, d_model), pos (B,).

    The speculative-decoding verify step: each slot feeds ``Tq`` tokens —
    the pending sampled token plus ``Tq - 1`` drafts — at absolute
    positions ``pos .. pos + Tq - 1``. All Tq tokens' K/V are quantized
    and written into their pages first (page ``p // PS``, slot
    ``p % PS``; inactive slots route out-of-bounds and are dropped), then
    every query attends over the pages with *per-row causal masking*:
    query ``i`` sees keys at positions ``<= pos + i`` only, so a draft
    token's attention — and therefore its logits and its K/V, should it
    be accepted — is bit-for-bit what a one-token decode at that position
    would have produced. Rejected drafts leave K/V rows beyond the
    accepted point; those rows are dead by masking (the host truncates
    the sequence's position, nothing is zeroed) and the next write at
    that position overwrites them.

    Tq == 1 degenerates to :func:`apply_decode_paged`'s dataflow: the
    projection/RoPE/cache-write path is literally shared
    (``_project_decode_qkv`` / ``_quantize_kv_token``), and every op in
    it is token-row independent — which is what keeps speculative output
    token-identical to non-speculative decode.

    Two attention paths, selected by ``cfg.decode_kernel`` exactly as in
    :func:`apply_decode_paged`: the fused ``mx_attention_verify_fused``
    kernel (one page walk feeds all Tq queries) or the einsum gather
    reference (also the wide-bf16-pool fallback).

    ``page_fmts`` (a (NP,) i32 device array of per-page format ids)
    switches to the mixed-format tiered pool layout: the pool stores raw
    uint8 byte rows, writes land in the hot fp8 format (bitcast into the
    byte rows — the engine marks written pages hot), and the fused kernel
    selects each page's dequant path from its format id. Tiered pools
    require the fused kernel path (the einsum gather has no per-page
    format select).
    """
    if cfg.decode_kernel not in ("einsum", "fused"):
        raise ValueError(f"unknown decode_kernel {cfg.decode_kernel!r}")
    if page_fmts is not None and (cfg.decode_kernel != "fused"
                                  or "k_elems" not in pool):
        raise ValueError("tiered (mixed-format) KV pools require the fused "
                         "MX decode kernel path")
    b, tq, _ = x.shape
    h, kvh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = jnp.asarray(pos, jnp.int32)
    posv = pos[:, None] + jnp.arange(tq, dtype=jnp.int32)[None]  # (B, Tq)
    q, k, v = _project_decode_qkv(params, x, posv, cfg, quant, compute_dtype)

    lead = pool["k" if "k" in pool else "k_elems"]
    npages, ps = lead.shape[0], lead.shape[1]
    pmax = page_rows.shape[1]
    widx = posv // ps  # (B, Tq) page-table columns
    page = jnp.take_along_axis(page_rows, jnp.clip(widx, 0, pmax - 1),
                               axis=1)
    # OOB: dropped by mode="drop". Unallocated entries are -1, and a
    # position past the table's extent must drop too, not clamp into the
    # last column — a padded final prefill chunk can reach past the
    # table while the sequence legitimately owns its last page, and a
    # clamped write would scatter garbage over live cache rows there.
    page = jnp.where((page < 0) | (widx > pmax - 1), npages, page)
    slot = posv % ps

    pool = dict(pool)
    if "k" in pool:
        pool["k"] = pool["k"].at[page, slot].set(
            k.astype(pool["k"].dtype), mode="drop")
        pool["v"] = pool["v"].at[page, slot].set(
            v.astype(pool["v"].dtype), mode="drop")
    else:
        kq, vq = _quantize_kv_token(k, v, cfg, quant)
        k_el, v_el = kq.elements, vq.elements
        if page_fmts is not None:
            # tiered pool: hot-format fp8 bytes into the uint8 byte rows
            k_el = jax.lax.bitcast_convert_type(k_el, jnp.uint8)
            v_el = jax.lax.bitcast_convert_type(v_el, jnp.uint8)
        pool["k_elems"] = pool["k_elems"].at[page, slot].set(
            k_el, mode="drop")
        pool["k_scales"] = pool["k_scales"].at[page, slot].set(
            kq.scales, mode="drop")
        pool["v_elems"] = pool["v_elems"].at[page, slot].set(
            v_el, mode="drop")
        pool["v_scales"] = pool["v_scales"].at[page, slot].set(
            vq.scales, mode="drop")

    if cfg.decode_kernel == "fused" and "k_elems" in pool:
        from repro.kernels import mx_attention_verify_fused

        # heads split (KVH major, G minor) as the decode path does
        qk = q.reshape(b, tq, kvh, h // kvh, d).transpose(0, 2, 1, 3, 4)
        out = mx_attention_verify_fused(
            qk, pool["k_elems"], pool["k_scales"], pool["v_elems"],
            pool["v_scales"], page_rows, pos + tq,
            fmt_name=quant.fmt, block_size=min(quant.block_size, d),
            softcap=cfg.softcap, window=cfg.window,
            page_fmts=page_fmts, mixed_fmts=mixed_fmts)
        out = out.transpose(0, 2, 1, 3, 4).reshape(
            b, tq, h, d).astype(compute_dtype)
    else:
        idx = jnp.clip(page_rows, 0, npages - 1)  # (B, P); garbage masked

        def gather(leaf):
            return leaf[idx].reshape(b, pmax * ps, *leaf.shape[2:])

        view = {key: gather(leaf) for key, leaf in pool.items()}
        kc, vc = _read_cache(view, quant, cfg, compute_dtype)
        t = kc.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        out = _attend(q, kc, vc, posv, kpos, cfg)
    y = linear.apply(params["wo"], out.reshape(b, tq, h * d), quant,
                     compute_dtype, tp_on="in")
    return y, pool


def apply_prefill_chunked(params, x, pool, page_rows, pos, num_valid,
                          cfg: AttnConfig, quant: QuantConfig,
                          compute_dtype=jnp.bfloat16, page_fmts=None,
                          mixed_fmts=None):
    """One chunk of paged prefill: x (B, C, d_model), pos (B,), num_valid
    (B,).

    The chunked-prefill generalization of :func:`apply_verify_paged`:
    ``C`` prompt tokens at absolute positions ``pos .. pos + C - 1``
    (``pos`` page-aligned, ``C`` a page multiple — the engine enforces
    both) attend over every page written so far plus themselves
    intra-causally, and the chunk's K/V lands in the sequence's pages.
    ``num_valid`` is how many chunk rows are real prompt tokens (the last
    chunk of a prompt is padded up to the fixed ``C``; padding rows write
    only dead-by-masking garbage and their outputs are ignored).

    Two paths, selected by ``cfg.decode_kernel`` exactly as decode/verify:

      * ``"fused"`` (MX pools) — :func:`mx_attention_prefill_fused`: one
        Pallas kernel walks the page table, quantizes the chunk's K/V
        in-register and writes it straight into its pages (aliased
        outputs — no host-side install), and folds both resident pages
        and the chunk's own quantized snap into one online softmax. No
        wide K/V beyond the chunk's own (B, C, KVH, D) projection output
        ever exists, and per-chunk work scales with resident tokens.
      * ``"einsum"`` (reference oracle, and wide bf16 pools) — delegate
        to :func:`apply_verify_paged` with Tq == C: host-side quantized
        page writes, then the gather-and-dequantize masked attention.

    Both share ``_project_decode_qkv`` / the ``core.quantize`` math with
    decode and verify, so the cache bytes a chunk writes are bit-for-bit
    what one-token decode at those positions would have written — the
    invariant chunked-vs-monolithic token identity rests on.

    ``page_fmts``/``mixed_fmts`` switch to the mixed-format tiered pool
    exactly as in :func:`apply_verify_paged` (fused path only): resident
    pages dequantize per their format id, the chunk's pages are written
    in the hot fp8 format.
    """
    if cfg.decode_kernel not in ("einsum", "fused"):
        raise ValueError(f"unknown decode_kernel {cfg.decode_kernel!r}")
    if page_fmts is not None and (cfg.decode_kernel != "fused"
                                  or "k_elems" not in pool):
        raise ValueError("tiered (mixed-format) KV pools require the fused "
                         "MX prefill kernel path")
    if cfg.decode_kernel == "fused" and "k_elems" in pool:
        from repro.kernels import mx_attention_prefill_fused

        b, c, _ = x.shape
        h, kvh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        pos = jnp.asarray(pos, jnp.int32)
        posv = pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        q, k, v = _project_decode_qkv(params, x, posv, cfg, quant,
                                      compute_dtype)
        qk = q.reshape(b, c, kvh, h // kvh, d).transpose(0, 2, 1, 3, 4)
        out, (ke, ks, ve, vs) = mx_attention_prefill_fused(
            qk, k, v, pool["k_elems"], pool["k_scales"], pool["v_elems"],
            pool["v_scales"], page_rows, pos,
            pos + jnp.asarray(num_valid, jnp.int32),
            fmt_name=quant.fmt, block_size=min(quant.block_size, d),
            softcap=cfg.softcap, window=cfg.window,
            page_fmts=page_fmts, mixed_fmts=mixed_fmts)
        pool = dict(pool, k_elems=ke, k_scales=ks, v_elems=ve, v_scales=vs)
        out = out.transpose(0, 2, 1, 3, 4).reshape(
            b, c, h, d).astype(compute_dtype)
        y = linear.apply(params["wo"], out.reshape(b, c, h * d), quant,
                         compute_dtype, tp_on="in")
        return y, pool
    return apply_verify_paged(params, x, pool, page_rows, pos, cfg, quant,
                              compute_dtype)


def apply_ragged(params, x, pool, page_rows, row_start, seq_lens,
                 cfg: AttnConfig, quant: QuantConfig,
                 compute_dtype=jnp.bfloat16, page_fmts=None,
                 mixed_fmts=None):
    """One ragged engine step: x (R, W, d_model), row_start/seq_lens (R,).

    The one-dispatch generalization of decode, verify, AND chunked
    prefill: every row feeds ``W`` token columns at absolute positions
    ``row_start .. row_start + W - 1``, of which ``seq_lens - row_start``
    are real this step — 1 for a plain decode row, 1 + K for a
    speculative verify window, up to W for an in-flight prefill chunk.
    Unlike :func:`apply_verify_paged` there is NO host-side ``.at[].set``
    cache write: the new rows' K/V ride into
    :func:`~repro.kernels.mx_attention_ragged_fused` wide and are
    quantized + merged into the row's pages inside the kernel (aliased
    pool outputs), so the whole step is one device dispatch and the
    per-token write stops round-tripping through HBM.

    Padding columns (past ``seq_lens``) project garbage the kernel
    clamps onto the last real position; their outputs are ignored and
    their K/V rows are excluded from the page merge, so real rows are
    bit-identical to the split decode/verify/prefill paths (shared
    ``_project_decode_qkv`` / ``_quantize_rows`` math, same page-walk
    accumulation order).

    Fused-MX-only: the ragged step exists to fuse the kernel page walk
    with the in-kernel write, so there is no einsum/wide-pool fallback —
    the engine falls back to ``step_mode="split"`` for those configs.
    ``page_rows`` may contain negative entries; the kernel routes them
    to the pool's reserved trash page (see the kernel's contract).

    Inside the engine's KV-head-sharded serve step
    (``parallel.ctx.serve_tp_axis`` set, i.e. traced under the engine's
    ``shard_map``) the pool leaves and the wq/wk/wv projections carry
    only this device's ``KVH / M`` head slice, so the kernel's grid —
    already ``(R, KVH, P)`` — shards along its KV-head dimension for
    free. The ONE collective of the whole step happens here: the kernel
    output is all-gathered over the mesh axis (tiled along the KV-head
    dim, device order == head order) before the output projection, whose
    replicated ``wo`` then sees bit-identical full-width operands on
    every device — which is what keeps the sharded engine
    token-identical to the single-device one (a sharded-``wo`` psum
    would split the f32 reduction instead and drift).

    MIRROR CONTRACT: the layer-fused megakernel
    (``kernels.mx_megakernel_step``) re-implements this row math —
    norm, QKV projection + RoPE, the fused page walk, the in-kernel
    quantized write — inside its own kernel body, and its acceptance
    bar is bit-identity with this path (logits AND written pool bytes).
    Any numeric change here (rounding points, projection order, RoPE
    variant, quantize math) must land in ``kernels/mx_megakernel.py``
    in the same PR or ``tests/test_megakernel.py`` will catch the
    drift.
    """
    if cfg.decode_kernel != "fused" or "k_elems" not in pool:
        raise ValueError(
            "apply_ragged requires the fused MX decode kernel over an "
            "MX-quantized page pool (use step_mode='split' otherwise)")
    from repro.kernels import mx_attention_ragged_fused
    from repro.parallel.ctx import serve_tp_axis

    r, w, _ = x.shape
    d = cfg.head_dim
    row_start = jnp.asarray(row_start, jnp.int32)
    posv = row_start[:, None] + jnp.arange(w, dtype=jnp.int32)[None]
    q, k, v = _project_decode_qkv(params, x, posv, cfg, quant,
                                  compute_dtype)
    # local head counts (== cfg's when unsharded; the device's slice
    # under serve TP — heads are laid out KV-major, so contiguous q-head
    # shards align with contiguous KV-head shards)
    kvh = k.shape[2]
    g = q.shape[2] // kvh
    qk = q.reshape(r, w, kvh, g, d).transpose(0, 2, 1, 3, 4)
    out, (ke, ks, ve, vs) = mx_attention_ragged_fused(
        qk, k, v, pool["k_elems"], pool["k_scales"], pool["v_elems"],
        pool["v_scales"], page_rows, row_start,
        jnp.asarray(seq_lens, jnp.int32),
        fmt_name=quant.fmt, block_size=min(quant.block_size, d),
        softcap=cfg.softcap, window=cfg.window,
        page_fmts=page_fmts, mixed_fmts=mixed_fmts)
    pool = dict(pool, k_elems=ke, k_scales=ks, v_elems=ve, v_scales=vs)
    axis = serve_tp_axis()
    if axis is not None:
        # (R, KVH/M, W, G, D) -> (R, KVH, W, G, D): the step's one
        # collective; per-(row, kv-head) online softmax is independent,
        # so the gathered tensor is exactly the unsharded kernel output
        out = jax.lax.all_gather(out, axis, axis=1, tiled=True)
    out = out.transpose(0, 2, 1, 3, 4)
    out = out.reshape(r, w, -1).astype(compute_dtype)
    y = linear.apply(params["wo"], out, quant,
                     compute_dtype, tp_on="in")
    return y, pool


def prefill_cache(params, x, positions, cfg: AttnConfig, quant: QuantConfig,
                  k, v, max_seq: int):
    """Populate a fresh cache from full-sequence K/V (last window if ring)."""
    b, s = positions.shape
    t = cache_len(cfg, max_seq)
    cache = init_cache(b, max_seq, cfg, quant)
    take = min(s, t)
    k_tail = k[:, s - take:s]
    v_tail = v[:, s - take:s]
    pos_tail = positions[0, s - take:s]
    # Decode writes token p at ring slot p % t, so prefill must too. The
    # tail positions are contiguous, so slot assignment is a roll by p0 % t
    # (p0 = first tail position; p0 == 0 whenever take < t).
    def place(buf2d):
        # buf2d: (..., take, ...) written at slots [(p0 + i) % t]
        return jnp.roll(buf2d, pos_tail[0] % t, axis=1) if take == t else buf2d

    if "k" in cache:
        cache["k"] = place(cache["k"].at[:, :take].set(k_tail.astype(cache["k"].dtype)))
        cache["v"] = place(cache["v"].at[:, :take].set(v_tail.astype(cache["v"].dtype)))
    else:
        kq, vq = _quantize_kv_token(k_tail, v_tail, cfg, quant)
        cache["k_elems"] = place(cache["k_elems"].at[:, :take].set(kq.elements))
        cache["k_scales"] = place(cache["k_scales"].at[:, :take].set(kq.scales))
        cache["v_elems"] = place(cache["v_elems"].at[:, :take].set(vq.elements))
        cache["v_scales"] = place(cache["v_scales"].at[:, :take].set(vq.scales))
    kpos = cache["kpos"].at[:take].set(pos_tail)
    cache["kpos"] = jnp.roll(kpos, pos_tail[0] % t, axis=0) if take == t else kpos
    return cache
