"""MX-aware linear layers — the framework integration of the paper's技ique.

Every dense projection in the model zoo goes through ``mx_linear``:

  * quant disabled      -> plain bf16 matmul (the FP32/BF16 baselines of §III)
  * weight-only         -> wide activations x MX weights (vector-scalar
                           variant; serving-style weight compression)
  * weight+activation   -> both operands block-quantized per step via the
                           custom-vjp ``qat_matmul`` (vector-vector variant)

Execution mode (emulated | fused | pallas) comes from ``QuantConfig.mode``.
Master weights stay wide; quantization happens at use, so the same params
train with or without MX.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import QuantConfig, fake_quant, mx_dot, qat_matmul, quantize
from repro.core.mx_tensor import MXTensor

from . import common as C


def init(key, d_in: int, d_out: int, axes=(C.D_MODEL, C.D_FF), scale=1.0):
    return C.dense_init(key, d_in, d_out, axes, scale)


def apply(params, x, quant: QuantConfig, compute_dtype=jnp.bfloat16,
          tp_on: str = "out"):
    """Apply ``x @ w`` under the quantization policy.

    ``tp_on`` marks which w dim is tensor-parallel (see qat_matmul): "in"
    for output projections (wo/down/out_proj), "out" otherwise.
    """
    w = params["w"]
    if isinstance(w, MXTensor):  # pre-quantized weights (serving path)
        y = mx_dot(
            x.astype(compute_dtype) if not quant.enabled else _maybe_q_act(x, quant),
            w,
            mode=quant.mode if quant.mode != "pallas" or _pallas_ok() else "fused",
            acc_dtype=quant.acc_dtype,
        )
        return y.astype(compute_dtype)
    if not quant.enabled:
        xw = x.astype(compute_dtype)
        return _dot_rounded(xw, w.astype(compute_dtype), compute_dtype)
    if quant.quantize_acts:
        # activations enter in compute dtype (bf16): the QAT path is
        # dtype-preserving end to end (§Perf iteration 2)
        y = qat_matmul(
            x.astype(compute_dtype),
            w.astype(jnp.float32),
            quant.fmt,
            quant.block_size,
            True,
            quant.mode if quant.mode != "pallas" else "fused",
            quant.acc_dtype,
            tp_on if quant.mx_weight_gather else "off",
        )
    else:
        # weight-only: straight-through fake-quantized weights, wide acts
        wq = fake_quant(w.astype(jnp.float32), quant.fmt, quant.block_size, 0)
        y = _dot_rounded(x.astype(compute_dtype), wq.astype(compute_dtype),
                         compute_dtype)
    return y.astype(compute_dtype)


def _dot_rounded(x, w, compute_dtype):
    """``x @ w`` with the output narrowing made explicit.

    A bf16-output dot accumulates in f32 and rounds at the output — but
    when the dot's consumer is an elementwise op inside one fused
    computation (the layer-fused megakernel body), XLA's excess-precision
    rules may hand the consumer the f32 accumulator instead. Accumulating
    in f32 and narrowing through ``reduce_precision`` pins the rounding
    point into the program so the per-layer step and the megakernel see
    bit-identical values. Numerically this is exactly what the plain
    bf16-output dot does when the rounding is *not* elided.
    """
    x = C.round_to(x, x.dtype)  # snap operand: its producer chain may
    w = C.round_to(w, w.dtype)  # carry excess precision into the f32 dot
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    return C.round_to(y, compute_dtype)


def _maybe_q_act(x, quant: QuantConfig):
    if quant.enabled and quant.quantize_acts:
        return quantize(
            x.astype(jnp.float32), quant.activation_format, quant.block_size
        )
    return x.astype(jnp.bfloat16)


def _pallas_ok() -> bool:
    import jax

    return jax.default_backend() == "tpu"


def quantize_weights(params, quant: QuantConfig):
    """Convert wide weight leaves to MXTensors (serving weight compression)."""
    if not quant.enabled:
        return params
    return {"w": quantize(params["w"].astype(jnp.float32), quant.fmt,
                          quant.block_size, axis=0)}
