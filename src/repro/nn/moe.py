"""Mixture-of-Experts FFN with top-k routing, shared experts, and EP sharding.

Design for 1000+ node scale (DESIGN.md §5): experts live on the ``expert``
logical axis (mapped to the ``model`` mesh axis). Token dispatch uses the
dense one-hot einsum formulation — collective-free within a shard (dispatch
and combine contract locally; only the usual data-parallel reductions
remain), deterministic, and capacity-factor-free. For MX, per-expert weights
are block-quantized exactly like dense FFN weights — MoE is where MX weight
compression pays most (expert bytes dominate).

Router math stays f32 (routing decisions must be bit-stable across replicas
for SPMD determinism).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, fake_quant

from . import common as C
from . import linear


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_shared: int = 0  # hidden dim of the shared-expert branch (total)
    ffn_kind: str = "swiglu"
    router_norm_topk: bool = True  # normalize top-k weights to sum 1
    aux_loss_weight: float = 0.01
    dispatch: str = "dense"  # "dense" | "sorted" (ragged_dot dropless)


def init(key, cfg: MoEConfig):
    ks = C.split_keys(key, 5)
    e, dm, dff = cfg.num_experts, cfg.d_model, cfg.d_ff_expert

    def expert_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "gate": C.truncated_normal_init(k1, (dm, dff), 1.0),
            "up": C.truncated_normal_init(k2, (dm, dff), 1.0),
            "down": C.truncated_normal_init(k3, (dff, dm), 1.0),
        }

    experts = jax.vmap(expert_block)(jnp.stack(C.split_keys(ks[0], e)))
    params = {
        "router": {"w": C.truncated_normal_init(ks[1], (dm, e), 1.0)},
        "experts": experts,
    }
    axes = {
        "router": {"w": (C.D_MODEL, C.EXPERT)},
        "experts": {
            "gate": (C.EXPERT, C.D_MODEL, C.D_FF),
            "up": (C.EXPERT, C.D_MODEL, C.D_FF),
            "down": (C.EXPERT, C.D_FF, C.D_MODEL),
        },
    }
    if cfg.num_shared:
        from . import ffn

        sp, sa = ffn.init(ks[2], dm, cfg.d_ff_shared, cfg.ffn_kind)
        params["shared"] = sp
        axes["shared"] = sa
    return params, axes


def _router(params, x, cfg: MoEConfig):
    """Top-k softmax routing in f32. Returns (weights, one_hot, aux_loss)."""
    logits = jnp.einsum(
        "btd,de->bte", x.astype(jnp.float32),
        params["router"]["w"].astype(jnp.float32),
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)  # (B,T,K)
    if cfg.router_norm_topk:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    one_hot = jax.nn.one_hot(top_idx, cfg.num_experts, dtype=jnp.float32)
    # Switch-style load-balancing loss: E * <f_e, p_e>
    frac_tokens = jnp.mean(jnp.sum(one_hot, axis=2), axis=(0, 1))  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(frac_tokens * frac_probs)
    return top_w, one_hot, aux


def _mx_expert_weight(wt, quant: QuantConfig, contract_axis: int, dtype,
                      dm_axis: int = 1):
    """Quantize an (E, d0, d1) expert stack shard-side, gather MX bytes.

    Same MX-FSDP move as ``core.dot._mx_fsdp_quantize`` but for stacked
    expert weights (§Perf iteration 8): GSPMD otherwise all-gathers the f32
    masters of every expert every layer — the single largest collective on
    mixtral train. Each device quantizes its local shard (MX blocks stay
    shard-local), the FSDP all-gather then moves fp8 elements + u8 scales,
    and the wide operand is rebuilt in-register per device.

    Layouts: gate/up are (E, d_model, d_ff) with contract_axis=1 (d_model =
    FSDP dim); down is (E, d_ff, d_model) with contract_axis=1 (d_ff = TP
    dim, d_model = FSDP dim at axis 2).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import formats as FF
    from repro.core import quantize
    from repro.core.mx_tensor import MXTensor
    from repro.parallel.ctx import current_mesh

    wt = wt.astype(jnp.float32)
    if not quant.enabled:
        return wt.astype(dtype)

    def fallback():
        return fake_quant(wt, quant.fmt, quant.block_size,
                          contract_axis).astype(dtype)

    mesh = current_mesh()
    fmt_i = FF.get_format(quant.fmt)
    fsdp = tuple(a for a in ("pod", "data")
                 if a in (mesh.axis_names if mesh else ()))
    if (mesh is None or fmt_i.packed or not fsdp
            or not quant.mx_weight_gather):
        return fallback()
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp]))
    tp = "model" if "model" in mesh.axis_names else None
    tp_size = mesh.shape[tp] if tp else 1
    e, d0, d1 = wt.shape
    e_tp = tp is not None and e % tp_size == 0

    # dm_axis (caller-specified) marks the d_model/FSDP dim: gate/up are
    # (E, d_model, d_ff) -> dm=1; down is (E, d_ff, d_model) -> dm=2.
    other_axis = 2 if dm_axis == 1 else 1
    dims = [tp if e_tp else None, None, None]
    if wt.shape[dm_axis] % fsdp_size:
        return fallback()
    dims[dm_axis] = fsdp
    if not e_tp and tp is not None and wt.shape[other_axis] % tp_size == 0:
        dims[other_axis] = tp
    # the contraction dim's local shard must stay MX-block aligned
    ca_shard = wt.shape[contract_axis]
    if dims[contract_axis] == fsdp:
        ca_shard //= fsdp_size
    elif dims[contract_axis] == tp:
        ca_shard //= tp_size
    if ca_shard % quant.block_size:
        return fallback()
    w_spec = P(*dims)
    # element storage has the contract axis LAST; the remaining dims keep
    # their relative order
    non_contract = [i for i in range(3) if i != contract_axis]
    storage_of = {ax: i for i, ax in enumerate(non_contract)}
    storage_of[contract_axis] = 2
    gather_axis = storage_of[dm_axis]
    local_shape = [e, wt.shape[1], wt.shape[2]]
    for i, d in enumerate(dims):
        if d == fsdp and i != dm_axis:
            local_shape[i] //= fsdp_size
        elif d == tp:
            local_shape[i] //= tp_size

    def body(ws):
        t = quantize(ws, quant.fmt, quant.block_size, axis=contract_axis)
        elems = jax.lax.all_gather(t.elements, fsdp, axis=gather_axis,
                                   tiled=True)
        scales = jax.lax.all_gather(t.scales, fsdp, axis=gather_axis,
                                    tiled=True)
        shp = list(local_shape)
        shp[dm_axis] = wt.shape[dm_axis]  # gathered back to global
        g = MXTensor(elements=elems, scales=scales, fmt_name=fmt_i.name,
                     block_size=quant.block_size, axis=contract_axis,
                     shape=tuple(shp))
        return g.dequantize(dtype)

    out_dims = [d if i != dm_axis else None for i, d in enumerate(dims)]
    from repro.parallel.ctx import shard_map_compat

    return shard_map_compat(body, mesh=mesh, in_specs=(w_spec,),
                            out_specs=P(*out_dims), check_vma=False)(wt)


def _expert_ffn(w, h_in, quant: QuantConfig, kind: str, dtype):
    """Apply all experts' gated FFN to dispatched tokens h_in (E,Cap,D)."""

    gate = jnp.einsum("ecd,edf->ecf", h_in,
                      _mx_expert_weight(w["gate"], quant, 1, dtype, dm_axis=1))
    up = jnp.einsum("ecd,edf->ecf", h_in,
                    _mx_expert_weight(w["up"], quant, 1, dtype, dm_axis=1))
    act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
    h = act(gate.astype(jnp.float32)).astype(dtype) * up
    return jnp.einsum("ecf,efd->ecd", h,
                      _mx_expert_weight(w["down"], quant, 1, dtype, dm_axis=2))


def _sorted_body(params, x, cfg: MoEConfig, quant: QuantConfig, dtype,
                 data_axes=()):
    """Dropless sorted dispatch on one data shard (tokens local).

    Each token is replicated top_k times, rows are sorted by expert id, and
    ``jax.lax.ragged_dot`` runs one grouped GEMM per projection — exactly
    top_k/E of the dense-dispatch FLOPs (mixtral: 4x less; deepseek: 10.7x)
    and no (E, T, D) dispatch buffer (§Perf iteration 9). Expert weights
    arrive FSDP-sharded on d_model; they are quantized shard-side and
    all-gathered as MX bytes (iteration 8 composed).
    """
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    top_w, one_hot, aux = _router(params, x, cfg)
    if data_axes:
        aux = jax.lax.pmean(aux, data_axes)
    top_idx = jnp.argmax(one_hot, axis=-1)  # (B,T,K) recover indices
    n = b * t
    ids = top_idx.reshape(n * k)
    wts = top_w.reshape(n * k).astype(dtype)
    order = jnp.argsort(ids)
    token_of = order // k
    xs = x.reshape(n, d)[token_of].astype(dtype)  # (N*K, D) sorted rows
    group_sizes = jnp.zeros((e,), jnp.int32).at[ids[order]].add(1)

    def gathered(wt, contract_axis, gather_axis):
        """Quantize shard-side, all-gather MX bytes over data on the
        (tensor-coords) d_model dim, dequantize locally."""
        wt = wt.astype(jnp.float32)
        if quant.enabled:
            from repro.core import quantize as _q
            from repro.core.mx_tensor import MXTensor

            tq = _q(wt, quant.fmt, quant.block_size, axis=contract_axis)
            if data_axes:
                non_contract = [i for i in range(3) if i != contract_axis]
                storage_of = {ax: i for i, ax in enumerate(non_contract)}
                storage_of[contract_axis] = 2
                ga = storage_of[gather_axis]
                elems = jax.lax.all_gather(tq.elements, data_axes,
                                           axis=ga, tiled=True)
                scales = jax.lax.all_gather(tq.scales, data_axes,
                                            axis=ga, tiled=True)
                shp = list(wt.shape)
                shp[gather_axis] *= _axes_size(data_axes)
                tq = MXTensor(elems, scales, tq.fmt_name, tq.block_size,
                              contract_axis, tuple(shp))
            return tq.dequantize(dtype)
        if data_axes:
            wt = jax.lax.all_gather(wt, data_axes, axis=gather_axis,
                                    tiled=True)
        return wt.astype(dtype)

    wg = gathered(params["experts"]["gate"], 1, 1)
    wu = gathered(params["experts"]["up"], 1, 1)
    gate = jax.lax.ragged_dot(xs, wg, group_sizes)
    up = jax.lax.ragged_dot(xs, wu, group_sizes)
    act = jax.nn.silu if cfg.ffn_kind == "swiglu" else jax.nn.gelu
    h = act(gate.astype(jnp.float32)).astype(dtype) * up
    wd = gathered(params["experts"]["down"], 1, 2)
    rows = jax.lax.ragged_dot(h, wd, group_sizes)
    rows = rows * wts[order][:, None]
    out = jnp.zeros((n, d), dtype).at[token_of].add(rows)
    return out.reshape(b, t, d), aux


def _axes_size(axes):
    import numpy as np

    from repro.parallel.ctx import current_mesh

    mesh = current_mesh()
    return int(np.prod([mesh.shape[a] for a in axes])) if mesh else 1


def apply_sorted(params, x, cfg: MoEConfig, quant: QuantConfig,
                 compute_dtype=jnp.bfloat16):
    """Dropless sorted-dispatch MoE (ragged_dot grouped GEMMs).

    Under a mesh, runs manually over the data axes (each shard sorts its
    own tokens — results identical to dense dispatch) with the model axis
    left in auto mode so TP/GSPMD still applies inside.
    """
    from jax.sharding import PartitionSpec as P

    from repro.parallel.ctx import current_mesh

    mesh = current_mesh()
    data_axes = tuple(a for a in ("pod", "data")
                      if a in (mesh.axis_names if mesh else ()))
    b = x.shape[0]
    if mesh is None or not data_axes or b % _axes_size(data_axes):
        out, aux = _sorted_body(params, x, cfg, quant, compute_dtype)
        if cfg.num_shared:
            from . import ffn

            out = out + ffn.apply(params["shared"], x, quant, cfg.ffn_kind,
                                  compute_dtype)
        return out, aux

    def body(params, xs):
        return _sorted_body(params, xs, cfg, quant, compute_dtype,
                            data_axes=data_axes)

    pspec = jax.tree_util.tree_map(lambda _: P(), params)
    # d_model dim of expert stacks is FSDP-sharded (manual over data)
    pspec["experts"] = {"gate": P(None, data_axes, None),
                        "up": P(None, data_axes, None),
                        "down": P(None, None, data_axes)}
    if "shared" in params:
        del pspec["shared"]
        params = dict(params)
        shared = params.pop("shared")
    else:
        shared = None
    from repro.parallel.ctx import shard_map_compat

    out, aux = shard_map_compat(
        body, mesh=mesh, axis_names=set(data_axes),
        in_specs=(pspec, P(data_axes, None, None)),
        out_specs=(P(data_axes, None, None), P()),
        check_vma=False)(params, x)
    if shared is not None:
        from . import ffn

        out = out + ffn.apply(shared, x, quant, cfg.ffn_kind, compute_dtype)
    return out, aux


def apply(params, x, cfg: MoEConfig, quant: QuantConfig,
          compute_dtype=jnp.bfloat16):
    """MoE FFN. x: (B, T, D). Returns (out, aux_loss).

    Dispatch mode "sorted" uses the dropless grouped-GEMM path
    (``apply_sorted``); "dense" is the einsum fallback below.

    Dense-dispatch: combine[b,t,e] = sum_k w_k * 1[idx_k == e]; dispatch is
    its 0/1 indicator. Per-shard einsums only — EP sharding turns the
    expert axis contraction into a local compute + one all-reduce that XLA
    merges with the existing output reduction.
    """
    if cfg.dispatch == "sorted":
        return apply_sorted(params, x, cfg, quant, compute_dtype)
    b, t, d = x.shape
    top_w, one_hot, aux = _router(params, x, cfg)
    combine = jnp.einsum("btk,btke->bte", top_w, one_hot)  # (B,T,E)
    dispatch = (combine > 0).astype(compute_dtype)
    from repro.parallel.ctx import maybe_constrain

    xw = x.astype(compute_dtype)
    h_in = jnp.einsum("bte,btd->ebtd", dispatch, xw)
    h_in = h_in.reshape(cfg.num_experts, b * t, d)
    # EP: dispatched activations shard over the expert axis; when the expert
    # count doesn't divide the TP axis (mixtral: 8 experts, 16-way model),
    # the flat token dim absorbs the model axis instead.
    h_in = maybe_constrain(h_in, "model", "tokens_all", None)
    h_out = _expert_ffn(params["experts"], h_in, quant, cfg.ffn_kind,
                        compute_dtype)
    h_out = h_out.reshape(cfg.num_experts, b, t, d)
    out = jnp.einsum("ebtd,bte->btd", h_out, combine.astype(compute_dtype))
    if cfg.num_shared:
        from . import ffn

        out = out + ffn.apply(params["shared"], x, quant, cfg.ffn_kind,
                              compute_dtype)
    return out, aux.astype(jnp.float32)
