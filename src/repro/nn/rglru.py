"""RG-LRU recurrent block (RecurrentGemma / Griffin), with temporal conv.

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is
diagonal and associative, so training/prefill uses
``jax.lax.associative_scan`` (log-depth, sequence-shardable); decode is a
single-step state update — this is what makes the 500k-token shape
tractable for this arch (state is O(width), not O(seq)).

Projections route through ``linear.apply`` -> MX policy applies; the
recurrence itself stays f32 (tiny FLOP share, numerically stateful —
DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import QuantConfig

from . import common as C
from . import linear

_C_RGLRU = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    width: int  # lru width (recurrentgemma: == d_model)
    conv_width: int = 4


def init(key, cfg: RGLRUConfig):
    ks = C.split_keys(key, 6)
    w = cfg.width
    px, ax = linear.init(ks[0], cfg.d_model, w, (C.D_MODEL, C.RNN))
    pg, ag = linear.init(ks[1], cfg.d_model, w, (C.D_MODEL, C.RNN))
    po, ao = linear.init(ks[2], w, cfg.d_model, (C.RNN, C.D_MODEL))
    # RG-LRU gates: per-channel input projections
    params = {
        "proj_x": px,
        "proj_gate": pg,
        "proj_out": po,
        "conv_w": C.truncated_normal_init(ks[3], (cfg.conv_width, w), 1.0),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "gate_a": C.truncated_normal_init(ks[4], (w, w), 1.0),
        "gate_x": C.truncated_normal_init(ks[5], (w, w), 1.0),
        "gate_a_b": jnp.zeros((w,), jnp.float32),
        "gate_x_b": jnp.zeros((w,), jnp.float32),
        # Lambda init so a^c spans ~[0.9, 0.999]
        "lam": jnp.linspace(0.9, 5.0, w, dtype=jnp.float32),
    }
    axes = {
        "proj_x": ax,
        "proj_gate": ag,
        "proj_out": ao,
        "conv_w": (C.CONV, C.RNN),
        "conv_b": (C.RNN,),
        "gate_a": (C.RNN, C.RNN),
        "gate_x": (C.RNN, C.RNN),
        "gate_a_b": (C.RNN,),
        "gate_x_b": (C.RNN,),
        "lam": (C.RNN,),
    }
    return params, axes


def _gates(params, xc):
    """Recurrence coefficients from conv output xc (f32)."""
    r = jax.nn.sigmoid(xc @ params["gate_a"] + params["gate_a_b"])
    i = jax.nn.sigmoid(xc @ params["gate_x"] + params["gate_x_b"])
    log_a = -_C_RGLRU * jax.nn.softplus(params["lam"]) * r  # (B,S,W) or (B,W)
    a = jnp.exp(log_a)
    # multiplier sqrt(1 - a^2), computed via log for stability
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i * xc


def _conv_full(params, x):
    """Causal temporal conv over (B, S, W) with width-4 kernel."""
    w = params["conv_w"].astype(jnp.float32)  # (CW, W)
    cw = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(cw):
        shifted = jnp.pad(x, ((0, 0), (cw - 1 - i, 0), (0, 0)))[:, : x.shape[1]]
        # tap i sees x at offset -(cw-1-i)
        out = out + shifted * w[i]
    return out + params["conv_b"].astype(jnp.float32)


def apply_train(params, x, cfg: RGLRUConfig, quant: QuantConfig,
                compute_dtype=jnp.bfloat16):
    """Full-sequence recurrent branch: conv -> RG-LRU scan -> gated merge."""
    b, s, _ = x.shape
    xr = linear.apply(params["proj_x"], x, quant, compute_dtype).astype(jnp.float32)
    gate = linear.apply(params["proj_gate"], x, quant, compute_dtype)
    xc = _conv_full(params, xr)
    a, b_term = _gates(params, xc)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    merged = h.astype(compute_dtype) * jax.nn.gelu(
        gate.astype(jnp.float32), approximate=True
    ).astype(compute_dtype)
    return linear.apply(params["proj_out"], merged, quant, compute_dtype,
                        tp_on="in")


def init_state(batch: int, cfg: RGLRUConfig):
    return {
        "h": jnp.zeros((batch, cfg.width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.width), jnp.float32),
    }


def apply_decode(params, x, state, cfg: RGLRUConfig, quant: QuantConfig,
                 compute_dtype=jnp.bfloat16):
    """Single-token step. x: (B, 1, d_model)."""
    b = x.shape[0]
    xr = linear.apply(params["proj_x"], x, quant, compute_dtype)
    xr = xr.astype(jnp.float32)[:, 0]  # (B, W)
    gate = linear.apply(params["proj_gate"], x, quant, compute_dtype)[:, 0]
    w = params["conv_w"].astype(jnp.float32)
    hist = jnp.concatenate([state["conv"], xr[:, None]], axis=1)  # (B,CW,W)
    xc = jnp.einsum("bcw,cw->bw", hist, w) + params["conv_b"]
    a, b_term = _gates(params, xc)
    h = a * state["h"] + b_term
    new_state = {"h": h, "conv": hist[:, 1:]}
    merged = h.astype(compute_dtype) * jax.nn.gelu(
        gate.astype(jnp.float32), approximate=True
    ).astype(compute_dtype)
    out = linear.apply(params["proj_out"], merged[:, None], quant,
                       compute_dtype, tp_on="in")
    return out, new_state


def prefill_state(params, x, cfg: RGLRUConfig, quant: QuantConfig,
                  compute_dtype=jnp.bfloat16):
    """Run the full sequence and return the final recurrent + conv state."""
    b, s, _ = x.shape
    xr = linear.apply(params["proj_x"], x, quant, compute_dtype).astype(jnp.float32)
    xc = _conv_full(params, xr)
    a, b_term = _gates(params, xc)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    cw = cfg.conv_width
    conv_state = xr[:, s - (cw - 1):, :] if s >= cw - 1 else jnp.pad(
        xr, ((0, 0), (cw - 1 - s, 0), (0, 0)))
    return {"h": h[:, -1], "conv": conv_state}
