"""Mamba2 SSD (state-space duality) block, chunked-scan implementation.

Training/prefill uses the quadratic-within-chunk / linear-across-chunk SSD
algorithm (Mamba2 paper, Listing 1): intra-chunk attention-like einsums +
a cross-chunk state recurrence expressed with segment-sum decays. Decode is
the O(1) recurrent update on the (B, H, P, N) state — attention-free, which
is why this arch runs the 500k decode shape.

in/out/conv projections route through the MX linear layer; the SSD scan
itself stays f32 (stateful recurrence, small FLOP share vs projections).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import QuantConfig

from . import common as C
from . import linear
from .norms import rmsnorm_apply, rmsnorm_init

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_model: int
    d_inner: int  # expand * d_model
    headdim: int = 64  # P
    d_state: int = 128  # N
    ngroups: int = 1  # G
    conv_width: int = 4
    chunk: int = 256

    @property
    def nheads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ngroups * self.d_state


def init(key, cfg: SSDConfig):
    ks = C.split_keys(key, 4)
    h = cfg.nheads
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.ngroups * cfg.d_state + h
    wi, ai = linear.init(ks[0], cfg.d_model, d_in_proj, (C.D_MODEL, C.RNN))
    wo, ao = linear.init(ks[1], cfg.d_inner, cfg.d_model, (C.RNN, C.D_MODEL))
    nrm, nrma = rmsnorm_init(ks[2], cfg.d_inner)
    params = {
        "in_proj": wi,
        "out_proj": wo,
        "norm": nrm,
        "conv_w": C.truncated_normal_init(ks[3], (cfg.conv_width, cfg.conv_dim), 1.0),
        "conv_b": jnp.zeros((cfg.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
    }
    axes = {
        "in_proj": ai,
        "out_proj": ao,
        "norm": nrma,
        "conv_w": (C.CONV, C.RNN),
        "conv_b": (C.RNN,),
        "A_log": (C.HEADS,),
        "dt_bias": (C.HEADS,),
        "D": (C.HEADS,),
    }
    return params, axes


def _segsum(x):
    """(..., L) -> (..., L, L) lower-tri segment sums: S[i,j]=sum_{j<k<=i}."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(t)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, d, NEG_INF)


def _ssd_scan(x, dt, A, B, Cm, cfg: SSDConfig, init_state=None):
    """Chunked SSD. x: (b,l,h,p) f32, dt: (b,l,h), A: (h,), B/C: (b,l,g,n).

    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(cfg.chunk, l)
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    nc = l // q
    rep = h // g  # heads per group

    xd = x * dt[..., None]  # discretized input
    Ad = A[None, None, :] * dt  # (b,l,h)

    # chunked views
    xc = xd.reshape(b, nc, q, h, p)
    Ac = Ad.reshape(b, nc, q, h).transpose(0, 3, 1, 2)  # (b,h,c,q)
    Bc = B.reshape(b, nc, q, g, n)
    Cc = Cm.reshape(b, nc, q, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,c,q,h,n) — g broadcast to heads
    Ch = jnp.repeat(Cc, rep, axis=3)

    A_cumsum = jnp.cumsum(Ac, axis=-1)  # (b,h,c,q)
    L = jnp.exp(_segsum(Ac))  # (b,h,c,q,q)

    # 1) intra-chunk (quadratic, attention-like)
    y_diag = jnp.einsum("bcqhn,bcshn,bhcqs,bcshp->bcqhp", Ch, Bh, L, xc)

    # 2) chunk-end states
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)  # (b,h,c,q)
    states = jnp.einsum("bcqhn,bhcq,bcqhp->bchpn", Bh, decay_states, xc)

    # 3) cross-chunk recurrence via decay matrix over chunk sums
    chunk_sum = A_cumsum[..., -1]  # (b,h,c)
    padded = jnp.pad(chunk_sum, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(padded))  # (b,h,c+1,c+1)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    states_all = jnp.concatenate([init_state[:, None], states], axis=1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states_all)
    prev_states = new_states[:, :-1]  # state entering each chunk
    final_state = new_states[:, -1]

    # 4) inter-chunk contribution
    state_decay = jnp.exp(A_cumsum)  # (b,h,c,q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def _conv_full(params, u):
    """Causal conv over (B, S, conv_dim), silu activation."""
    w = params["conv_w"].astype(jnp.float32)
    cw = w.shape[0]
    out = jnp.zeros_like(u)
    for i in range(cw):
        shifted = jnp.pad(u, ((0, 0), (cw - 1 - i, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu(out + params["conv_b"].astype(jnp.float32))


def _split_proj(zxbcdt, cfg: SSDConfig):
    di, g, n, h = cfg.d_inner, cfg.ngroups, cfg.d_state, cfg.nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + cfg.conv_dim]
    dt_raw = zxbcdt[..., di + cfg.conv_dim:]
    return z, xbc, dt_raw


def _post(params, y, z, cfg, quant, compute_dtype):
    gated = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    normed = rmsnorm_apply(params["norm"], gated.astype(compute_dtype))
    return linear.apply(params["out_proj"], normed, quant, compute_dtype,
                        tp_on="in")


def apply_train(params, xin, cfg: SSDConfig, quant: QuantConfig,
                compute_dtype=jnp.bfloat16, init_state=None, return_state=False):
    b, s, _ = xin.shape
    h, p, g, n = cfg.nheads, cfg.headdim, cfg.ngroups, cfg.d_state
    zxbcdt = linear.apply(params["in_proj"], xin, quant, compute_dtype)
    z, xbc, dt_raw = _split_proj(zxbcdt.astype(jnp.float32), cfg)
    xbc = _conv_full(params, xbc)
    x = xbc[..., : cfg.d_inner].reshape(b, s, h, p)
    B = xbc[..., cfg.d_inner: cfg.d_inner + g * n].reshape(b, s, g, n)
    Cm = xbc[..., cfg.d_inner + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])  # (b,s,h)
    A = -jnp.exp(params["A_log"])  # (h,)
    y, state = _ssd_scan(x, dt, A, B, Cm, cfg, init_state)
    y = y + params["D"][None, None, :, None] * x
    out = _post(params, y.reshape(b, s, -1), z, cfg, quant, compute_dtype)
    if return_state:
        return out, state
    return out


def init_state(batch: int, cfg: SSDConfig):
    return {
        "h": jnp.zeros((batch, cfg.nheads, cfg.headdim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), jnp.float32),
    }


def apply_decode(params, xin, state, cfg: SSDConfig, quant: QuantConfig,
                 compute_dtype=jnp.bfloat16):
    """Single-token recurrent step. xin: (B, 1, d_model)."""
    b = xin.shape[0]
    h, p, g, n = cfg.nheads, cfg.headdim, cfg.ngroups, cfg.d_state
    zxbcdt = linear.apply(params["in_proj"], xin, quant, compute_dtype)
    z, xbc_new, dt_raw = _split_proj(zxbcdt.astype(jnp.float32)[:, 0], cfg)
    w = params["conv_w"].astype(jnp.float32)
    hist = jnp.concatenate([state["conv"], xbc_new[:, None]], axis=1)
    xbc = jax.nn.silu(
        jnp.einsum("bcw,cw->bw", hist, w) + params["conv_b"].astype(jnp.float32)
    )
    x = xbc[..., : cfg.d_inner].reshape(b, h, p)
    B = xbc[..., cfg.d_inner: cfg.d_inner + g * n].reshape(b, g, n)
    Cm = xbc[..., cfg.d_inner + g * n:].reshape(b, g, n)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)  # (b,h,n)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])  # (b,h)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(A[None] * dt)  # (b,h)
    hs = state["h"] * decay[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x * dt[..., None], Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", hs, Ch) + params["D"][None, :, None] * x
    out = _post(params, y.reshape(b, 1, -1), z[:, None], cfg, quant, compute_dtype)
    return out, {"h": hs, "conv": hist[:, 1:]}


def prefill_state(params, xin, cfg: SSDConfig, quant: QuantConfig,
                  compute_dtype=jnp.bfloat16):
    """Run the full sequence, return (last-token logits input, state)."""
    b, s, _ = xin.shape
    out, ssd_state = apply_train(params, xin, cfg, quant, compute_dtype,
                                 return_state=True)
    zxbcdt = linear.apply(params["in_proj"], xin, quant, compute_dtype)
    _, xbc, _ = _split_proj(zxbcdt.astype(jnp.float32), cfg)
    cw = cfg.conv_width
    conv_state = xbc[:, s - (cw - 1):, :] if s >= cw - 1 else jnp.pad(
        xbc, ((0, 0), (cw - 1 - s, 0), (0, 0)))
    return out, {"h": ssd_state, "conv": conv_state}
