"""MX dot products: the software execution modes of the VMXDOTP study.

Three execution modes mirror the paper's three hardware tiers:

  * ``emulated`` — the RVV-baseline analogue (paper §III): MX is treated as a
    storage-only format. Elements are decoded to f32 in one step, scales are
    expanded and applied in a second step, and a plain f32 dot follows. Wide
    intermediates materialize in HBM; on a vector core the same structure
    costs conversion + scale instructions.
  * ``fused`` — the Spatz-baseline analogue (MiniFloat-NN-style): a single
    fused dequantize expression produces bf16 operands directly consumed by a
    dot with f32 accumulation. Fewer steps, narrower intermediates, but wide
    operands still materialize.
  * ``pallas`` — the VMXDOTP analogue: the fused TPU kernel in
    ``repro.kernels`` streams compact MX data HBM→VMEM and applies scales
    in-register; no wide tensor touches HBM. (Validated in interpret mode on
    CPU; selected automatically only when explicitly requested.)

``mx_dot`` contracts ``a @ b`` where the blocked axis is the contraction
axis on both sides. ``qat_matmul`` is the custom-vjp training primitive
(straight-through estimator through quantization).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from . import formats as F
from .mx_tensor import MXTensor
from .quantize import quantize, quantize_value

Array = jnp.ndarray
MODES = ("emulated", "fused", "pallas")


def _dequant_two_step(t: MXTensor) -> Array:
    """Paper §III emulated path: decode, then expand + apply scales (f32)."""
    vals = F.decode_elements(t.elements, t.fmt, jnp.float32)
    blocked = vals.reshape(*vals.shape[:-1], t.num_blocks, t.block_size)
    scales = F.e8m0_to_scale(t.scales)  # separate expansion step
    wide = (blocked * scales[..., None]).reshape(vals.shape)
    if t.axis not in (-1, wide.ndim - 1):
        wide = jnp.moveaxis(wide, -1, t.axis)
    return wide


def _dequant_fused(t: MXTensor, dtype=jnp.bfloat16) -> Array:
    """Single-expression dequant in a narrow dtype (XLA fuses to one kernel)."""
    return t.dequantize(dtype)


def _as_wide(x: Union[Array, MXTensor], mode: str, dtype) -> Array:
    if isinstance(x, MXTensor):
        if mode == "emulated":
            return _dequant_two_step(x)
        return _dequant_fused(x, dtype)
    return x.astype(dtype) if mode != "emulated" else x.astype(jnp.float32)


def mx_dot(
    a: Union[Array, MXTensor],
    b: Union[Array, MXTensor],
    *,
    mode: str = "fused",
    acc_dtype=jnp.float32,
    out_dtype=None,
) -> Array:
    """Contract ``a (..., K) @ b (K, N)`` with MX semantics.

    Either operand may be an :class:`MXTensor` (blocked along the contraction
    axis) or a plain array — the latter matches the paper's vector-scalar
    variants (``vmxdotp.*f``) where one side is wide.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "pallas":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.mx_matmul(a, b, acc_dtype=acc_dtype, out_dtype=out_dtype)

    operand_dtype = jnp.float32 if mode == "emulated" else jnp.bfloat16
    aw = _as_wide(a, mode, operand_dtype)
    bw = _as_wide(b, mode, operand_dtype)
    out = jax.lax.dot_general(
        aw,
        bw,
        (((aw.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    return out.astype(out_dtype or acc_dtype)


# ---------------------------------------------------------------------------
# Quantization-aware training primitive
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7)
)
def qat_matmul(
    x: Array,
    w: Array,
    fmt: str = "fp8_e4m3",
    block_size: int = 32,
    quantize_acts: bool = True,
    mode: str = "fused",
    acc_dtype=jnp.float32,
    tp_on: str = "out",
) -> Array:
    """``x @ w`` through MX quantization with a straight-through backward.

    Master weights stay wide; both operands are freshly block-quantized along
    the contraction axis each call (per-step quantization, as in MX training
    recipes). The backward pass uses the *quantized* values (consistent
    gradients) but flows straight through the quantizer.

    ``tp_on`` ("out" | "in") says which w dim carries tensor parallelism —
    used to pin the quantized representation's sharding so the FSDP weight
    all-gather moves MX bytes (~1.06 B/param), not f32 masters (MX-FSDP,
    §Perf iteration 5).
    """
    y, _ = _qat_fwd(x, w, fmt, block_size, quantize_acts, mode, acc_dtype,
                    tp_on)
    return y


def _mx_fsdp_quantize(w, fmt, block_size, tp_on):
    """MX-FSDP: quantize on the FSDP shard, all-gather the MX bytes.

    GSPMD left to itself gathers the f32 master and quantizes replicated
    (measured: f32 weight all-gathers, §Perf iteration 5a — refuted).
    shard_map makes the intended dataflow explicit: each device quantizes
    its local weight shard (MX blocks are shard-local), then the FSDP
    all-gather moves fp8 elements + u8 scales (~1.06 B/param) instead of
    f32 (4 B/param) — a 3.8x cut of weight-gather traffic. TP-dim sharding
    is preserved; any divisibility failure falls back to the plain path.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.parallel.ctx import current_mesh, shard_map_compat

    mesh = current_mesh()
    fmt_i = F.get_format(fmt)
    if mesh is None or fmt_i.packed:  # fp4 path keeps the plain quantizer
        return quantize(w, fmt, block_size, axis=0)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model" if "model" in mesh.axis_names else None
    if not fsdp:
        return quantize(w, fmt, block_size, axis=0)
    d_in, d_out = w.shape
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp]))
    tp_size = mesh.shape[tp] if tp else 1

    if tp_on == "out":
        ok = (d_in % fsdp_size == 0 and (d_in // fsdp_size) % block_size == 0)
        tp_ok = tp is not None and d_out % tp_size == 0
        if not ok:
            return quantize(w, fmt, block_size, axis=0)
        w_spec = P(fsdp, tp if tp_ok else None)
        out_specs = (P(tp if tp_ok else None, None),
                     P(tp if tp_ok else None, None))
        gather_dim = 1  # elements (d_out_shard, d_in_shard): gather d_in
    else:
        ok = (tp is not None and d_in % tp_size == 0
              and (d_in // tp_size) % block_size == 0)
        fsdp_ok = d_out % fsdp_size == 0
        if not ok or not fsdp_ok:
            return quantize(w, fmt, block_size, axis=0)
        w_spec = P(tp, fsdp)
        out_specs = (P(None, tp), P(None, tp))
        gather_dim = 0  # elements (d_out_shard, d_in_shard): gather d_out

    def body(w_shard):
        t = quantize(w_shard, fmt, block_size, axis=0)
        elems = jax.lax.all_gather(t.elements, fsdp, axis=gather_dim,
                                   tiled=True)
        scales = jax.lax.all_gather(t.scales, fsdp, axis=gather_dim,
                                    tiled=True)
        return elems, scales

    elems, scales = shard_map_compat(body, mesh=mesh, in_specs=(w_spec,),
                                     out_specs=out_specs, check_vma=False)(w)
    return MXTensor(elements=elems, scales=scales, fmt_name=fmt_i.name,
                    block_size=block_size, axis=0, shape=w.shape)


def _qat_fwd(x, w, fmt, block_size, quantize_acts, mode, acc_dtype,
             tp_on="out"):
    # Residuals and dot operands stay bf16 (fp8/fp4 values are exactly
    # representable; power-of-two scales are exact): no f32 activation
    # copies materialize in the training graph (§Perf iteration 2).
    res_dtype = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32
    if tp_on != "off":
        w_mx = _mx_fsdp_quantize(w, fmt, block_size, tp_on)
    else:
        w_mx = quantize(w, fmt, block_size, axis=0)
    if quantize_acts:
        x_mx = quantize(x, fmt, block_size, axis=-1)
        y = mx_dot(x_mx, w_mx, mode=mode, acc_dtype=acc_dtype)
        xq = x_mx.dequantize(res_dtype)
    else:
        y = mx_dot(x, w_mx, mode=mode, acc_dtype=acc_dtype)
        xq = x
    wq = w_mx.dequantize(res_dtype)
    return y.astype(x.dtype), (xq, wq)


def _qat_bwd(fmt, block_size, quantize_acts, mode, acc_dtype, tp_on, res, dy):
    xq, wq = res
    op_dtype = xq.dtype  # bf16 in training graphs, f32 in exact tests
    dy = dy.astype(op_dtype)
    # dx in operand dtype: the TP all-reduce of activation grads then moves
    # bf16 instead of f32 — halves the dominant train-step collective
    # (§Perf iteration 3). dw stays f32 into the optimizer.
    dx = jax.lax.dot_general(
        dy,
        wq,
        (((dy.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=op_dtype,
    )
    x2 = xq.reshape(-1, xq.shape[-1])
    dy2 = dy.reshape(-1, dy.shape[-1])
    dw = jax.lax.dot_general(
        x2, dy2, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    return dx.astype(xq.dtype), dw.astype(jnp.float32)


qat_matmul.defvjp(_qat_fwd, _qat_bwd)


def fake_quant(x: Array, fmt: str, block_size: int, axis: int = -1) -> Array:
    """Straight-through fake quantization of a single tensor (for QAT)."""

    @jax.custom_vjp
    def _fq(v):
        return quantize_value(v, fmt, block_size, axis)

    _fq.defvjp(lambda v: (_fq(v), None), lambda _, g: (g,))
    return _fq(x)
