"""Quantization policy: which tensors are MX-quantized, how, and where.

This is the framework-level surface of the paper's technique: a single
config object threaded through every layer, selecting element format,
software-defined block size (paper design goal: not fixed to 32), execution
mode, accumulator precision, and which tensor classes participate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """MX quantization policy for a model.

    Attributes:
      enabled: master switch; False means wide (bf16/f32) everywhere.
      fmt: element format for weights ("fp8_e4m3" | "fp8_e5m2" |
        "fp6_e3m2" | "fp6_e2m3" | "fp4_e2m1").
      act_fmt: element format for activations (defaults to ``fmt``; E5M2 is
        the usual choice for gradients/activations due to range).
      block_size: software-defined MX block size k (divides contraction dims).
      quantize_acts: quantize activations entering matmuls (vector-vector
        variant) or keep them wide (vector-scalar variant, weight-only).
      mode: execution mode ("emulated" | "fused" | "pallas").
      acc_dtype: accumulator precision (f32 per spec, bf16 compact option).
      quantize_kv_cache: store the serving KV cache in MX format.
      quantize_grads: MX-compress cross-pod gradient all-reduce (training).
      mx_weight_gather: perform the FSDP weight all-gather on the MX
        representation (fp8 elements + E8M0 scales ~= 1.06 B/param) instead
        of wide masters — the paper's compact-operand insight applied to
        the collective fabric (beyond-paper; §Perf iteration 5).
    """

    enabled: bool = True
    fmt: str = "fp8_e4m3"
    act_fmt: Optional[str] = None
    block_size: int = 32
    quantize_acts: bool = True
    mode: str = "fused"
    acc_dtype: object = jnp.float32
    quantize_kv_cache: bool = False
    quantize_grads: bool = False
    mx_weight_gather: bool = True

    @property
    def activation_format(self) -> str:
        return self.act_fmt or self.fmt

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


WIDE = QuantConfig(enabled=False)
MXFP8 = QuantConfig(fmt="fp8_e4m3", act_fmt="fp8_e5m2")
# FP6 sits between FP8 and FP4: same 6-bit-per-element cache footprint gain
# the paper's software-defined formats make reachable. Matmul kernels do not
# take FP6 operands yet (KV pages and the repack ladder do), so FP6 presets
# keep activations at e5m2 and are primarily a KV-cache/serving policy.
MXFP6 = QuantConfig(fmt="fp6_e3m2", act_fmt="fp8_e5m2")
MXFP4 = QuantConfig(fmt="fp4_e2m1", act_fmt="fp8_e5m2")
