"""Block quantization to MX formats (OCP MX spec v1.0 semantics).

``quantize`` is the software analogue of preparing VMXDOTP operands: split
the array into blocks of ``block_size`` along the contraction axis, derive
one E8M0 shared exponent per block from the block amax, and cast elements to
the narrow format with round-to-nearest-even + saturation.

Block sizes are software-defined (the paper's design goal): any ``k`` that
divides the blocked axis is legal, not just the spec's k=32.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import formats as F
from .mx_tensor import MXTensor


def _move_axis_last(x: jnp.ndarray, axis: int):
    axis = axis % x.ndim
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
    return x


def quantize(
    x: jnp.ndarray,
    fmt="fp8_e4m3",
    block_size: int = 32,
    axis: int = -1,
) -> MXTensor:
    """Quantize ``x`` to an :class:`MXTensor` along ``axis``.

    Args:
      x: array to quantize (any float dtype).
      fmt: element format ("fp8_e4m3" | "fp8_e5m2" | "fp6_e3m2" |
        "fp6_e2m3" | "fp4_e2m1").
      block_size: MX block size k (must divide ``x.shape[axis]``).
      axis: axis along which blocks run (the contraction axis for matmuls).
    """
    fmt = F.get_format(fmt)
    logical_shape = x.shape
    axis = axis % x.ndim
    # Bandwidth policy: bf16 inputs are quantized in bf16 (block max and
    # power-of-two scaling are exact in bf16; the ratio double-rounds
    # 8->format mantissa bits, acceptable for QAT and it halves the HBM
    # traffic of the in-graph quantizer — §Perf iteration 2). f32 inputs
    # keep the exact f32 path used by the kernel oracles.
    work_dtype = jnp.bfloat16 if x.dtype == jnp.bfloat16 else jnp.float32
    xl = _move_axis_last(x, axis).astype(work_dtype)
    k = xl.shape[-1]
    if k % block_size != 0:
        raise ValueError(
            f"block_size {block_size} does not divide axis length {k}"
        )
    blocked = xl.reshape(*xl.shape[:-1], k // block_size, block_size)
    amax = jnp.max(jnp.abs(blocked), axis=-1)
    e_biased = F.e8m0_from_amax(amax, fmt)  # (..., num_blocks) uint8
    scale = F.e8m0_to_scale(e_biased, work_dtype)[..., None]
    ratio = jnp.where(scale > 0, blocked / scale, 0.0)
    elements = F.encode_elements(ratio.reshape(xl.shape), fmt)
    return MXTensor(
        elements=elements,
        scales=e_biased,
        fmt_name=fmt.name,
        block_size=block_size,
        axis=axis,
        shape=logical_shape,
    )


def dequantize(t: MXTensor, dtype=jnp.float32) -> jnp.ndarray:
    return t.dequantize(dtype)


def quantize_value(
    x: jnp.ndarray, fmt="fp8_e4m3", block_size: int = 32, axis: int = -1
) -> jnp.ndarray:
    """Fake-quantize: quantize then dequantize, staying in wide dtype.

    Used by the QAT straight-through estimator and by accuracy benchmarks.
    """
    return quantize(x, fmt, block_size, axis).dequantize(x.dtype)
