"""MXTensor: a pytree container for block-scaled (microscaling) arrays.

An ``MXTensor`` stores an array quantized along one axis in blocks of
``block_size`` elements. Per the OCP MX spec each block carries one shared
E8M0 scale; elements are stored in a narrow FP format (FP8 dtypes, or
nibble-packed uint8 for FP4).

The quantized axis is always stored as the *last* axis internally; ``axis``
records where it lives logically so ``dequantize`` can restore the layout.
Keeping the blocked axis contiguous mirrors the paper's column-major-B layout
("elements of the same MX block are stored contiguously in memory",
§IV-D) and is what the Pallas kernel's BlockSpecs assume.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import formats as F


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MXTensor:
    """Block-scaled tensor: ``elements`` (narrow FP) + E8M0 ``scales``.

    Attributes:
      elements: storage array; shape (..., K) for FP8, (..., K//2) for FP4
        (two nibbles per byte). The blocked (contraction) axis is last.
      scales: uint8 biased E8M0 exponents, shape (..., K // block_size).
      fmt_name: element format name ("fp8_e4m3" | "fp8_e5m2" | "fp4_e2m1").
      block_size: software-defined MX block size k (paper: any multiple of
        the hardware block; here any k that divides K).
      axis: logical position of the blocked axis in the dequantized array.
      shape: logical (dequantized) shape.
    """

    elements: jnp.ndarray
    scales: jnp.ndarray
    fmt_name: str = "fp8_e4m3"
    block_size: int = 32
    axis: int = -1
    shape: tuple = ()

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.elements, self.scales), (
            self.fmt_name,
            self.block_size,
            self.axis,
            self.shape,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        elements, scales = children
        fmt_name, block_size, axis, shape = aux
        return cls(elements, scales, fmt_name, block_size, axis, shape)

    # -- properties ----------------------------------------------------------
    @property
    def fmt(self) -> F.ElementFormat:
        return F.get_format(self.fmt_name)

    @property
    def k(self) -> int:
        """Logical length of the blocked axis."""
        return self.shape[self.axis]

    @property
    def num_blocks(self) -> int:
        return self.k // self.block_size

    @property
    def nbytes(self) -> int:
        """Storage footprint in bytes (elements + scales)."""
        return self.elements.size * self.elements.dtype.itemsize + self.scales.size

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        """Reconstruct the wide array: ``elements * 2^(scales - 127)``."""
        vals = F.decode_elements(self.elements, self.fmt, jnp.float32)
        blocked = vals.reshape(*vals.shape[:-1], self.num_blocks, self.block_size)
        scale = F.e8m0_to_scale(self.scales)[..., None]
        wide = (blocked * scale).reshape(vals.shape)
        if self.axis not in (-1, wide.ndim - 1):
            wide = jnp.moveaxis(wide, -1, self.axis)
        return wide.astype(dtype)

    def astype_acc(self, dtype):  # convenience used by serving code
        return self.dequantize(dtype)
